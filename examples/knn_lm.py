"""kNN-LM with a compressed datastore (Khandelwal et al. 2019 × this paper).

    PYTHONPATH=src python examples/knn_lm.py

The paper motivates index compression partly through kNN-LM-style pipelines
(§1).  This example builds the full loop with our substrate:

  1. train a tiny transformer LM on a synthetic Zipfian corpus,
  2. run it over the corpus collecting (hidden state → next token) pairs —
     the datastore,
  3. compress the datastore index with PCA+int8 (24×),
  4. serve the kNN lookups through the :class:`RetrievalService` front
     door (the datastore registered as a named index, queried via the
     async handle API), then decode with p = λ·p_kNN + (1−λ)·p_LM and
     compare perplexity LM-only vs kNN-LM-compressed.
"""

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import (CenterNorm, CompressionPipeline, Int8Quantizer, PCA)
from repro.models import transformer as T
from repro.retrieval import CompressedIndex
from repro.serve import QueryOptions, RetrievalService
from repro.train import optimizer as O
from repro.train import trainer

CFG = LMConfig("knn-lm-tiny", n_layers=2, d_model=64, n_heads=4,
               n_kv_heads=2, d_ff=128, vocab_size=256, attn_q_chunk=32,
               loss_chunk=None, remat="none")


def zipf_corpus(rng, n_seqs, seq_len, vocab, trans):
    """Markov token stream over a SHARED transition table (so the kNN
    datastore built on train text transfers to test text)."""
    toks = np.zeros((n_seqs, seq_len), np.int32)
    toks[:, 0] = rng.integers(0, vocab, n_seqs)
    for t in range(1, seq_len):
        choice = rng.integers(0, 4, n_seqs)
        toks[:, t] = trans[toks[:, t - 1], choice]
    return jnp.asarray(toks)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lam", type=float, default=0.3)
    ap.add_argument("--k", type=int, default=8)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    trans = rng.integers(0, CFG.vocab_size, (CFG.vocab_size, 4))
    train_toks = zipf_corpus(rng, 256, 64, CFG.vocab_size, trans)
    test_toks = zipf_corpus(rng, 32, 64, CFG.vocab_size, trans)

    # --- 1) train the LM
    tx = O.adamw(1e-3, max_grad_norm=1.0)
    state = trainer.init_state(jax.random.PRNGKey(0),
                               lambda r: T.init(r, CFG), tx)
    step = jax.jit(trainer.make_train_step(
        lambda p, b: T.loss_fn(p, b, CFG), tx), donate_argnums=(0,))
    for i in range(args.steps):
        sel = rng.integers(0, train_toks.shape[0], 16)
        batch = {"tokens": train_toks[sel], "labels": train_toks[sel]}
        state, metrics = step(state, batch)
        if (i + 1) % 50 == 0:
            print(f"step {i + 1}: loss={float(metrics['loss']):.3f}")
    params = state["params"]

    # --- 2) datastore: (hidden state at position t → token t+1)
    feats, _ = jax.jit(lambda p, t: T.forward_features(p, t, CFG))(
        params, train_toks)
    keys = np.asarray(feats[:, :-1].astype(jnp.float32)).reshape(-1, 64)
    vals = np.asarray(train_toks[:, 1:]).reshape(-1)
    print(f"datastore: {keys.shape[0]} entries × {keys.shape[1]} dims")

    # --- 3) compress it (PCA to half dims + int8)
    pipe = CompressionPipeline([CenterNorm(), PCA(32), CenterNorm(),
                                Int8Quantizer()])
    idx = CompressedIndex.build(jnp.asarray(keys), None, pipe)
    print(f"compressed {keys.nbytes / idx.nbytes:.0f}x")

    # --- 4) evaluate perplexity with and without kNN mixing
    feats_t, _ = jax.jit(lambda p, t: T.forward_features(p, t, CFG))(
        params, test_toks)
    head = params["lm_head"]
    logits = np.asarray(feats_t.astype(jnp.float32) @ head)
    q = np.asarray(feats_t[:, :-1].astype(jnp.float32)).reshape(-1, 64)
    targets = np.asarray(test_toks[:, 1:]).reshape(-1)

    logp_lm = jax.nn.log_softmax(jnp.asarray(logits[:, :-1])
                                 .reshape(-1, CFG.vocab_size), -1)
    nll_lm = -np.asarray(logp_lm)[np.arange(len(targets)), targets]

    # the datastore is a named index behind the serving front door; the
    # eval loop is just another producer submitting async query blocks
    with RetrievalService(default_k=args.k) as service:
        service.register("datastore", idx)
        handle = service.query(q, QueryOptions(index="datastore",
                                               k=args.k))
        res = handle.result(timeout=300)
        dists, ids = res.scores, res.ids
    knn_tokens = vals[np.asarray(ids)]                      # (N, k)
    w = jax.nn.softmax(jnp.asarray(dists), -1)              # similarity IP
    p_knn = np.zeros((len(targets), CFG.vocab_size), np.float32)
    np.add.at(p_knn, (np.arange(len(targets))[:, None], knn_tokens),
              np.asarray(w))
    lam = args.lam
    p_mix = lam * p_knn + (1 - lam) * np.exp(np.asarray(logp_lm))
    nll_mix = -np.log(np.maximum(
        p_mix[np.arange(len(targets)), targets], 1e-9))

    print(f"\nperplexity LM-only:            {np.exp(nll_lm.mean()):.2f}")
    print(f"perplexity kNN-LM (24x index): {np.exp(nll_mix.mean()):.2f}")
    if np.exp(nll_mix.mean()) < np.exp(nll_lm.mean()):
        print("→ compressed datastore still improves the LM "
              "(the paper's motivating use case).")


if __name__ == "__main__":
    sys.exit(main())
