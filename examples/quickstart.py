"""Quickstart: compress a KB index 24×, save the artifact, serve from it.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --n-docs 2000 --n-queries 64

Builds a DPR-like synthetic KB, fits the paper's best practical pipeline
(center+norm → PCA-128 → center+norm → int8) through the declarative
:class:`IndexSpec` / :func:`build_index` API, compares retrieval quality +
storage against the uncompressed index, then round-trips the full index
artifact through ``save``/``load_index`` — the cold-start path a serve
process uses (no raw corpus, no re-fit).
"""

import argparse
import os
import sys
import tempfile
import time

import numpy as np

from repro.core import CenterNorm
from repro.data import make_dpr_like_kb
from repro.retrieval import (DenseIndex, IndexSpec, build_index, load_index,
                             r_precision)
from repro.utils import human_bytes


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=50_000)
    ap.add_argument("--n-queries", type=int, default=1000)
    ap.add_argument("--dim", type=int, default=128,
                    help="PCA target dimensionality")
    args = ap.parse_args(argv)

    print(f"1) synthesizing DPR-like KB ({args.n_docs} docs x 768 dims) ...")
    kb = make_dpr_like_kb(n_queries=args.n_queries, n_docs=args.n_docs)
    print(f"   doc L2 norm  {kb.meta['doc_l2']:.1f} "
          f"(paper: 12.3)   query L2 {kb.meta['query_l2']:.1f} (paper: 9.3)")

    print("2) uncompressed baseline ...")
    pre = CenterNorm().fit(kb.docs, kb.queries)
    docs_n, queries_n = pre(kb.docs, "docs"), pre(kb.queries, "queries")
    exact = DenseIndex(docs_n)
    base_rp = r_precision(queries_n, docs_n, kb.relevant, "ip")
    print(f"   R-Precision {base_rp:.3f}   index size "
          f"{human_bytes(exact.nbytes)}")

    print(f"3) building the 24x index from a declarative spec "
          f"(center+norm → PCA-{args.dim} → center+norm → int8) ...")
    # the paper's exact stage order: post-processing *before* the trailing
    # quantizer, so storage is real int8 codes (24x) on the kernel path
    spec = IndexSpec(stages=(("CenterNorm", {}), ("PCA", {"dim": args.dim}),
                             ("CenterNorm", {}), ("Int8Quantizer", {})))
    t0 = time.time()
    idx = build_index(spec, kb.docs, kb.queries)
    print(f"   fitted + encoded in {time.time() - t0:.1f}s; "
          f"index size {human_bytes(idx.nbytes)} "
          f"({exact.nbytes / idx.nbytes:.0f}x smaller)")

    print("4) save artifact, cold-start reload (no corpus, no re-fit) ...")
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "kb_index.npz")
        idx.save(path)
        t0 = time.time()
        idx = load_index(path)
        stages = " -> ".join(n for n, _ in idx.spec.stages)
        print(f"   artifact {human_bytes(os.path.getsize(path))}, "
              f"loaded in {time.time() - t0:.2f}s ({stages})")

    print("5) serving queries from the reloaded compressed index ...")
    t0 = time.time()
    _, ids = idx.search(kb.queries, k=2)
    dt = time.time() - t0
    hits = np.mean([len(set(ids_i.tolist()) & set(rel_i.tolist())) / 2
                    for ids_i, rel_i in zip(np.asarray(ids), kb.relevant)])
    print(f"   R-Precision {hits:.3f} "
          f"({100 * hits / base_rp:.0f}% of uncompressed) "
          f"at {1000 * dt / len(kb.queries):.2f} ms/query (CPU)")

    print("\npaper's claim: 24x compression retains ~92% retrieval "
          "performance — reproduced." if hits / base_rp > 0.85 else
          "\nWARNING: ratio below expectation")


if __name__ == "__main__":
    sys.exit(main())
