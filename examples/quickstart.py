"""Quickstart: compress a KB index 24× and serve queries from it.

    PYTHONPATH=src python examples/quickstart.py

Builds a DPR-like synthetic KB, fits the paper's best practical pipeline
(center+norm → PCA-128 → center+norm → int8), and compares retrieval
quality + storage against the uncompressed index.
"""

import sys
import time

import numpy as np

from repro.core import (CenterNorm, CompressionPipeline, Int8Quantizer, PCA)
from repro.data import make_dpr_like_kb
from repro.retrieval import CompressedIndex, DenseIndex, r_precision
from repro.utils import human_bytes


def main() -> None:
    print("1) synthesizing DPR-like KB (50k docs × 768 dims) ...")
    kb = make_dpr_like_kb(n_queries=1000, n_docs=50_000)
    print(f"   doc L2 norm  {kb.meta['doc_l2']:.1f} "
          f"(paper: 12.3)   query L2 {kb.meta['query_l2']:.1f} (paper: 9.3)")

    print("2) uncompressed baseline ...")
    pre = CenterNorm().fit(kb.docs, kb.queries)
    docs_n, queries_n = pre(kb.docs, "docs"), pre(kb.queries, "queries")
    exact = DenseIndex(docs_n)
    base_rp = r_precision(queries_n, docs_n, kb.relevant, "ip")
    print(f"   R-Precision {base_rp:.3f}   index size "
          f"{human_bytes(exact.nbytes)}")

    print("3) fitting the 24x pipeline (center+norm → PCA-128 → "
          "center+norm → int8) ...")
    pipe = CompressionPipeline([CenterNorm(), PCA(128), CenterNorm(),
                                Int8Quantizer()])
    t0 = time.time()
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe)
    print(f"   fitted + encoded in {time.time() - t0:.1f}s; "
          f"index size {human_bytes(idx.nbytes)} "
          f"({exact.nbytes / idx.nbytes:.0f}x smaller)")

    print("4) serving queries from the compressed index ...")
    t0 = time.time()
    _, ids = idx.search(kb.queries, k=2)
    dt = time.time() - t0
    hits = np.mean([len(set(ids_i.tolist()) & set(rel_i.tolist())) / 2
                    for ids_i, rel_i in zip(np.asarray(ids), kb.relevant)])
    print(f"   R-Precision {hits:.3f} "
          f"({100 * hits / base_rp:.0f}% of uncompressed) "
          f"at {1000 * dt / len(kb.queries):.2f} ms/query (CPU)")

    print("\npaper's claim: 24x compression retains ~92% retrieval "
          "performance — reproduced." if hits / base_rp > 0.85 else
          "\nWARNING: ratio below expectation")


if __name__ == "__main__":
    sys.exit(main())
