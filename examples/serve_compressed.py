"""End-to-end serving driver: batched requests against a compressed,
(optionally sharded) KB index — the paper's production deployment.

    PYTHONPATH=src python examples/serve_compressed.py --requests 50
    PYTHONPATH=src python examples/serve_compressed.py --method pca_onebit

Simulates a request stream (batches of queries), measures per-batch latency
percentiles, and verifies quality online against an exact-search shadow
index (the standard "shadow scoring" deployment-validation pattern).
"""

import argparse
import sys
import time

import numpy as np

from repro.core import build_method
from repro.data import make_dpr_like_kb
from repro.retrieval import CompressedIndex, DenseIndex
from repro.utils import human_bytes


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="pca_int8",
                    choices=("pca_int8", "pca_onebit", "onebit", "int8"))
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--n-docs", type=int, default=50_000)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    dim = 245 if args.method == "pca_onebit" else args.dim
    kb = make_dpr_like_kb(n_queries=args.requests * args.batch,
                          n_docs=args.n_docs)

    print(f"building compressed index [{args.method}] ...")
    pipe = build_method(args.method, dim)
    idx = CompressedIndex.build(kb.docs, kb.queries[:512], pipe)
    shadow = DenseIndex(idx.encode_queries(kb.docs))   # shadow: float stages
    print(f"  index {human_bytes(idx.nbytes)} vs shadow "
          f"{human_bytes(shadow.nbytes)} "
          f"({shadow.nbytes / idx.nbytes:.0f}x)")

    lat, overlap = [], []
    queries = np.asarray(kb.queries)
    for r in range(args.requests):
        batch = queries[r * args.batch: (r + 1) * args.batch]
        t0 = time.perf_counter()
        _, ids = idx.search(batch, args.k)
        lat.append(time.perf_counter() - t0)
        if r % 5 == 0:      # shadow-score 20% of traffic
            _, want = shadow.search(
                idx.encode_queries(batch), args.k)
            overlap.append(np.mean([
                len(set(a.tolist()) & set(b.tolist())) / args.k
                for a, b in zip(np.asarray(ids), np.asarray(want))]))

    lat_ms = np.asarray(lat) * 1000
    print(f"\nserved {args.requests} batches × {args.batch} queries")
    print(f"  latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms  (CPU host)")
    print(f"  top-{args.k} overlap vs exact shadow: "
          f"{np.mean(overlap):.3f}")


if __name__ == "__main__":
    sys.exit(main())
