"""End-to-end serving driver: a request stream against a compressed,
(optionally IVF) KB index artifact through the :mod:`repro.serve` engine.

    PYTHONPATH=src python examples/serve_compressed.py --requests 50
    PYTHONPATH=src python examples/serve_compressed.py --method pca_onebit

The index is described declaratively (:class:`IndexSpec`), built once with
:func:`build_index`, saved to a single ``.npz`` artifact, and the engine
cold-starts from that artifact (``ServeEngine.from_artifact``) exactly like
a production serve process would — no raw corpus, no re-fit.  The driver
then simulates a request stream (blocks of queries submitted to the
engine), which coalesces them into padded micro-batches, dispatches to the
index, measures latency percentiles, and validates quality online against
an exact-search shadow index (the standard "shadow scoring" pattern).
"""

import argparse
import os
import sys
import tempfile

import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import IndexSpec, build_index
from repro.serve import MicroBatcher, ServeEngine, ShadowScorer
from repro.utils import human_bytes


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="pca_int8",
                    choices=("pca_int8", "pca_onebit", "onebit", "int8"))
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--n-docs", type=int, default=50_000)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--no-post", action="store_true",
                    help="skip post-quantization CenterNorm: storage stays "
                         "quantized and scoring runs the int8/1-bit kernels")
    ap.add_argument("--drain-every", type=int, default=1,
                    help="submit N requests between drains (N>1 shows the "
                         "micro-batcher coalescing requests)")
    ap.add_argument("--ivf-nlist", type=int, default=0,
                    help="build an IVF index with this many lists "
                         "(0 = exact search)")
    ap.add_argument("--ivf-nprobe", type=int, default=0,
                    help="default probe width (0 = nlist/2); every 4th "
                         "request overrides it per-request to nlist")
    args = ap.parse_args(argv)

    dim = 245 if args.method == "pca_onebit" else args.dim
    kb = make_dpr_like_kb(n_queries=args.requests * args.batch,
                          n_docs=args.n_docs)

    ivf = None
    full_probe = None
    if args.ivf_nlist:
        nprobe = args.ivf_nprobe or max(1, args.ivf_nlist // 2)
        ivf = (args.ivf_nlist, nprobe)

    spec = IndexSpec(method=args.method, dim=dim, post=not args.no_post,
                     ivf=ivf)
    print(f"building index from spec [{args.method}"
          f"{', ivf=' + str(ivf) if ivf else ''}] ...")
    idx = build_index(spec, kb.docs, kb.queries[:512])
    print(f"  scorer backend: {idx.scorer.name}")
    shadow = ShadowScorer.for_compressed(idx, kb.docs, every=5)
    print(f"  index {human_bytes(idx.nbytes)} vs shadow "
          f"{human_bytes(shadow.index.nbytes)} "
          f"({shadow.index.nbytes / idx.nbytes:.0f}x)")
    if ivf:
        full_probe = idx.nlist
        print(f"  IVF: nlist={idx.nlist} nprobe={idx.nprobe} "
              f"(every 4th request forces nprobe={full_probe})")

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "kb_index.npz")
        idx.save(path)
        print(f"  artifact {human_bytes(os.path.getsize(path))}; engine "
              "cold-starts from it (no corpus, no re-fit)")
        engine = ServeEngine.from_artifact(
            path, k=args.k, batcher=MicroBatcher(max_batch=args.max_batch),
            shadow=shadow)

    queries = np.asarray(kb.queries)
    served = 0
    for r in range(args.requests):
        # recall-sensitive traffic widens its probe per request; the engine
        # batches each nprobe group through its own compiled graph
        nprobe = full_probe if (full_probe and r % 4 == 3) else None
        engine.submit(queries[r * args.batch: (r + 1) * args.batch],
                      nprobe=nprobe)
        if (r + 1) % args.drain_every == 0:
            served += len(engine.drain())
    served += len(engine.drain())

    stats = engine.stats()
    print(f"\nserved {served} requests "
          f"({stats['queries_served']} queries, "
          f"{stats['batches_served']} micro-batches)")
    print(f"  latency p50={stats['p50_ms']:.1f}ms "
          f"p95={stats['p95_ms']:.1f}ms "
          f"p99={stats['p99_ms']:.1f}ms  (CPU host)")
    print(f"  top-{args.k} overlap vs exact shadow: "
          f"{stats['shadow_overlap']:.3f} "
          f"({stats['shadow_batches']} batches sampled)")


if __name__ == "__main__":
    sys.exit(main())
