"""End-to-end serving driver: a request stream against the
:class:`~repro.serve.RetrievalService` front door, including a mid-stream
staged → canaried → promoted KB refresh.

    PYTHONPATH=src python examples/serve_compressed.py --requests 50
    PYTHONPATH=src python examples/serve_compressed.py --method pca_onebit

The index is described declaratively (:class:`IndexSpec`), built once with
:func:`build_index`, saved to a single ``.npz`` artifact, and the service
registers that artifact as version 1 of a named index — exactly like a
production serve process: no raw corpus, no re-fit.  Producer code then
streams query blocks through the async API (``service.query(...) →
QueryHandle``) while a background drain loop micro-batches and dispatches
them.  Halfway through, a *refreshed* corpus (the nightly-rebuild
scenario: new documents appended) is built into a second artifact, staged
off the serving path, canaried against live traffic via shadow overlap,
and promoted with zero downtime — requests keep flowing throughout and
each one ranks entirely against the version it bound to.

With ``--ivf-nlist`` the driver adds a third act: the refreshed KB is
streamed to a *chunked* (v3) artifact and hot-swapped in with only a 25%
hot-tier byte budget resident — the encoded inverted lists stay on disk,
Zipf-skewed open-loop traffic (the PR-7 load generator) keeps the LRU hot
tier warm, and the per-version ``stats()`` row reports the tier hit rate.

With ``--shards N`` a fourth act shards the refreshed KB over N devices
(``ShardSpec(shards=N)`` — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to force host
devices) and hot-swaps it in: staging places every shard or none, the
promote is the same atomic pointer flip, results match the single-host
version bit-for-bit, and the ``stats()`` row grows a per-shard rollup.
"""

import argparse
import os
import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import (IndexSpec, ShardSpec, build_index,
                             load_index_meta, save_index)
from repro.serve import QueryOptions, RetrievalService
from repro.utils import human_bytes


def serve_tiered(service, idx, tmp, queries):
    """Act three: same KB, v3 chunked artifact, 25% resident budget."""
    # the open-loop Zipf/Poisson generator lives in benchmarks/
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.loadgen import (DEFAULT_MENU, build_workload,
                                    run_trial, warmup)

    path = os.path.join(tmp, "kb_v3")
    save_index(idx, path, chunked=True)
    enc = load_index_meta(path)["encoded_nbytes"]
    budget = max(1, enc // 4)
    print(f"\ntiered swap: v3 chunked artifact ({human_bytes(enc)} encoded "
          f"lists) staged at a 25% resident budget "
          f"({human_bytes(budget)})")
    service.stage("kb", artifact=path, resident_budget=budget)
    live = service.promote("kb")
    warmup(service, "kb", queries, DEFAULT_MENU, 64, 120.0)
    wl = build_workload(np.random.default_rng(3), duration_s=1.0,
                        rows_per_s=150.0, arrival="poisson",
                        menu=DEFAULT_MENU, pool_size=len(queries),
                        zipf_alpha=1.1)
    r = run_trial(service, "kb", queries, DEFAULT_MENU, wl)
    tier = service.stats()["indexes"]["kb"]["versions"][live]["tier"]
    print(f"  served {r['admitted']} open-loop requests "
          f"(p50={r['p50_ms']:.1f}ms p99={r['p99_ms']:.1f}ms, "
          f"{r['lost']} lost)")
    print(f"  tier: hit rate {tier['hit_rate']:.1%} "
          f"({tier['hits']} hits, {tier['misses']} misses), "
          f"{human_bytes(tier['bytes_resident'])} of "
          f"{human_bytes(tier['budget_bytes'])} hot tier resident, "
          f"{human_bytes(tier['bytes_read'])} paged from disk")


def serve_sharded(service, spec, docs, sample, queries, n_shards, batch, k):
    """Act four: the same KB sharded over the device mesh, hot-swapped in
    behind the same front door — identical results, per-shard rollup."""
    import jax
    n_dev = jax.device_count()
    if n_shards > n_dev:
        print(f"\nsharded swap skipped: --shards {n_shards} wants more "
              f"devices than available ({n_dev}) — run under "
              f"XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{n_shards}")
        return
    print(f"\nsharded swap: rebuilding over ShardSpec(shards={n_shards}) "
          f"({n_dev} devices attached)")
    import dataclasses
    sharded = build_index(
        dataclasses.replace(spec, shard=ShardSpec(shards=n_shards)),
        docs, sample)
    before = service.query(queries[:batch],
                           QueryOptions(index="kb", k=k)).result(timeout=120)
    service.stage("kb", index=sharded)   # places every shard, or raises
    live = service.promote("kb")
    after = service.query(queries[:batch],
                          QueryOptions(index="kb", k=k)).result(timeout=120)
    same_ids = np.array_equal(before.ids, after.ids)
    same_bits = same_ids and before.scores.tobytes() == after.scores.tobytes()
    row = service.stats()["indexes"]["kb"]["versions"][live]
    # quantizer-tail pipelines (--no-post) are bit-identical in score
    # bytes too; post-CenterNorm specs score on the float decode path,
    # where ids still match but the last ulp may differ per shard shape
    verdict = "bit-identical" if same_bits else \
        "same top-k ids" if same_ids else "DIVERGED"
    print(f"  promoted v{live}: results vs single-host {verdict}")
    for s in row.get("shards", []):
        lists = f", {s['n_lists']} lists" if "n_lists" in s else ""
        print(f"    shard {s['shard']}: {s['n_docs']} docs{lists}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="pca_int8",
                    choices=("pca_int8", "pca_onebit", "onebit", "int8"))
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--n-docs", type=int, default=50_000)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--no-post", action="store_true",
                    help="skip post-quantization CenterNorm: storage stays "
                         "quantized and scoring runs the int8/1-bit kernels")
    ap.add_argument("--ivf-nlist", type=int, default=0,
                    help="build an IVF index with this many lists "
                         "(0 = exact search)")
    ap.add_argument("--ivf-nprobe", type=int, default=0,
                    help="default probe width (0 = nlist/2); every 4th "
                         "request overrides it per-request to nlist")
    ap.add_argument("--shards", type=int, default=0,
                    help="fourth act: hot-swap in the KB sharded over "
                         "this many devices (needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    args = ap.parse_args(argv)

    dim = 245 if args.method == "pca_onebit" else args.dim
    kb = make_dpr_like_kb(n_queries=max(64, args.requests * args.batch),
                          n_docs=args.n_docs)
    fresh = make_dpr_like_kb(n_queries=8, n_docs=max(64, args.n_docs // 20),
                             seed=1)

    ivf = None
    full_probe = None
    if args.ivf_nlist:
        nprobe = args.ivf_nprobe or max(1, args.ivf_nlist // 2)
        ivf = (args.ivf_nlist, nprobe)
        full_probe = args.ivf_nlist

    spec = IndexSpec(method=args.method, dim=dim, post=not args.no_post,
                     ivf=ivf)
    queries = np.asarray(kb.queries)
    k = args.k

    def build_artifact(docs, path, tag):
        idx = build_index(spec, docs, kb.queries[:512])
        idx.save(path)
        print(f"  {tag}: {len(idx)} docs, scorer {idx.scorer.name}, "
              f"artifact {human_bytes(os.path.getsize(path))}")
        return idx

    served = [0]

    def stream(service, lo, hi):
        """Submit requests [lo, hi); resolve async handles as they land."""
        handles = []
        for r in range(lo, hi):
            nprobe = full_probe if (full_probe and r % 4 == 3) else None
            off = (r * args.batch) % max(1, len(queries) - args.batch)
            handles.append(service.query(
                queries[off: off + args.batch],
                QueryOptions(index="kb", k=k, nprobe=nprobe)))
        for h in handles:
            h.result(timeout=120)
        served[0] += len(handles)

    print(f"building v1 index from spec [{args.method}"
          f"{', ivf=' + str(ivf) if ivf else ''}] ...")
    with tempfile.TemporaryDirectory() as tmp:
        path_v1 = os.path.join(tmp, "kb_v1.npz")
        path_v2 = os.path.join(tmp, "kb_v2.npz")
        build_artifact(kb.docs, path_v1, "v1")

        with RetrievalService(default_k=k,
                              max_batch=args.max_batch) as service:
            service.register("kb", artifact=path_v1)
            print("  service cold-started from the artifact "
                  "(no corpus, no re-fit)\n")

            half, three_q = args.requests // 2, (3 * args.requests) // 4
            stream(service, 0, half)

            # the nightly refresh: corpus grows, new artifact is staged off
            # the serving path, canaried on live traffic, then promoted
            print(f"refresh after {served[0]} requests: building v2 "
                  f"(+{len(fresh.docs)} new docs) while serving continues")
            docs_v2 = jnp.concatenate([kb.docs, fresh.docs], axis=0)
            idx_v2 = build_artifact(docs_v2, path_v2, "v2")
            service.stage("kb", artifact=path_v2, canary_every=2)
            stream(service, half, max(half + 1, three_q))
            canary = service.canary("kb")
            print(f"  canary: overlap {canary['overlap']:.3f} over "
                  f"{canary['batches']} sampled batches")
            live = service.promote("kb", min_overlap=0.5)
            print(f"  promoted v{live} (rollback(\"kb\") would undo)\n")
            stream(service, max(half + 1, three_q), args.requests)

            stats = service.stats()
            table = stats["indexes"]["kb"]
            print(f"served {stats['requests_served']} requests "
                  f"({stats['queries_served']} queries, "
                  f"{stats['batches_served']} micro-batches) across "
                  f"versions {sorted(table['versions'])}, "
                  f"live=v{table['live']}")
            print(f"  latency p50={stats['p50_ms']:.1f}ms "
                  f"p95={stats['p95_ms']:.1f}ms "
                  f"p99={stats['p99_ms']:.1f}ms  (CPU host)")
            print(f"  admission: {stats['pending_queries']} pending, "
                  f"{stats['requests_rejected']} rejected")

            if ivf:
                serve_tiered(service, idx_v2, tmp, queries)

            if args.shards:
                serve_sharded(service, spec, docs_v2, kb.queries[:512],
                              queries, args.shards, args.batch, k)


if __name__ == "__main__":
    sys.exit(main())
