"""Live KB churn against a serving index: add → drift → delete → compact.

    PYTHONPATH=src python examples/live_updates.py --requests 40
    PYTHONPATH=src python examples/live_updates.py --method pca_onebit

The production-churn scenario the static paper setup doesn't cover: a
compressed index built once (``IndexSpec(mutable=True)``) keeps serving
while documents arrive and disappear.  New docs are encoded through the
*frozen* fitted pipeline into delta segments and are searchable
immediately; deletes tombstone global doc ids and take effect on the
next query; the preprocessing-drift monitor watches the added docs'
mean/norm statistics against the pipeline's fitted centering stats, and
when the delta fraction (or drift) crosses the trigger the index is
compacted — folded into a fresh main artifact and hot-swapped through
the same stage → promote machinery as a nightly rebuild, without
pausing the request stream.
"""

import argparse
import sys

import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import IndexSpec, build_index
from repro.serve import QueryOptions, RetrievalService
from repro.utils import human_bytes


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="pca_int8",
                    choices=("pca_int8", "pca_onebit", "onebit", "int8"))
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--n-docs", type=int, default=20_000)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    dim = 245 if args.method == "pca_onebit" else args.dim
    kb = make_dpr_like_kb(n_queries=max(128, args.requests * args.batch),
                          n_docs=args.n_docs)
    fresh = make_dpr_like_kb(n_queries=8, n_docs=max(64, args.n_docs // 8),
                             seed=1)
    queries = np.asarray(kb.queries)

    spec = IndexSpec(method=args.method, dim=dim, post=False, mutable=True)
    print(f"building mutable index [{args.method}] over {args.n_docs} docs")
    index = build_index(spec, kb.docs, kb.queries[:512])
    print(f"  {len(index)} live docs, scorer {index.scorer.name}, "
          f"{human_bytes(index.nbytes)} storage\n")

    served = [0]

    def stream(service, n, tag, forbidden=()):
        handles = []
        for r in range(n):
            off = (served[0] + r) * args.batch % (len(queries) - args.batch)
            handles.append(service.query(
                queries[off: off + args.batch],
                QueryOptions(index="kb", k=args.k)))
        for h in handles:
            ids = set(np.asarray(h.result(timeout=120).ids).ravel().tolist())
            dead = ids & set(forbidden)
            if dead:
                raise SystemExit(f"{tag}: served deleted doc ids {dead}")
        served[0] += n
        print(f"  [{tag}] {n} requests served, none touched a deleted doc")

    quarter = max(1, args.requests // 4)
    with RetrievalService(default_k=args.k) as service:
        service.register("kb", index)
        stream(service, quarter, "steady state")

        # breaking news: new docs land in a delta segment, via the frozen
        # pipeline — searchable on the very next query
        rep = service.update("kb", add=np.asarray(fresh.docs))
        lo, hi = rep["gid_range"]
        print(f"added {rep['added']} docs as segment #{rep['segments']} "
              f"(global ids {lo}..{hi - 1}); "
              f"drift mean_shift={rep['drift']['mean_shift']:.3f}")
        stream(service, quarter, "post-add")

        # retractions: tombstone a slice of the new docs + some originals
        dead = [*range(lo, lo + 32), 0, 1, 2, 3]
        rep = service.update("kb", delete=dead)
        print(f"deleted {rep['deleted']} docs "
              f"({rep['tombstones']} tombstones, {rep['n_live']} live)")
        stream(service, quarter, "post-delete", forbidden=dead)

        # fold: segments + tombstones → fresh main, staged and promoted
        # under live traffic; global ids survive the swap
        trigger = rep["needs_compaction"]
        live = service.compact("kb")
        print(f"compacted into v{live} "
              f"(trigger fired: {trigger}) — zero downtime")
        stream(service, max(1, args.requests - 3 * quarter),
               "post-compact", forbidden=dead)

        stats = service.stats()
        table = stats["indexes"]["kb"]
        mut = table["versions"][table["live"]]["mutable"]
        print(f"\nserved {stats['requests_served']} requests across "
              f"versions {sorted(table['versions'])}, live=v{table['live']}")
        print(f"  updates={stats['updates_applied']} "
              f"compactions={stats['compactions_run']} "
              f"live_docs={mut['n_live']} segments={mut['segments']}")
        print(f"  latency p50={stats['p50_ms']:.1f}ms "
              f"p99={stats['p99_ms']:.1f}ms  (CPU host)")


if __name__ == "__main__":
    sys.exit(main())
