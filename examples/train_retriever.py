"""Train a two-tower retriever end-to-end, then compress its item index.

    PYTHONPATH=src python examples/train_retriever.py --steps 300
    PYTHONPATH=src python examples/train_retriever.py --size 100m --steps 200

Demonstrates the full training substrate: in-batch sampled-softmax training,
cosine LR schedule, grad clipping, checkpointing with resume, preemption
handling — then freezes the item tower, embeds a candidate corpus, and
compresses it with the paper's PCA+int8 pipeline, reporting recall@10
before/after compression (the end-to-end effect of the paper's technique on
a *trained* system, not just synthetic embeddings).
"""

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TwoTowerConfig
from repro.core import (CenterNorm, CompressionPipeline, Int8Quantizer, PCA)
from repro.models import layers as L
from repro.models import recsys as R
from repro.retrieval import CompressedIndex, topk_search
from repro.train import optimizer as O
from repro.train import trainer
from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import PreemptionHandler
from repro.utils import tree_num_params


SIZES = {
    # embed_dim, tower, user/item vocab — "100m" ≈ 1.0e8 params
    "small": TwoTowerConfig(embed_dim=32, tower_mlp=(128, 64, 32),
                            n_user_features=4, n_item_features=4,
                            user_vocab=20_000, item_vocab=40_000),
    "100m": TwoTowerConfig(embed_dim=256, tower_mlp=(1024, 512, 256),
                           n_user_features=8, n_item_features=8,
                           user_vocab=150_000, item_vocab=150_000),
}


N_CLUSTERS = 64


def make_world(rng, n_users=10_000, n_items=20_000):
    return (rng.integers(0, N_CLUSTERS, n_users),
            rng.integers(0, N_CLUSTERS, n_items))


def feature_ids(entities, cluster_of, n_features, vocab):
    """Feature 0 = cluster id (categorical signal, e.g. genre); the rest are
    id hashes (memorization capacity)."""
    cols = [cluster_of[entities]]
    for j in range(1, n_features):
        cols.append((entities * 31 + j * 7919) % (vocab - N_CLUSTERS)
                    + N_CLUSTERS)
    return np.stack(cols, axis=1)


def synthetic_interactions(rng, cfg, batch, user_cluster, item_cluster):
    """Clustered user→item preference structure (so training has signal)."""
    n_users, n_items = len(user_cluster), len(item_cluster)
    by_cluster = [np.where(item_cluster == c)[0] for c in range(N_CLUSTERS)]
    while True:
        users = rng.integers(0, n_users, batch)
        c = user_cluster[users]
        # positive item from the user's cluster
        items = np.array([by_cluster[ci][rng.integers(len(by_cluster[ci]))]
                          for ci in c])
        yield ({"user_ids": jnp.asarray(
                    feature_ids(users, user_cluster,
                                cfg.n_user_features, cfg.user_vocab),
                    jnp.int32),
                "item_ids": jnp.asarray(
                    feature_ids(items, item_cluster,
                                cfg.n_item_features, cfg.item_vocab),
                    jnp.int32)},
               users, items)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="small", choices=tuple(SIZES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_two_tower")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = SIZES[args.size]
    spec = R.two_tower_spec(cfg)
    tx = O.adamw(O.cosine_schedule(args.lr, 20, args.steps),
                 weight_decay=1e-4, max_grad_norm=1.0)
    state = trainer.init_state(
        jax.random.PRNGKey(0), lambda r: L.init_params(r, spec), tx)
    print(f"model: {tree_num_params(state['params']) / 1e6:.1f}M params")

    ck = Checkpointer(args.ckpt_dir, keep=2)
    if args.resume and ck.latest_step() is not None:
        state = ck.restore(state)
        print(f"resumed from step {int(state['step'])}")

    loss_fn = lambda p, b: R.two_tower_loss(p, b, cfg)
    step_fn = jax.jit(trainer.make_train_step(loss_fn, tx),
                      donate_argnums=(0,))
    handler = PreemptionHandler()

    rng = np.random.default_rng(0)
    user_cluster, item_cluster = make_world(rng)
    stream = synthetic_interactions(rng, cfg, args.batch, user_cluster,
                                    item_cluster)
    for i in range(int(state["step"]), args.steps):
        batch, _, _ = next(stream)
        state, metrics = step_fn(state, batch)
        if handler.should_stop():
            ck.save(state, i + 1, blocking=True)
            print(f"[preempted] checkpoint at step {i + 1}")
            return
        if (i + 1) % 50 == 0:
            print(f"step {i + 1}: loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.2f}")
            ck.save(state, i + 1)
    ck.wait()

    # ---- build + compress the candidate index from the trained item tower
    print("\nembedding 20k candidate items ...")
    n_items = len(item_cluster)
    all_item_ids = feature_ids(np.arange(n_items), item_cluster,
                               cfg.n_item_features, cfg.item_vocab)
    item_emb = R.item_embedding(state["params"],
                                jnp.asarray(all_item_ids, jnp.int32), cfg)

    batch, users, items = next(stream)
    user_emb = R.user_embedding(state["params"], batch["user_ids"], cfg)

    def cluster_p10(top10):
        got = item_cluster[np.asarray(top10)]               # (B, 10)
        want = user_cluster[users][:, None]
        return float(np.mean(got == want))

    _, exact10 = topk_search(user_emb, item_emb, 10)
    exact_p = cluster_p10(exact10)
    print(f"uncompressed cluster-precision@10: {exact_p:.3f} "
          f"(chance {1 / N_CLUSTERS:.3f})")

    dim = min(cfg.embed_dim // 2, 128)
    pipe = CompressionPipeline([CenterNorm(), PCA(dim), CenterNorm(),
                                Int8Quantizer()])
    idx = CompressedIndex.build(item_emb, user_emb, pipe)
    _, comp10 = idx.search(user_emb, 10)
    comp_p = cluster_p10(comp10)
    ratio = (item_emb.size * 4) / idx.nbytes
    print(f"compressed  cluster-precision@10: {comp_p:.3f} at {ratio:.0f}x "
          f"smaller index "
          f"({100 * comp_p / max(exact_p, 1e-9):.0f}% retained)")


if __name__ == "__main__":
    sys.exit(main())
