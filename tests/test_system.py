"""End-to-end behaviour tests: the paper's full pipeline on synthetic data.

These assert the paper's HEADLINE CLAIMS hold qualitatively on our DPR-like
KB (exact values are data-dependent; EXPERIMENTS.md records the full grid):

  1. center+normalize ≥ raw, and equalizes IP vs L2      (§3.3, Table 5)
  2. PCA-128 ≈ 90–100% of uncompressed                   (§4.2)
  3. int8 ≈ 100%, 1-bit ≈ 85–95%                         (§4.4)
  4. PCA+int8 (24×) within a few % of PCA alone          (§4.5)
  5. random projections clearly worse than PCA           (§4.1)
  6. PCA needs very few fit samples                      (§5.1)
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CenterNorm, CompressionPipeline, build_method)
from repro.data import make_dpr_like_kb
from repro.retrieval import r_precision


@pytest.fixture(scope="module")
def kb():
    return make_dpr_like_kb(n_queries=300, n_docs=10_000)


@pytest.fixture(scope="module")
def baseline(kb):
    pipe = CompressionPipeline([CenterNorm()])
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    return r_precision(q, d, kb.relevant, sim="ip")


def _run(kb, method, dim=128, **kw):
    pipe = build_method(method, dim, **kw)
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    return r_precision(q, d, kb.relevant, sim="ip")


def test_preprocessing_helps_and_equalizes(kb, baseline):
    raw_ip = r_precision(kb.queries, kb.docs, kb.relevant, sim="ip")
    raw_l2 = r_precision(kb.queries, kb.docs, kb.relevant, sim="l2")
    assert raw_l2 < raw_ip                 # L2 collapses on raw DPR-like data
    assert baseline >= raw_ip - 0.02       # center+norm ≥ raw IP
    pipe = CompressionPipeline([CenterNorm()])
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    cn_l2 = r_precision(q, d, kb.relevant, sim="l2")
    assert cn_l2 == pytest.approx(baseline, abs=1e-6)  # normalized ⇒ same rank


def test_pca_retains_most_performance(kb, baseline):
    assert _run(kb, "pca") / baseline > 0.88


def test_precision_reduction(kb, baseline):
    assert _run(kb, "int8") / baseline > 0.97
    assert _run(kb, "fp16") / baseline > 0.99
    r1 = _run(kb, "onebit") / baseline
    assert 0.75 < r1 <= 1.0


def test_combined_pca_int8_24x(kb, baseline):
    combined = _run(kb, "pca_int8")
    pca_only = _run(kb, "pca")
    assert combined > pca_only - 0.04      # negligible extra loss (§4.5)


def test_random_projections_worse_than_pca(kb, baseline):
    gauss = _run(kb, "gaussian_projection")
    sparse = _run(kb, "sparse_projection")
    pca = _run(kb, "pca")
    assert gauss < pca and sparse < pca


def test_pca_needs_few_samples(kb, baseline):
    """§5.1: PCA fitted on 512 docs ≈ PCA fitted on everything."""
    small = _run(kb, "pca")
    from repro.core import PCA
    pipe = CompressionPipeline([CenterNorm(),
                                PCA(128, max_fit_samples=512), CenterNorm()])
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    few = r_precision(q, d, kb.relevant, sim="ip")
    assert few > small - 0.06


def test_compressed_serving_end_to_end(kb):
    """Production path: build compressed index, serve queries, compare ids
    against the uncompressed oracle."""
    from repro.core import Int8Quantizer, PCA
    from repro.retrieval import CompressedIndex, DenseIndex

    pipe = CompressionPipeline([CenterNorm(), PCA(128), CenterNorm(),
                                Int8Quantizer()])
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    _, got = idx.search(kb.queries[:64], 10)

    exact = DenseIndex(CenterNorm().fit(kb.docs, kb.queries)(kb.docs))
    q = CenterNorm().fit(kb.docs, kb.queries)(kb.queries[:64], "queries")
    _, want = exact.search(q, 10)
    overlap = np.mean([len(set(np.asarray(got)[i]) & set(np.asarray(want)[i]))
                       / 10 for i in range(64)])
    assert overlap > 0.5        # 24× smaller index, majority agreement
