"""replint: golden findings per pass on the fixture corpus, baseline
round-trip, VMEM report over the real kernels, and the runtime hooks
(retrace_guard on the IVF streaming hot path, LockSanitizer semantics).

The static passes are pure-AST: fixtures are parsed, never imported.
"""

import ast
import json
import sys
import threading
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "replint_fixtures"
sys.path.insert(0, str(REPO_ROOT))

from tools.repro_lint import (apply_baseline, check_locks, check_retrace,  # noqa: E402
                              check_tieorder, check_vmem, load_baseline,
                              write_baseline)
from tools.repro_lint.cli import main as replint_main, vmem_report  # noqa: E402
from tools.repro_lint.vmem import (KernelProfile, VMEM_LIMIT,  # noqa: E402
                                   estimate_file)
from tools.repro_lint.runtime import (LockSanitizer, RetraceError,  # noqa: E402
                                      retrace_guard)


def _parse(name: str) -> ast.Module:
    return ast.parse((FIXTURES / name).read_text())


def _rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# lock discipline
# ---------------------------------------------------------------------------


def test_locks_bad_fixture_golden():
    findings = check_locks(_parse("locks_bad.py"), "serve/locks_bad.py")
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)

    bare_reads = {f.qualname for f in by_rule.get("lock-bare-read", [])}
    assert "BadCounter.peek" in bare_reads
    bare_writes = {f.qualname for f in by_rule.get("lock-bare-write", [])}
    assert "BadCounter.reset" in bare_writes
    assert any(f.qualname == "BadCounter.slow_bump" and f.detail == "time.sleep"
               for f in by_rule.get("lock-blocking-call", []))
    assert any(f.detail == "_drop_locked"
               for f in by_rule.get("lock-helper-unlocked", []))
    assert len(by_rule.get("lock-order", [])) == 1


def test_locks_good_fixture_silent():
    assert check_locks(_parse("locks_good.py"), "serve/locks_good.py") == []


def test_locks_finding_keys_stable_across_line_shifts():
    src = (FIXTURES / "locks_bad.py").read_text()
    shifted = "# shifted\n# shifted\n" + src
    a = check_locks(ast.parse(src), "serve/locks_bad.py")
    b = check_locks(ast.parse(shifted), "serve/locks_bad.py")
    assert {f.key for f in a} == {f.key for f in b}


# ---------------------------------------------------------------------------
# retrace hazards
# ---------------------------------------------------------------------------


def test_retrace_bad_fixture_golden():
    findings = check_retrace(_parse("retrace_bad.py"), "core/retrace_bad.py")
    rules = _rules(findings)
    assert "retrace-in-loop" in rules
    assert any(f.rule == "retrace-self-capture" and f.detail == "scale"
               for f in findings)
    syncs = {f.detail for f in findings if f.rule == "retrace-host-sync"}
    assert {"float", "int", "item", "np.asarray"} <= syncs
    # the snapshot idiom must stay silent
    assert not any("good_builder" in f.qualname for f in findings)


def test_retrace_serve_path_forbids_jit_construction():
    findings = check_retrace(_parse("retrace_bad.py"),
                             "src/repro/serve/retrace_bad.py")
    assert any(f.rule == "retrace-in-serve" for f in findings)


# ---------------------------------------------------------------------------
# tie-order
# ---------------------------------------------------------------------------


def test_tieorder_bad_fixture_golden():
    findings = check_tieorder(_parse("tieorder_bad.py"),
                              "examples/tieorder_bad.py")
    quals = {f.qualname for f in findings if f.rule == "tieorder-raw-rank"}
    assert quals == {"rank_naive", "order_by_sim"}


def test_tieorder_strict_mode_reports_audit_sites():
    findings = check_tieorder(_parse("tieorder_bad.py"),
                              "examples/tieorder_bad.py", strict=True)
    audit = {f.qualname for f in findings
             if f.rule == "tieorder-raw-rank-audit"}
    assert "bucket_labels" in audit


def test_tieorder_whitelist_covers_topk_module():
    findings = check_tieorder(_parse("tieorder_bad.py"),
                              "src/repro/retrieval/topk.py", strict=True)
    assert findings == []


# ---------------------------------------------------------------------------
# VMEM budgets
# ---------------------------------------------------------------------------

BIG_PROFILE = [KernelProfile(
    "fixture", {},
    ["float32", "float32", "float32"],
    [(4096, 4096), (4096, 4096), (4096, 1024)],
)]


def test_vmem_oversized_fixture_fails_budget():
    tree = ast.parse((FIXTURES / "vmem_big" / "kernel.py").read_text())
    findings = check_vmem(tree, "kernels/vmem_big/kernel.py",
                          profiles=BIG_PROFILE)
    rules = _rules(findings)
    assert "vmem-budget" in rules
    assert "vmem-misaligned" in rules     # the (128, 100) output block
    ests = estimate_file(tree, "kernels/vmem_big/kernel.py", BIG_PROFILE)
    assert len(ests) == 1 and ests[0].total_bytes > VMEM_LIMIT
    assert not ests[0].ok


def test_vmem_report_covers_all_five_kernels_and_passes():
    report, ok = vmem_report(REPO_ROOT)
    assert ok, report
    for pkg in ("binary_ip", "int8_ip", "fused_quantize", "topk_blocks",
                "ivf_fused"):
        assert pkg in report, report
    # both fused-IVF storage variants are profiled
    assert "ivf_fused[float]" in report and "ivf_fused[onebit]" in report


def test_vmem_real_kernels_within_budget():
    for f in sorted((REPO_ROOT / "src/repro/kernels").rglob("kernel.py")):
        rel = f.relative_to(REPO_ROOT).as_posix()
        findings = check_vmem(ast.parse(f.read_text()), rel)
        assert findings == [], [fi.render() for fi in findings]


# ---------------------------------------------------------------------------
# baseline round-trip + CLI
# ---------------------------------------------------------------------------


def test_baseline_round_trip(tmp_path):
    findings = check_locks(_parse("locks_bad.py"), "serve/locks_bad.py")
    assert findings
    path = tmp_path / "baseline.json"
    write_baseline(path, findings)
    baseline = load_baseline(path)
    assert len(baseline) == len({f.key for f in findings})

    # full suppression: nothing active, nothing stale
    res = apply_baseline(findings, baseline)
    assert res.active == [] and res.stale_keys == []
    assert len(res.suppressed) == len(findings)

    # fixing a violation strands its baseline entry -> stale (shrink-only)
    fixed = [f for f in findings if f.rule != "lock-bare-read"]
    res2 = apply_baseline(fixed, baseline)
    assert res2.stale_keys
    assert all("lock-bare-read" in k for k in res2.stale_keys)


def test_cli_repo_is_clean_with_empty_baseline(capsys):
    rc = replint_main(["src", "benchmarks", "examples",
                       "--baseline", "tools/repro_lint/baseline.json",
                       "--root", str(REPO_ROOT)])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert json.loads(
        (REPO_ROOT / "tools/repro_lint/baseline.json").read_text()) == {}


def test_cli_stale_baseline_entry_fails(tmp_path, capsys):
    stale = tmp_path / "baseline.json"
    stale.write_text(json.dumps({"lock-bare-read:gone.py:X.y:attr": "old"}))
    rc = replint_main(["src/repro/serve", "--baseline", str(stale),
                       "--root", str(REPO_ROOT)])
    out = capsys.readouterr().out
    assert rc == 1 and "stale" in out


# ---------------------------------------------------------------------------
# runtime: retrace_guard on the IVF streaming hot path
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_ivf():
    from repro.retrieval import IVFFlatIndex
    rng = np.random.default_rng(7)
    docs = jnp.asarray(rng.standard_normal((300, 32)), jnp.float32)
    return IVFFlatIndex(nlist=8, nprobe=4, kmeans_iters=3).fit(docs)


def test_retrace_guard_ivf_streaming_steady_state(small_ivf):
    rng = np.random.default_rng(8)
    qs = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    small_ivf.search(qs, 5)                      # warm-up: compiles here
    with retrace_guard(expected=0, what="IVF streaming search") as tally:
        for _ in range(4):
            small_ivf.search(qs, 5)              # steady state: cache hits
    assert tally.compiles == 0


def test_retrace_guard_fires_on_shape_churn(small_ivf):
    rng = np.random.default_rng(9)
    with pytest.raises(RetraceError):
        with retrace_guard(expected=0, what="shape churn"):
            # a never-before-seen query batch shape forces a fresh trace
            qs = jnp.asarray(rng.standard_normal((13, 32)), jnp.float32)
            small_ivf.search(qs, 5)


# ---------------------------------------------------------------------------
# runtime: LockSanitizer
# ---------------------------------------------------------------------------


class _Sleeper:
    def __init__(self):
        self._lock = threading.Lock()

    def bad(self):
        with self._lock:
            time.sleep(0.001)

    def good(self):
        with self._lock:
            pass
        time.sleep(0.001)


def test_lock_sanitizer_catches_sleep_under_lock():
    s = _Sleeper()
    san = LockSanitizer().wrap(s, "_lock")
    with san:
        s.bad()
    assert san.violations
    v = san.violations[0]
    assert v.kind == "blocking-call" and "time.sleep" in v.detail
    assert "_Sleeper._lock" in v.held
    with pytest.raises(AssertionError):
        san.assert_clean()


def test_lock_sanitizer_clean_path_and_restore():
    s = _Sleeper()
    san = LockSanitizer().wrap(s, "_lock")
    orig_sleep = time.sleep
    with san:
        s.good()
        assert time.sleep is not orig_sleep      # detector installed
    assert time.sleep is orig_sleep              # restored on exit
    san.assert_clean()


def test_lock_sanitizer_flags_conflicting_order():
    class Two:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

    t = Two()
    san = LockSanitizer().wrap(t, "_a", "_b")
    with san:
        with t._a:
            with t._b:
                pass
        with t._b:
            with t._a:
                pass
    assert any(v.kind == "lock-order" for v in san.violations)


def test_lock_sanitizer_reentrant_rlock():
    class R:
        def __init__(self):
            self._lock = threading.RLock()

    r = R()
    san = LockSanitizer().wrap(r, "_lock")
    with san:
        with r._lock:
            with r._lock:                         # reentrant: no violation
                assert san.held_locks() == ("R._lock",)
    san.assert_clean()


# ---------------------------------------------------------------------------
# regression pin: the representative lock-discipline fix (satellite 1)
# ---------------------------------------------------------------------------


def test_serve_tree_is_lock_discipline_clean():
    """Pins the PR-9 fixes: engine observe_depth snapshot, service close()
    thread handoff, stats() counter reads, router always-lock, limits
    _refill_locked.  Any regression re-introduces a finding here."""
    serve = REPO_ROOT / "src" / "repro" / "serve"
    all_findings = []
    for f in sorted(serve.glob("*.py")):
        rel = f.relative_to(REPO_ROOT).as_posix()
        all_findings += check_locks(ast.parse(f.read_text()), rel)
    assert all_findings == [], [fi.render() for fi in all_findings]


def test_engine_observe_depth_sees_rows_under_lock():
    """Representative case: the adaptive batcher's depth signal is the
    row count captured *inside* the queue lock, racing producers can't
    skew it mid-read (the pre-PR-9 code re-read `_inflight_rows` bare)."""
    from repro.retrieval import DenseIndex
    from repro.serve.batcher import AdaptiveBatcher
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(3)
    docs = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    batcher = AdaptiveBatcher(min_batch=4, max_batch=32)
    engine = ServeEngine(DenseIndex(docs), k=5, batcher=batcher)

    seen = []
    orig = batcher.observe_depth
    batcher.observe_depth = lambda rows: (seen.append(rows), orig(rows))[1]

    qs = np.asarray(rng.standard_normal((7, 16)), np.float32)
    engine.submit(qs)
    engine.submit(qs[:3])
    engine.drain()
    assert seen == [10]           # exactly the rows popped by this drain
