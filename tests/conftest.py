import os
import sys

# tests run with PYTHONPATH=src; this makes them work standalone too.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Keep smoke tests on 1 device — the dry-run (and only the dry-run) forces
# 512 host devices in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: k-means / IVF fit-heavy tests, excluded from the CI fast "
        "lane (-m 'not slow'); the full tier-1 run still includes them")
