import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SchNetConfig
from repro.models import gnn as G


CFG = SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=12, n_atom_types=10)


def _molecule_batch(rng, n_graphs=3, n_atoms=8, n_edges=20):
    n = n_graphs * n_atoms
    return {
        "positions": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "edge_index": jnp.asarray(rng.integers(0, n, (2, n_edges * n_graphs)),
                                  jnp.int32),
        "atom_types": jnp.asarray(rng.integers(0, 10, (n,)), jnp.int32),
        "graph_ids": jnp.repeat(jnp.arange(n_graphs), n_atoms),
        "targets": jnp.asarray(rng.standard_normal(n_graphs), jnp.float32),
    }


def test_graph_task_shapes_and_grads():
    rng = np.random.default_rng(0)
    batch = _molecule_batch(rng)
    params = G.init(jax.random.PRNGKey(0), CFG)
    out = G.forward(params, batch, CFG, n_graphs=3)
    assert out.shape == (3,)
    loss, _ = G.loss_fn(params, batch, CFG)
    g = jax.grad(lambda p: G.loss_fn(p, batch, CFG)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x)))
             for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_node_task():
    import dataclasses
    cfg = dataclasses.replace(CFG, task="node", d_feat_in=24, n_classes=5)
    rng = np.random.default_rng(1)
    n, e = 50, 200
    batch = {
        "features": jnp.asarray(rng.standard_normal((n, 24)), jnp.float32),
        "positions": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "edge_index": jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 5, (n,)), jnp.int32),
        "label_mask": jnp.ones((n,), jnp.float32),
    }
    params = G.init(jax.random.PRNGKey(0), cfg)
    out = G.forward(params, batch, cfg)
    assert out.shape == (n, 5)
    loss, _ = G.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


def test_message_passing_locality():
    """A node with no incoming edges keeps its embedding-derived state."""
    rng = np.random.default_rng(2)
    n = 10
    # all edges point into node 0; node 9 is isolated (self-loop on 0)
    edges = np.zeros((2, 5), np.int32)
    edges[0] = [1, 2, 3, 4, 5]
    batch = {
        "positions": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "edge_index": jnp.asarray(edges),
        "atom_types": jnp.zeros((n,), jnp.int32),
    }
    params = G.init(jax.random.PRNGKey(0), CFG)
    emb = G.node_embeddings(params, batch, CFG)
    # nodes 1..9 share atom type and receive no messages → identical
    np.testing.assert_allclose(np.asarray(emb[1]), np.asarray(emb[9]),
                               rtol=1e-4)
    # node 0 received messages → different
    assert float(jnp.abs(emb[0] - emb[9]).max()) > 1e-4


def test_rbf_expansion():
    d = jnp.asarray([0.0, 5.0, 10.0])
    rbf = G.rbf_expand(d, 20, 10.0)
    assert rbf.shape == (3, 20)
    # each distance activates the basis function centred at it
    assert int(jnp.argmax(rbf[0])) == 0
    assert int(jnp.argmax(rbf[2])) == 19


def test_edge_mask_zeroes_messages():
    rng = np.random.default_rng(3)
    batch = _molecule_batch(rng)
    params = G.init(jax.random.PRNGKey(0), CFG)
    batch_masked = dict(batch)
    batch_masked["edge_mask"] = jnp.zeros(
        (batch["edge_index"].shape[1],), jnp.float32)
    emb_masked = G.node_embeddings(params, batch_masked, CFG)
    # with all edges masked, embeddings equal the no-edge forward
    batch_none = dict(batch)
    batch_none["edge_index"] = jnp.zeros((2, batch["edge_index"].shape[1]),
                                         jnp.int32)
    batch_none["edge_mask"] = jnp.zeros_like(batch_masked["edge_mask"])
    emb_none = G.node_embeddings(params, batch_none, CFG)
    np.testing.assert_allclose(np.asarray(emb_masked), np.asarray(emb_none),
                               rtol=1e-4, atol=1e-5)
