"""Token-bucket rate limiting: buckets, priority lanes, service wiring."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.retrieval import IndexSpec, build_index
from repro.serve import QueueFull, RateLimited, RateLimiter, \
    RetrievalService, TokenBucket

D = 32


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(3)
    return {
        "docs": rng.standard_normal((300, D)).astype(np.float32),
        "queries": rng.standard_normal((64, D)).astype(np.float32),
    }


# ---------------------------------------------------------------------------
# TokenBucket
# ---------------------------------------------------------------------------


def test_bucket_burst_then_refill():
    clk = FakeClock()
    b = TokenBucket(rate=10.0, burst=5.0, clock=clk)
    assert b.try_acquire(5)                    # starts full: whole burst
    assert not b.try_acquire(1)                # empty now
    clk.advance(0.1)                           # +1 token
    assert b.try_acquire(1)
    assert not b.try_acquire(1)
    clk.advance(100.0)                         # refill caps at burst
    assert b.available == pytest.approx(5.0)
    assert not b.try_acquire(6)                # can never exceed burst
    assert b.try_acquire(5)


def test_bucket_all_or_nothing_and_refund():
    clk = FakeClock()
    b = TokenBucket(rate=1.0, burst=4.0, clock=clk)
    assert not b.try_acquire(5)                # too big: bucket untouched
    assert b.available == pytest.approx(4.0)
    assert b.try_acquire(3)
    b.refund(3)
    assert b.available == pytest.approx(4.0)
    b.refund(100)                              # refund never exceeds burst
    assert b.available == pytest.approx(4.0)


def test_bucket_validates():
    with pytest.raises(ValueError):
        TokenBucket(rate=0, burst=1)
    with pytest.raises(ValueError):
        TokenBucket(rate=1, burst=0)


# ---------------------------------------------------------------------------
# RateLimiter: lanes and the guaranteed-share contract
# ---------------------------------------------------------------------------


def test_unconfigured_index_is_unlimited():
    lim = RateLimiter()
    assert lim.allow("kb", "default", 10_000)
    assert "kb" not in lim
    assert lim.stats() == {}


def test_capped_lane_sheds_alone_uncapped_lane_keeps_share():
    """The core serving contract: a bulk lane capped at 30% shedding its
    overload must leave the interactive lane's budget untouched."""
    clk = FakeClock()
    lim = RateLimiter(clock=clk)
    lim.configure("kb", qps=100.0, burst=100.0, lanes={"bulk": 0.3})
    # bulk burns through its 30-row lane burst, then sheds...
    assert lim.allow("kb", "bulk", 30)
    assert not lim.allow("kb", "bulk", 10)
    # ...while interactive still has the rest of the shared budget: the
    # bulk lane's failed attempts took nothing from it (two-phase refund)
    assert lim.allow("kb", "interactive", 70)
    assert not lim.allow("kb", "interactive", 10)   # shared budget now dry
    st = lim.stats()["kb"]
    assert st["rows_allowed"] == 100
    assert st["rows_denied"] == 20
    assert st["denied_by_lane"] == {"bulk": 10, "interactive": 10}


def test_lane_denial_does_not_drain_shared_bucket():
    """When the *shared* bucket denies a capped lane, the lane tokens it
    took in phase one must be refunded — otherwise the failed attempt
    would eat the lane's future budget too."""
    clk = FakeClock()
    lim = RateLimiter(clock=clk)
    lim.configure("kb", qps=100.0, burst=10.0, lanes={"bulk": 1.0})
    assert lim.allow("kb", "bulk", 10)         # shared burst (10) now empty
    assert not lim.allow("kb", "bulk", 10)     # shared denies
    clk.advance(0.1)                           # +10 shared, +10 lane
    assert lim.allow("kb", "bulk", 10)         # lane was refunded: fits


def test_configure_replaces_policy_and_remove():
    lim = RateLimiter(clock=FakeClock())
    lim.configure("kb", qps=1.0, burst=1.0)
    assert not lim.allow("kb", "default", 5)
    lim.configure("kb", qps=100.0, burst=50.0)   # live replacement
    assert lim.allow("kb", "default", 5)
    assert lim.remove("kb")
    assert not lim.remove("kb")
    assert lim.allow("kb", "default", 10_000)    # unlimited again


def test_lane_fraction_validated():
    lim = RateLimiter(clock=FakeClock())
    with pytest.raises(ValueError, match="fraction"):
        lim.configure("kb", qps=10.0, lanes={"bulk": 1.5})
    with pytest.raises(ValueError, match="fraction"):
        lim.configure("kb", qps=10.0, lanes={"bulk": 0.0})


# ---------------------------------------------------------------------------
# service wiring
# ---------------------------------------------------------------------------


def test_service_sheds_rate_limited_before_admission(corpus):
    clk = FakeClock()
    idx = build_index(IndexSpec(method="dense"), jnp.asarray(corpus["docs"]))
    svc = RetrievalService(start=False, limiter=RateLimiter(clock=clk))
    svc.register("kb", idx)
    svc.set_rate_limit("kb", qps=10.0, burst=16.0, lanes={"bulk": 0.5})

    svc.query(corpus["queries"][:8], index="kb", lane="bulk")   # lane burst
    with pytest.raises(RateLimited):
        svc.query(corpus["queries"][:8], index="kb", lane="bulk")
    # RateLimited is a QueueFull: one except arm covers both shed paths
    with pytest.raises(QueueFull):
        svc.query(corpus["queries"][:8], index="kb", lane="bulk")
    # shed traffic must not occupy queue capacity
    assert svc.pending_queries == 8
    s = svc.stats()
    assert s["requests_rate_limited"] == 2
    assert s["requests_admitted"] == 1
    assert s["shed_rate"] == pytest.approx(2 / 3)
    assert s["limits"]["kb"]["rows_denied"] == 16
    svc.drain_once()
    svc.close()


def test_service_rate_limit_unknown_index(corpus):
    with RetrievalService(start=False) as svc:
        with pytest.raises(KeyError):
            svc.set_rate_limit("nope", qps=10.0)


def test_service_clear_rate_limit(corpus):
    clk = FakeClock()
    idx = build_index(IndexSpec(method="dense"), jnp.asarray(corpus["docs"]))
    svc = RetrievalService(start=False, limiter=RateLimiter(clock=clk))
    svc.register("kb", idx)
    svc.set_rate_limit("kb", qps=1.0, burst=1.0)
    with pytest.raises(RateLimited):
        svc.query(corpus["queries"][:8], index="kb")
    assert svc.clear_rate_limit("kb")
    svc.query(corpus["queries"][:8], index="kb")     # unlimited again
    svc.drain_once()
    svc.close()
