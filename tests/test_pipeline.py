import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (METHODS, CenterNorm, CompressionPipeline,
                        Int8Quantizer, PCA, build_method,
                        method_compression_ratio)
from repro.data import make_dpr_like_kb


@pytest.fixture(scope="module")
def kb():
    return make_dpr_like_kb(n_queries=64, n_docs=2000, d=128, r_eff=48)


def test_fit_threads_through_stages(kb):
    """Each stage must be fitted on its predecessors' output."""
    pipe = CompressionPipeline([CenterNorm(), PCA(16), CenterNorm()])
    pipe.fit(kb.docs, kb.queries)
    # the PCA mean must be ~0-mean data (post CenterNorm), i.e. small
    assert float(jnp.linalg.norm(pipe.transforms[1].state["mean"])) < 0.5


def test_fit_transform(kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(16)])
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    assert d.shape == (2000, 16) and q.shape == (64, 16)


def test_save_load_roundtrip(tmp_path, kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(16), CenterNorm(),
                                Int8Quantizer()])
    pipe.fit(kb.docs, kb.queries)
    path = str(tmp_path / "pipe.npz")
    pipe.save(path)
    pipe2 = CompressionPipeline([CenterNorm(), PCA(16), CenterNorm(),
                                 Int8Quantizer()]).load(path)
    np.testing.assert_allclose(np.asarray(pipe.transform(kb.docs)),
                               np.asarray(pipe2.transform(kb.docs)),
                               rtol=1e-6)


def test_registry_builds_every_method(kb):
    cheap = [m for m in METHODS
             if m not in ("greedy_dim_drop", "distance_learning",
                          "contrastive") and not m.startswith("ae_")]
    for name in cheap:
        pipe = build_method(name, dim=16)
        d, q = pipe.fit_transform(kb.docs, kb.queries)
        assert d.shape[0] == 2000
        assert not bool(jnp.any(jnp.isnan(jnp.asarray(d, jnp.float32))))


def test_method_ratios():
    assert method_compression_ratio("pca", 128) == pytest.approx(6.0)
    assert method_compression_ratio("pca_int8", 128) == pytest.approx(24.0)
    assert method_compression_ratio("onebit", 128) == pytest.approx(32.0)
    assert method_compression_ratio(
        "pca_onebit", 245) == pytest.approx(100.0, rel=0.01)
