"""Per-architecture smoke tests (deliverable f).

Every assigned architecture × input shape instantiates a REDUCED config of
the same family and runs one real step on CPU, asserting output shapes and
no NaNs.  The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_NAMES, ARCH_NAMES, get_arch
from repro.data import batches as B
from repro.launch.steps import build_step


def _finite(tree) -> bool:
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = jnp.asarray(leaf)
        if jnp.issubdtype(arr.dtype, jnp.floating) and bool(
                jnp.any(jnp.isnan(arr))):
            return False
    return True


CELLS = [(a, s.name) for a in ALL_NAMES for s in get_arch(a).shapes]


@pytest.mark.parametrize("arch_name,shape_name", CELLS,
                         ids=[f"{a}:{s}" for a, s in CELLS])
def test_cell_smoke(arch_name, shape_name):
    arch = get_arch(arch_name)
    shape = arch.shape(shape_name)
    bundle = build_step(arch, shape, mesh=None, rules=None, reduced=True)

    rng = np.random.default_rng(42)
    batch = B.make_batch(rng, arch, shape, reduced=True)

    # materialize state/params from the abstract structures: params get
    # small random values; optimizer state must be ZEROS (Adam's second
    # moment is a variance — random negatives would NaN under sqrt)
    def materialize(x, zeros=False):
        if isinstance(x, jax.ShapeDtypeStruct):
            if not zeros and jnp.issubdtype(x.dtype, jnp.floating):
                return (jax.random.normal(jax.random.PRNGKey(0), x.shape)
                        * 0.02).astype(x.dtype)
            return jnp.zeros(x.shape, x.dtype)
        return x

    args = []
    for a in bundle.abstract_args[:-1]:
        if isinstance(a, dict) and "opt" in a and "params" in a:
            args.append({
                "params": jax.tree_util.tree_map(materialize, a["params"]),
                "opt": jax.tree_util.tree_map(
                    lambda x: materialize(x, zeros=True), a["opt"]),
                "step": jnp.zeros((), jnp.int32),
            })
        else:
            args.append(jax.tree_util.tree_map(materialize, a))
    args.append(batch)

    out = bundle.jit()(*args)
    assert _finite(out), f"NaNs in {arch_name}:{shape_name}"

    # spot-check shapes for the main families
    if shape.kind == "lm_train":
        state, metrics = out
        assert float(metrics["loss"]) > 0
    elif shape.kind == "lm_decode":
        logits, cache = out
        model = arch.reduced
        dims = B.reduce_dims(shape)
        assert logits.shape == (dims["global_batch"], model.vocab_size)
    elif shape.kind == "retrieval_cand":
        vals, ids = out
        assert vals.shape[0] >= 1


def test_all_ten_archs_present():
    assert len(ARCH_NAMES) == 10
    assert len(CELLS) == 10 * 4 + 2     # 40 assigned + 2 paper-dpr cells
