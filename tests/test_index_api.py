"""The unified Index API: IndexSpec, build_index, and artifact persistence.

Acceptance contract (ISSUE 3): for every scorer backend and for an IVF
promotion, ``build_index(spec, docs, qs).save(p)`` then ``load_index(p)``
returns identical ``(scores, ids)`` to the original on a fixed query set,
with no access to the raw corpus at load time.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CenterNorm, CompressionPipeline, Int8Quantizer,
                        OneBitQuantizer, PCA)
from repro.retrieval import (CompressedIndex, DenseIndex, Index, IndexSpec,
                             IVFIndex, ShardSpec, ShardedCompressedIndex,
                             ShardedIVFIndex, build_index, load_index,
                             load_index_meta, resolve_k)

BACKEND_METHODS = {
    "float": "original",   # pipeline with no quantizer → float storage
    "fp16": "fp16",
    "int8": "int8",
    "onebit": "onebit",
}


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    docs = jnp.asarray(rng.standard_normal((600, 64)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    return docs, queries


def _assert_identical(a, b):
    va, ia = a
    vb, ib = b
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


# ---------------------------------------------------------------------------
# IndexSpec: validation and JSON round trip
# ---------------------------------------------------------------------------


def test_spec_requires_exactly_one_recipe():
    with pytest.raises(ValueError, match="exactly one"):
        IndexSpec()
    with pytest.raises(ValueError, match="exactly one"):
        IndexSpec(method="pca_int8", stages=(("PCA", {"dim": 8}),))


def test_spec_validation():
    with pytest.raises(ValueError, match="sim"):
        IndexSpec(method="int8", sim="cosine")
    with pytest.raises(ValueError, match="backend"):
        IndexSpec(method="int8", backend="gpu")
    with pytest.raises(ValueError, match="ivf"):
        IndexSpec(method="int8", ivf=(0, 4))


@pytest.mark.parametrize("spec", [
    IndexSpec(method="pca_int8", dim=64, sim="cos", backend="jnp"),
    IndexSpec(method="dense"),
    IndexSpec(method="onebit", ivf=(32, 8), kmeans_iters=9),
    IndexSpec(stages=(("CenterNorm", {}), ("PCA", {"dim": 16}),
                      ("Int8Quantizer", {})), backend="jnp"),
    IndexSpec(method="pca_int8", shard=ShardSpec(doc_axis=("pod", "model"),
                                                 query_axis="data")),
    IndexSpec(method="pca_int8", shard=ShardSpec(shards=4, replicas=2)),
])
def test_spec_json_roundtrip(spec):
    assert IndexSpec.from_json(spec.to_json()) == spec
    hash(spec)     # frozen specs stay hashable (usable as cache keys)


def test_shard_spec_old_json_defaults():
    # pre-placement-API JSON (no shards/replicas keys) loads with the
    # new fields defaulted, so old artifacts keep round-tripping
    old = ShardSpec.from_dict({"doc_axis": "model", "query_axis": None})
    assert old == ShardSpec()
    assert old.shards is None and old.replicas == 1


def test_spec_stage_list_ignores_dim_knobs(corpus):
    docs, queries = corpus
    spec = IndexSpec(stages=(("CenterNorm", {}), ("PCA", {"dim": 16})),
                     backend="jnp")
    idx = build_index(spec, docs, queries)
    assert idx.pipeline.transforms[1].dim == 16


# ---------------------------------------------------------------------------
# build_index: kind dispatch
# ---------------------------------------------------------------------------


def test_build_index_kinds(corpus):
    docs, queries = corpus
    assert isinstance(build_index(IndexSpec(method="dense"), docs),
                      DenseIndex)
    assert isinstance(
        build_index(IndexSpec(method="int8", backend="jnp"), docs, queries),
        CompressedIndex)
    idx = build_index(IndexSpec(method="int8", backend="jnp", ivf=(8, 4),
                                kmeans_iters=4), docs, queries)
    assert isinstance(idx, IVFIndex)
    assert (idx.nlist, idx.nprobe) == (8, 4)


def test_build_index_shard_derives_mesh(corpus):
    # the placement redesign: no mesh= needed — ShardSpec is the whole
    # placement surface and the mesh is derived from it
    docs, queries = corpus
    idx = build_index(IndexSpec(method="int8", backend="jnp",
                                shard=ShardSpec()), docs, queries)
    assert isinstance(idx, ShardedCompressedIndex)
    assert idx.mesh.devices.size == jax.device_count()


def test_build_index_mesh_kwarg_deprecated(corpus):
    docs, queries = corpus
    mesh = jax.make_mesh((jax.device_count(),), ("model",))
    with pytest.warns(DeprecationWarning, match="mesh"):
        idx = build_index(IndexSpec(method="int8", backend="jnp",
                                    shard=ShardSpec()), docs, queries,
                          mesh=mesh)
    assert isinstance(idx, ShardedCompressedIndex)


def test_shard_spec_replicas_must_divide_devices(corpus):
    docs, queries = corpus
    bad = jax.device_count() * 2 + 1
    with pytest.raises(ValueError, match="replicas"):
        build_index(IndexSpec(method="int8", backend="jnp",
                              shard=ShardSpec(replicas=bad)), docs, queries)


def test_all_classes_satisfy_protocol(corpus):
    docs, queries = corpus
    idx = build_index(IndexSpec(method="int8", backend="jnp"), docs, queries)
    assert isinstance(idx, Index)
    assert isinstance(build_index(IndexSpec(method="dense"), docs), Index)


# ---------------------------------------------------------------------------
# save/load round-trip parity (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend_name", sorted(BACKEND_METHODS))
def test_roundtrip_exact_backends(tmp_path, corpus, backend_name):
    docs, queries = corpus
    # post=False keeps the quantizer as the trailing stage, so storage is
    # genuinely fp16 / int8 codes / bit-packed words (not a float view)
    spec = IndexSpec(method=BACKEND_METHODS[backend_name], dim=32,
                     backend="jnp", post=False)
    idx = build_index(spec, docs, queries)
    if backend_name != "float":
        assert idx.scorer.name == backend_name
    before = idx.search(queries, 10)
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    idx2 = load_index(path)
    assert idx2.spec == spec
    assert len(idx2) == len(idx) and idx2.nbytes == idx.nbytes
    _assert_identical(before, idx2.search(queries, 10))


def test_roundtrip_dense(tmp_path, corpus):
    docs, queries = corpus
    idx = build_index(IndexSpec(method="dense"), docs)
    path = str(tmp_path / "dense.npz")
    idx.save(path)
    _assert_identical(idx.search(queries, 10),
                      DenseIndex.load(path).search(queries, 10))


def test_roundtrip_pca_recipes(tmp_path, corpus):
    docs, queries = corpus
    for method, dim in (("pca_int8", 32), ("pca_onebit", 37)):
        spec = IndexSpec(method=method, dim=dim, backend="jnp", post=False)
        idx = build_index(spec, docs, queries)
        path = str(tmp_path / f"{method}.npz")
        idx.save(path)
        _assert_identical(idx.search(queries, 10),
                          load_index(path).search(queries, 10))


@pytest.mark.slow
@pytest.mark.parametrize("backend_name", sorted(BACKEND_METHODS))
def test_roundtrip_ivf_backends(tmp_path, corpus, backend_name):
    docs, queries = corpus
    spec = IndexSpec(method=BACKEND_METHODS[backend_name], dim=32,
                     backend="jnp", post=False, ivf=(16, 8), kmeans_iters=6)
    idx = build_index(spec, docs, queries)
    if backend_name != "float":
        assert idx.scorer.name == backend_name
    before = idx.search(queries, 10)
    path = str(tmp_path / "ivf.npz")
    idx.save(path)
    idx2 = load_index(path)
    assert isinstance(idx2, IVFIndex)
    assert (idx2.nlist, idx2.nprobe) == (idx.nlist, idx.nprobe)
    _assert_identical(before, idx2.search(queries, 10))
    # per-call nprobe still works on the reloaded index, identically
    _assert_identical(idx.search(queries, 10, nprobe=16),
                      idx2.search(queries, 10, nprobe=16))


@pytest.mark.slow
def test_roundtrip_to_ivf_promotion(tmp_path, corpus):
    """A promoted index (shared storage, decode-routed) persists too."""
    docs, queries = corpus
    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5)])
    base = CompressedIndex.build(docs, queries, pipe, backend="jnp")
    ivf = base.to_ivf(nlist=16, nprobe=8, kmeans_iters=6)
    before = ivf.search(queries, 10)
    path = str(tmp_path / "promo.npz")
    ivf.save(path)
    ivf2 = IVFIndex.load(path)
    _assert_identical(before, ivf2.search(queries, 10))
    # the artifact owns its storage: mutating the original source index
    # must not poison the reloaded one
    base.add(docs[:8])
    _assert_identical(before, ivf2.search(queries, 10))


@pytest.mark.slow
def test_roundtrip_sharded(tmp_path, corpus):
    docs, queries = corpus
    spec = IndexSpec(method="pca_int8", dim=32, backend="jnp",
                     shard=ShardSpec())
    idx = build_index(spec, docs, queries)
    before = idx.search(queries, 10)
    path = str(tmp_path / "sharded.npz")
    idx.save(path)
    # a bare load_index derives the mesh from the spec saved in the
    # artifact — no mesh= (or even ShardSpec) required at load time
    idx2 = load_index(path)
    assert isinstance(idx2, ShardedCompressedIndex)
    _assert_identical(before, idx2.search(queries, 10))
    idx3 = ShardedCompressedIndex.load(path)
    _assert_identical(before, idx3.search(queries, 10))


@pytest.mark.slow
def test_roundtrip_sharded_ivf(tmp_path, corpus):
    docs, queries = corpus
    spec = IndexSpec(method="onebit", backend="jnp", ivf=(16, 8),
                     kmeans_iters=6, shard=ShardSpec())
    idx = build_index(spec, docs, queries)
    before = idx.search(queries, 10)
    path = str(tmp_path / "sivf.npz")
    idx.save(path)
    idx2 = load_index(path)
    assert isinstance(idx2, ShardedIVFIndex)
    _assert_identical(before, idx2.search(queries, 10))
    idx3 = ShardedIVFIndex.load(path)
    _assert_identical(before, idx3.search(queries, 10))


@pytest.mark.slow
def test_load_index_shard_wraps_single_host_artifact(tmp_path, corpus):
    # shard= at load time places a *single-host* artifact over the mesh:
    # the v3-artifact-plus-ShardSpec door into sharded serving
    docs, queries = corpus
    spec = IndexSpec(method="int8", backend="jnp", post=False)
    idx = build_index(spec, docs, queries)
    before = idx.search(queries, 10)
    path = str(tmp_path / "single.npz")
    idx.save(path)
    idx2 = load_index(path, shard=ShardSpec())
    assert isinstance(idx2, ShardedCompressedIndex)
    assert idx2.spec.shard == ShardSpec()
    _assert_identical(before, idx2.search(queries, 10))


def test_load_rejects_wrong_kind(tmp_path, corpus):
    docs, queries = corpus
    idx = build_index(IndexSpec(method="int8", backend="jnp"), docs, queries)
    path = str(tmp_path / "c.npz")
    idx.save(path)
    with pytest.raises(TypeError, match="CompressedIndex"):
        DenseIndex.load(path)


def test_load_rejects_foreign_npz(tmp_path):
    path = str(tmp_path / "foreign.npz")
    np.savez(path, x=np.zeros(3))
    with pytest.raises(ValueError, match="artifact"):
        load_index(path)


def test_load_index_meta_reads_identity_header(tmp_path, corpus):
    docs, queries = corpus
    spec = IndexSpec(method="int8", backend="jnp", post=False)
    idx = build_index(spec, docs, queries)
    path = str(tmp_path / "meta.npz")
    idx.save(path)
    meta = load_index_meta(path)
    assert meta["kind"] == "CompressedIndex"
    assert meta["n_docs"] == len(idx)
    assert meta["dim"] == int(docs.shape[1])
    assert IndexSpec.from_dict(meta["spec"]) == spec
    # the fingerprint is a stable identity: re-saving the same index
    # reproduces it, a different recipe does not
    idx.save(str(tmp_path / "meta2.npz"))
    assert load_index_meta(str(tmp_path / "meta2.npz"))["fingerprint"] == \
        meta["fingerprint"]
    idx2 = build_index(IndexSpec(method="fp16", backend="jnp", post=False),
                       docs, queries)
    idx2.save(str(tmp_path / "other.npz"))
    assert load_index_meta(str(tmp_path / "other.npz"))["fingerprint"] != \
        meta["fingerprint"]
    # non-artifact .npz files are refused without loading arrays
    np.savez(str(tmp_path / "junk.npz"), x=np.zeros(3))
    with pytest.raises(ValueError, match="artifact"):
        load_index_meta(str(tmp_path / "junk.npz"))


def test_save_empty_index_errors(tmp_path):
    pipe = CompressionPipeline([Int8Quantizer()])
    idx = CompressedIndex(pipe, backend="jnp")
    with pytest.raises(ValueError, match="empty"):
        idx.save(str(tmp_path / "e.npz"))


def test_engine_cold_start_from_artifact(tmp_path, corpus):
    from repro.serve import ServeEngine, load_engine
    docs, queries = corpus
    idx = build_index(IndexSpec(method="int8", backend="jnp"), docs, queries)
    want = np.asarray(idx.search(queries, 5)[1])
    path = str(tmp_path / "engine.npz")
    idx.save(path)
    # the one loader: load_engine is the supported cold-start adapter
    engine = load_engine(path, k=5)
    rid = engine.submit(np.asarray(queries))
    got = engine.drain()[rid].ids
    np.testing.assert_array_equal(got, want)
    # from_artifact survives as a thin alias, but it warns
    with pytest.warns(DeprecationWarning, match="from_artifact"):
        engine2 = ServeEngine.from_artifact(path, k=5)
    rid = engine2.submit(np.asarray(queries))
    np.testing.assert_array_equal(engine2.drain()[rid].ids, want)


# ---------------------------------------------------------------------------
# uniform k clamping (satellite: one guard for all five classes)
# ---------------------------------------------------------------------------


def _five_indexes(docs, queries):
    yield build_index(IndexSpec(method="dense"), docs)
    yield build_index(IndexSpec(method="int8", backend="jnp"), docs, queries)
    yield build_index(IndexSpec(method="int8", backend="jnp", ivf=(4, 4),
                                kmeans_iters=3), docs, queries)
    yield build_index(IndexSpec(method="int8", backend="jnp",
                                shard=ShardSpec()), docs, queries)
    yield build_index(IndexSpec(method="int8", backend="jnp", ivf=(4, 4),
                                kmeans_iters=3, shard=ShardSpec()),
                      docs, queries)


@pytest.mark.slow
def test_k_clamps_uniformly_across_all_five_classes():
    rng = np.random.default_rng(3)
    docs = jnp.asarray(rng.standard_normal((23, 64)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    for idx in _five_indexes(docs, queries):
        name = type(idx).__name__
        assert isinstance(idx, Index), name      # protocol, all five classes
        vals, ids = idx.search(queries, 100)     # k ≫ n_docs
        assert vals.shape == (4, 23), name
        assert ids.shape == (4, 23), name
        with pytest.raises(ValueError, match="k must be"):
            idx.search(queries, 0)
        with pytest.raises(ValueError, match="k must be"):
            idx.search(queries, -3)


def test_resolve_k_contract():
    assert resolve_k(5, 100) == 5
    assert resolve_k(100, 5) == 5
    with pytest.raises(ValueError):
        resolve_k(0, 10)


# ---------------------------------------------------------------------------
# pipeline load validation (satellite: no half-fitted stages)
# ---------------------------------------------------------------------------


def test_pipeline_load_rejects_incomplete_stage(tmp_path, corpus):
    docs, queries = corpus
    pipe = CompressionPipeline([CenterNorm(), PCA(8), Int8Quantizer()])
    pipe.fit(docs, queries)
    path = str(tmp_path / "p.npz")
    pipe.save(path)
    data = dict(np.load(path))
    del data["2:Int8Quantizer:zero"]
    bad = str(tmp_path / "bad.npz")
    np.savez(bad, **data)
    fresh = CompressionPipeline([CenterNorm(), PCA(8), Int8Quantizer()])
    with pytest.raises(ValueError, match="missing keys.*zero"):
        fresh.load(bad)


def test_pipeline_load_rejects_stage_type_mismatch(tmp_path, corpus):
    docs, queries = corpus
    pipe = CompressionPipeline([CenterNorm(), PCA(8)])
    pipe.fit(docs, queries)
    path = str(tmp_path / "p.npz")
    pipe.save(path)
    with pytest.raises(ValueError, match="mismatch"):
        CompressionPipeline([PCA(8), CenterNorm()]).load(path)


def test_pipeline_load_rejects_extra_stage_index(tmp_path, corpus):
    docs, queries = corpus
    pipe = CompressionPipeline([CenterNorm(), PCA(8)])
    pipe.fit(docs, queries)
    path = str(tmp_path / "p.npz")
    pipe.save(path)
    with pytest.raises(ValueError, match="stage index"):
        CompressionPipeline([CenterNorm()]).load(path)
