"""SegmentedIndex: live adds + tombstone deletes == a fresh build.

The acceptance bar: a SegmentedIndex with delta segments and tombstones
must return bit-identical rankings to a freshly built index over the
equivalent (surviving) corpus — per scorer backend, and under IVF with
any probe width — with global doc ids surviving compaction.
"""

import copy

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CenterNorm, CompressionPipeline, FloatCast,
                        Int8Quantizer, OneBitQuantizer, PCA)
from repro.retrieval import (IndexSpec, SegmentedIndex, build_index,
                             load_index, load_index_meta)
from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFIndex, build_padded_lists
from repro.retrieval.kmeans import assign
from repro.retrieval.scorers import apply_float_stages
from repro.retrieval.segments import DriftMonitor, fitted_center_mean

D = 48
K = 7
BACKEND_TAILS = {
    "float": [],
    "fp16": [FloatCast()],
    "int8": [Int8Quantizer()],
    "onebit": [OneBitQuantizer(0.5)],
}


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return {
        "docs": jnp.asarray(rng.standard_normal((300, D)), jnp.float32),
        "extra": jnp.asarray(rng.standard_normal((60, D)), jnp.float32),
        "queries": jnp.asarray(rng.standard_normal((12, D)), jnp.float32),
    }


DEAD = [3, 10, 11, 299, 305]      # three main rows, two delta rows


def fresh_over_surviving(pipe, all_docs, alive):
    """A fresh index over the surviving corpus, same fitted pipeline."""
    fresh = CompressedIndex(pipe, backend="jnp")
    fresh.add(all_docs[jnp.asarray(alive)])
    return fresh


# ---------------------------------------------------------------------------
# exact-search parity per scorer backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(BACKEND_TAILS))
def test_parity_with_fresh_build_per_backend(data, backend):
    pipe = CompressionPipeline([CenterNorm(), PCA(24)] +
                               copy.deepcopy(BACKEND_TAILS[backend]))
    main = CompressedIndex.build(data["docs"], data["queries"], pipe,
                                 backend="jnp")
    seg = SegmentedIndex(main)
    seg.add(data["extra"])
    assert seg.delete(DEAD) == len(DEAD)
    assert len(seg) == 360 - len(DEAD)

    all_docs = jnp.concatenate([data["docs"], data["extra"]], axis=0)
    alive = np.setdiff1d(np.arange(360), DEAD)
    fresh = fresh_over_surviving(pipe, all_docs, alive)
    fv, fi = fresh.search(data["queries"], K)
    sv, si = seg.search(data["queries"], K)
    # fresh ids are surviving-corpus positions; map them to global ids
    np.testing.assert_array_equal(alive[np.asarray(fi)], np.asarray(si))
    np.testing.assert_allclose(np.asarray(fv), np.asarray(sv),
                               rtol=1e-5, atol=1e-6)

    # compaction folds the layers but preserves rankings AND global ids
    comp = seg.compact()
    assert isinstance(comp, SegmentedIndex)
    assert len(comp) == len(seg)
    assert comp.n_segments == 0 and comp.n_deltas == 0
    cv, ci = comp.search(data["queries"], K)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ci))
    np.testing.assert_allclose(np.asarray(sv), np.asarray(cv),
                               rtol=1e-5, atol=1e-6)
    # the old index is untouched — compaction is copy-on-write
    sv2, si2 = seg.search(data["queries"], K)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(si2))


def test_dense_main_parity(data):
    seg = SegmentedIndex(DenseIndex(data["docs"]))
    seg.add(data["extra"])
    seg.delete(DEAD)
    all_docs = jnp.concatenate([data["docs"], data["extra"]], axis=0)
    alive = np.setdiff1d(np.arange(360), DEAD)
    fv, fi = DenseIndex(all_docs[jnp.asarray(alive)]).search(
        data["queries"], K)
    sv, si = seg.search(data["queries"], K)
    np.testing.assert_array_equal(alive[np.asarray(fi)], np.asarray(si))
    np.testing.assert_allclose(np.asarray(fv), np.asarray(sv),
                               rtol=1e-5, atol=1e-6)
    comp = seg.compact()
    cv, ci = comp.search(data["queries"], K)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ci))


# ---------------------------------------------------------------------------
# IVF parity: same centroids, delta rows obey the same probe reachability
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("nprobe", [2, 4, 16])
def test_ivf_parity_with_equivalent_index(data, nprobe):
    """Segmented IVF == one IVF index with the same centroids holding all
    surviving rows — for narrow, medium, and full probe widths."""
    pipe = CompressionPipeline([CenterNorm(), PCA(24), Int8Quantizer()])
    main = IVFIndex.build(data["docs"], data["queries"], pipe,
                          nlist=16, nprobe=4, backend="jnp",
                          kmeans_iters=4)
    seg = SegmentedIndex(main)
    seg.add(data["extra"])
    seg.delete(DEAD)

    # reference: one IVFIndex, identical centroids, all surviving rows
    ref = IVFIndex(pipe, nlist=16, nprobe=4, backend="jnp", kmeans_iters=4)
    ref.float_stages = main.float_stages
    ref.scorer = copy.deepcopy(main.scorer)
    all_docs = jnp.concatenate([data["docs"], data["extra"]], axis=0)
    alive = np.setdiff1d(np.arange(360), DEAD)
    x = apply_float_stages(main.float_stages, all_docs[jnp.asarray(alive)],
                           "docs")
    labels = np.asarray(assign(jnp.asarray(x, jnp.float32),
                               main.centroids))
    ref.storage = ref.scorer.encode_docs(x)
    ref.centroids = main.centroids
    ref.nlist = main.nlist
    ref._labels = labels
    ref.lists = jnp.asarray(build_padded_lists(labels, main.nlist))
    ref._n_docs = int(x.shape[0])
    ref._dim = int(x.shape[-1])
    ref._version = 1

    rv, ri = ref.search(data["queries"], K, nprobe=nprobe)
    sv, si = seg.search(data["queries"], K, nprobe=nprobe)
    ri = np.asarray(ri)
    want = np.where(ri >= 0, alive[np.maximum(ri, 0)], -1)
    np.testing.assert_array_equal(want, np.asarray(si))
    np.testing.assert_allclose(np.asarray(rv), np.asarray(sv),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_ivf_compaction_full_probe_matches_exact(data):
    """After compaction the router is refit, so ranking parity is checked
    at full probe width (== exact search over the surviving rows)."""
    spec = IndexSpec(method="pca_int8", dim=24, backend="jnp", post=False,
                     ivf=(12, 12), kmeans_iters=4, mutable=True)
    seg = build_index(spec, data["docs"], data["queries"])
    seg.add(data["extra"])
    seg.delete(DEAD)
    sv, si = seg.search(data["queries"], K, nprobe=12)
    comp = seg.compact()
    cv, ci = comp.search(data["queries"], K, nprobe=12)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ci))
    np.testing.assert_allclose(np.asarray(sv), np.asarray(cv),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# delete semantics, id allocation, guard rails
# ---------------------------------------------------------------------------


def test_delete_validation_and_idempotence(data):
    seg = SegmentedIndex(DenseIndex(data["docs"]))
    assert seg.delete([5, 5, 7]) == 2
    assert seg.delete([5]) == 0                    # idempotent
    assert seg.delete([]) == 0
    with pytest.raises(KeyError):
        seg.delete([360])                          # never allocated
    with pytest.raises(KeyError):
        seg.delete([-1])
    assert len(seg) == 298
    assert seg.n_tombstoned == 2


def test_deleted_ids_stay_dead_after_compaction(data):
    seg = SegmentedIndex(DenseIndex(data["docs"]))
    seg.add(data["extra"])
    seg.delete([0, 350])
    comp = seg.compact()
    # replaying the delete log over the compacted index is a no-op
    assert comp.delete([0, 350]) == 0
    assert comp.next_gid == 360                    # allocator monotonic
    comp.add(data["extra"][:5])
    assert comp.next_gid == 365
    _, ids = comp.search(data["queries"], 360)
    got = set(np.asarray(ids).ravel().tolist())
    assert 0 not in got and 350 not in got
    assert 364 in got                              # fresh rows searchable


def test_add_validation_and_main_guard(data):
    main = DenseIndex(data["docs"])
    seg = SegmentedIndex(main)
    with pytest.raises(ValueError, match="n ≥ 1"):
        seg.add(data["extra"][:0])
    with pytest.raises(TypeError, match="cannot wrap"):
        SegmentedIndex(seg)
    pipe = CompressionPipeline([CenterNorm(), PCA(8), Int8Quantizer()])
    cmain = CompressedIndex.build(data["docs"], data["queries"], pipe,
                                  backend="jnp")
    cseg = SegmentedIndex(cmain)
    cmain.add(data["extra"])                       # out-of-band mutation
    with pytest.raises(ValueError, match="changed under"):
        cseg.search(data["queries"], K)


def test_all_docs_deleted(data):
    seg = SegmentedIndex(DenseIndex(data["docs"][:4]))
    seg.delete(range(4))
    assert len(seg) == 0
    with pytest.raises(ValueError, match="empty"):
        seg.compact()


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------


def test_drift_monitor_flags_shifted_additions(data):
    pipe = CompressionPipeline([CenterNorm(), PCA(16), Int8Quantizer()])
    main = CompressedIndex.build(data["docs"], data["queries"], pipe,
                                 backend="jnp")
    ref = fitted_center_mean(pipe)
    assert ref is not None and ref.shape == (D,)

    in_dist = SegmentedIndex(main)
    in_dist.add(data["extra"])                     # same distribution
    shifted = SegmentedIndex(main)
    shifted.add(data["extra"] + 8.0)               # way off the fitted mean
    assert shifted.drift.mean_shift > 5 * max(in_dist.drift.mean_shift,
                                              1e-6)
    assert shifted.needs_compaction()
    st = shifted.mutable_stats()
    assert st["drift"]["n_added"] == 60
    assert st["needs_compaction"]


def test_delta_fraction_triggers_compaction(data):
    seg = SegmentedIndex(DenseIndex(data["docs"][:64]),
                         max_delta_fraction=0.25)
    assert not seg.needs_compaction()
    seg.add(data["extra"])                         # 60/124 ≈ 0.48 > 0.25
    assert seg.needs_compaction()
    comp = seg.compact()
    assert not comp.needs_compaction()             # folded → trigger clears


def test_drift_monitor_empty_and_ref_free():
    m = DriftMonitor()
    assert m.mean_shift == 0.0
    assert np.isnan(m.stats()["mean_norm"])
    m.update(np.ones((4, 8)))
    assert m.stats()["n_added"] == 4
    assert m.mean_shift > 0                        # vs zero reference


# ---------------------------------------------------------------------------
# persistence: segments + tombstones + allocator round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    IndexSpec(method="pca_int8", dim=24, backend="jnp", post=False,
              mutable=True),
    IndexSpec(method="pca_onebit", dim=33, backend="jnp", post=False,
              mutable=True),
    IndexSpec(method="dense", mutable=True),
    pytest.param(IndexSpec(method="pca_int8", dim=24, backend="jnp",
                           post=False, ivf=(12, 5), kmeans_iters=4,
                           mutable=True), marks=pytest.mark.slow),
], ids=["pca_int8", "pca_onebit", "dense", "ivf"])
def test_segmented_artifact_round_trip(tmp_path, data, spec):
    seg = build_index(spec, data["docs"], data["queries"])
    assert isinstance(seg, SegmentedIndex)
    seg.add(data["extra"])
    seg.delete(DEAD)
    v0, i0 = seg.search(data["queries"], K)

    path = str(tmp_path / "kb.npz")
    seg.save(path)
    meta = load_index_meta(path)
    assert meta["kind"] == "SegmentedIndex"
    assert meta["mutable"] and meta["n_docs"] == 360 - len(DEAD)

    back = load_index(path)
    assert isinstance(back, SegmentedIndex)
    assert back.spec == spec
    assert back.next_gid == 360 and len(back) == 360 - len(DEAD)
    assert back.drift.n_added == 60
    v1, i1 = back.search(data["queries"], K)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1),
                               rtol=1e-6, atol=1e-7)

    # the loaded copy is still mutable: add → delete → compact → search
    back.add(data["extra"][:8])
    assert back.next_gid == 368
    back.delete([361])
    comp = back.compact()
    _, ci = comp.search(data["queries"], K)
    assert 361 not in set(np.asarray(ci).ravel().tolist())


def test_mutable_spec_composes_with_sharding(data):
    # mutable=True × shard= used to be rejected; the placement redesign
    # makes them compose — a SegmentedIndex over a sharded main, serving
    # identical results to the same spec unsharded
    from repro.retrieval import ShardSpec
    spec = IndexSpec(method="int8", backend="jnp", mutable=True,
                     shard=ShardSpec(shards=1))
    idx = build_index(spec, data["docs"], data["queries"])
    assert isinstance(idx, SegmentedIndex)
    plain = build_index(
        IndexSpec(method="int8", backend="jnp", mutable=True),
        data["docs"], data["queries"])
    idx.add(data["extra"])
    plain.add(data["extra"])
    vs, is_ = idx.search(data["queries"], K)
    vp, ip = plain.search(data["queries"], K)
    np.testing.assert_array_equal(np.asarray(is_), np.asarray(ip))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vp))


def test_topk_merge_helpers_shared():
    """The (score desc, id asc) merge lives in topk.py and is re-exported
    by ivf.py — one definition for exact, IVF, sharded, and segmented."""
    from repro.retrieval import ivf, topk
    assert ivf.masked_topk_by_id is topk.masked_topk_by_id
    assert ivf.topk_score_then_id is topk.topk_score_then_id
