"""Per-kernel allclose vs pure-jnp oracle, swept over shapes/dtypes.

All Pallas kernels run with ``interpret=True`` on CPU (the kernel body
executes in Python) — the same body lowers to Mosaic on TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import CenterNorm, CompressionPipeline, Int8Quantizer, PCA
from repro.core.quantization import pack_bits
from repro.kernels.binary_ip import ops as bops, ref as bref
from repro.kernels.binary_ip.kernel import binary_ip_pallas
from repro.kernels.fused_quantize import ops as fops, ref as fref
from repro.kernels.int8_ip import ops as iops, ref as iref
from repro.kernels.int8_ip.kernel import int8_ip_pallas
from repro.kernels.topk_blocks import ops as tops
from repro.kernels.topk_blocks.kernel import topk_blocks_pallas


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# binary_ip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q,d,dim,bq,bd", [
    (7, 33, 64, 8, 16),        # paddings in every axis
    (32, 128, 96, 16, 64),
    (1, 5, 32, 8, 8),          # single query / tiny corpus
    (64, 300, 256, 32, 128),
])
def test_binary_ip_shapes(q, d, dim, bq, bd):
    rng = np.random.default_rng(q * d)
    queries, docs = _rand(rng, q, dim), _rand(rng, d, dim)
    qp, dp = pack_bits(queries), pack_bits(docs)
    want = bref.binary_ip_scores_ref(qp, dp, dim, 0.5)
    got = bops.binary_ip_scores(queries, dp, dim, offset=0.5,
                                use_pallas=True, interpret=True,
                                block_q=bq, block_d=bd)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("offset", [0.5, 0.0, 0.25])
def test_binary_ip_offsets(offset):
    rng = np.random.default_rng(0)
    queries, docs = _rand(rng, 9, 64), _rand(rng, 40, 64)
    qp, dp = pack_bits(queries), pack_bits(docs)
    want = bref.binary_ip_scores_ref(qp, dp, 64, offset)
    for use_pallas in (False, True):
        got = bops.binary_ip_scores(queries, dp, 64, offset=offset,
                                    use_pallas=use_pallas, interpret=True,
                                    block_q=8, block_d=16)
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   atol=1e-5)


def test_binary_ip_packed_queries():
    rng = np.random.default_rng(1)
    queries, docs = _rand(rng, 5, 32), _rand(rng, 20, 32)
    qp, dp = pack_bits(queries), pack_bits(docs)
    got = bops.binary_ip_scores(qp, dp, 32, use_pallas=False)
    want = bref.binary_ip_scores_ref(qp, dp, 32, 0.5)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20), st.integers(1, 50), st.integers(1, 3),
       st.integers(0, 1000))
def test_binary_ip_property(q, d, words, seed):
    """Kernel == oracle for arbitrary shapes (d multiple of 32)."""
    rng = np.random.default_rng(seed)
    dim = words * 32
    queries, docs = _rand(rng, q, dim), _rand(rng, d, dim)
    dp = pack_bits(docs)
    want = bref.binary_ip_scores_ref(pack_bits(queries), dp, dim, 0.5)
    got = bops.binary_ip_scores(queries, dp, dim, use_pallas=True,
                                interpret=True, block_q=8, block_d=8)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# int8_ip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sim", ["ip", "l2"])
@pytest.mark.parametrize("q,d,dim", [(5, 37, 48), (16, 100, 64)])
def test_int8_scores(sim, q, d, dim):
    rng = np.random.default_rng(q + d)
    queries, docs = _rand(rng, q, dim), _rand(rng, d, dim)
    quant = Int8Quantizer().fit(docs)
    codes = quant.encode(docs)
    want = iref.int8_scores_ref(queries, codes, quant.state["scale"],
                                quant.state["zero"], sim)
    got = iops.int8_scores(queries, codes, quant.state["scale"],
                           quant.state["zero"], sim, use_pallas=True,
                           interpret=True, block_q=8, block_d=16)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.02 * scale)  # bf16 query path


def test_int8_ranking_preserved():
    """bf16 kernel scores must give the same top-k as the f32 oracle."""
    rng = np.random.default_rng(7)
    queries, docs = _rand(rng, 8, 64), _rand(rng, 200, 64)
    quant = Int8Quantizer().fit(docs)
    codes = quant.encode(docs)
    want = iref.int8_scores_ref(queries, codes, quant.state["scale"],
                                quant.state["zero"], "ip")
    got = iops.int8_scores(queries, codes, quant.state["scale"],
                           quant.state["zero"], "ip", use_pallas=True,
                           interpret=True, block_q=8, block_d=32)
    w10 = np.argsort(-np.asarray(want), 1)[:, :10]
    g10 = np.argsort(-np.asarray(got), 1)[:, :10]
    overlap = np.mean([len(set(a) & set(b)) / 10 for a, b in zip(w10, g10)])
    assert overlap > 0.95


# ---------------------------------------------------------------------------
# fused_quantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,dc,bn", [(50, 64, 16, 16), (257, 96, 32, 64)])
def test_fused_quantize_matches_ref_and_pipeline(n, d, dc, bn):
    rng = np.random.default_rng(n)
    docs, queries = _rand(rng, n, d), _rand(rng, max(n // 4, 2), d)
    pipe = CompressionPipeline([CenterNorm(), PCA(dc), CenterNorm(),
                                Int8Quantizer()])
    pipe.fit(docs, queries)
    want = fops.fused_quantize(docs, pipe, use_pallas=False)
    got = fops.fused_quantize(docs, pipe, use_pallas=True, interpret=True,
                              block_n=bn)
    diff = np.abs(np.asarray(want).astype(int) - np.asarray(got).astype(int))
    assert diff.max() <= 1 and (diff > 0).mean() < 0.01  # rounding boundary
    # ref == the actual 4-stage pipeline encode
    staged = pipe.transforms[3].encode(
        pipe.transforms[2](pipe.transforms[1](
            pipe.transforms[0](docs, "docs"), "docs"), "docs"), "docs")
    np.testing.assert_array_equal(np.asarray(want), np.asarray(staged))


def test_fused_quantize_rejects_wrong_pipeline():
    pipe = CompressionPipeline([CenterNorm()])
    with pytest.raises(ValueError):
        fops.params_from_pipeline(pipe)


# ---------------------------------------------------------------------------
# topk_blocks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q,d,k,bq,bd", [
    (10, 333, 7, 8, 64), (3, 50, 10, 4, 16), (33, 1000, 16, 16, 128),
])
def test_streaming_topk(q, d, k, bq, bd):
    rng = np.random.default_rng(q * d + k)
    scores = _rand(rng, q, d)
    wv, wi = tops.streaming_topk(scores, k, use_pallas=False)
    gv, gi = tops.streaming_topk(scores, k, use_pallas=True, interpret=True,
                                 block_q=bq, block_d=bd)
    np.testing.assert_allclose(np.asarray(wv), np.asarray(gv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(gi))


def test_streaming_topk_with_ties():
    scores = jnp.asarray(np.tile(np.arange(16)[::-1] // 2, (3, 1)),
                         jnp.float32)
    wv, wi = tops.streaming_topk(scores, 4, use_pallas=False)
    gv, gi = tops.streaming_topk(scores, 4, use_pallas=True, interpret=True,
                                 block_q=2, block_d=8)
    np.testing.assert_allclose(np.asarray(wv), np.asarray(gv))
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(gi))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(2, 200), st.integers(1, 12),
       st.integers(0, 999))
def test_streaming_topk_property(q, d, k, seed):
    rng = np.random.default_rng(seed)
    scores = _rand(rng, q, d)
    wv, _ = tops.streaming_topk(scores, k, use_pallas=False)
    gv, _ = tops.streaming_topk(scores, k, use_pallas=True, interpret=True,
                                block_q=4, block_d=32)
    np.testing.assert_allclose(np.asarray(wv), np.asarray(gv), rtol=1e-6)
