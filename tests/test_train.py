import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as O
from repro.train import trainer


def quad_loss(params, batch):
    loss = jnp.sum(jnp.square(params["w"] - 3.0))
    return loss, {"l": loss}


def test_adamw_converges_on_quadratic():
    tx = O.adamw(0.1)
    params = {"w": jnp.zeros((4,))}
    state = trainer.init_state(jax.random.PRNGKey(0), lambda _: params, tx)
    step = jax.jit(trainer.make_train_step(quad_loss, tx))
    for _ in range(200):
        state, metrics = step(state, {})
    np.testing.assert_allclose(np.asarray(state["params"]["w"]), 3.0,
                               atol=1e-2)
    assert int(state["step"]) == 200


def test_weight_decay_shrinks():
    tx = O.adamw(0.01, weight_decay=0.5)

    def zero_loss(params, batch):
        return jnp.sum(params["w"] * 0.0), {}

    params = {"w": jnp.ones((3, 3))}
    state = trainer.init_state(jax.random.PRNGKey(0), lambda _: params, tx)
    step = jax.jit(trainer.make_train_step(zero_loss, tx))
    for _ in range(20):
        state, _ = step(state, {})
    assert float(jnp.max(jnp.abs(state["params"]["w"]))) < 1.0


def test_clip_by_global_norm():
    clip = O.clip_by_global_norm(1.0)
    grads = {"a": jnp.full((10,), 100.0)}
    out, _ = clip.update(grads, (), None)
    assert float(O.global_norm(out)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((10,), 1e-3)}
    out, _ = clip.update(small, (), None)
    np.testing.assert_allclose(np.asarray(out["a"]), 1e-3, rtol=1e-5)


def test_schedules():
    s = O.cosine_schedule(1.0, warmup_steps=10, total_steps=110)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(110)) == pytest.approx(0.1, rel=1e-3)
    assert float(s(5)) == pytest.approx(0.5)


def test_microbatch_grads_equal_full_batch():
    """Accumulated microbatch grads == single-batch grads (linear loss)."""
    def loss(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean(jnp.square(pred - batch["y"]))
        return l, {}

    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}
    params = {"w": jnp.asarray(rng.standard_normal((4,)), jnp.float32)}
    tx = O.sgd(0.1)
    state = {"params": params, "opt": tx.init(params),
             "step": jnp.zeros((), jnp.int32)}

    s1, _ = jax.jit(trainer.make_train_step(loss, tx))(state, batch)
    # microbatches=4 averages per-micro losses; with MSE over equal-sized
    # micros the mean-of-means equals the full mean
    s4, _ = jax.jit(trainer.make_train_step(loss, tx, microbatches=4))(
        {"params": params, "opt": tx.init(params),
         "step": jnp.zeros((), jnp.int32)}, batch)
    np.testing.assert_allclose(np.asarray(s1["params"]["w"]),
                               np.asarray(s4["params"]["w"]), rtol=1e-5)
    s4u, _ = jax.jit(trainer.make_train_step(
        loss, tx, microbatches=4, unroll_microbatches=True))(
        {"params": params, "opt": tx.init(params),
         "step": jnp.zeros((), jnp.int32)}, batch)
    np.testing.assert_allclose(np.asarray(s4["params"]["w"]),
                               np.asarray(s4u["params"]["w"]), rtol=1e-6)


def test_l1_penalty():
    tx = O.chain(O.add_l1_penalty(0.5))
    grads = {"w": jnp.zeros((3,))}
    params = {"w": jnp.asarray([1.0, -2.0, 0.0])}
    out, _ = tx.update(grads, tx.init(params), params)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.5, -0.5, 0.0])
