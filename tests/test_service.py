"""RetrievalService: registry, async handles, admission, hot-swap,
live updates (add/delete/compact) against mutable indexes."""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.retrieval import IndexSpec, build_index, load_index
from repro.retrieval.index import DenseIndex
from repro.serve import (CanaryFailed, QueryOptions, QueueFull,
                         RetrievalService, ServiceClosed)
from tools.repro_lint.runtime import LockSanitizer

D = 32
K = 5


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    return {
        "docs1": rng.standard_normal((400, D)).astype(np.float32),
        "docs2": rng.standard_normal((400, D)).astype(np.float32),
        "queries": rng.standard_normal((64, D)).astype(np.float32),
    }


# one spec per scorer backend; post=False keeps storage genuinely quantized
BACKEND_SPECS = [
    ("float", IndexSpec(method="dense")),
    ("fp16", IndexSpec(method="fp16", backend="jnp", post=False)),
    ("int8", IndexSpec(method="int8", backend="jnp", post=False)),
    ("onebit", IndexSpec(method="onebit", backend="jnp", post=False)),
]


def make_artifacts(tmp_path, corpus, spec):
    paths = []
    for tag, docs in (("v1", corpus["docs1"]), ("v2", corpus["docs2"])):
        idx = build_index(spec, jnp.asarray(docs),
                          jnp.asarray(corpus["queries"]))
        p = str(tmp_path / f"{tag}.npz")
        idx.save(p)
        paths.append(p)
    return paths


def expected(path, queries, k=K):
    scores, ids = load_index(path).search(jnp.asarray(queries), k)
    return np.asarray(scores), np.asarray(ids)


# ---------------------------------------------------------------------------
# registry + async request API
# ---------------------------------------------------------------------------


def test_query_matches_direct_search(corpus):
    with RetrievalService() as svc:
        svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
        q = corpus["queries"][:9]
        handle = svc.query(q, QueryOptions(index="kb", k=K))
        res = handle.result(timeout=30)
        assert handle.done()
        _, want = DenseIndex(jnp.asarray(corpus["docs1"])).search(
            jnp.asarray(q), K)
        np.testing.assert_array_equal(res.ids, np.asarray(want))
        assert res.ids.shape == (9, K)
        assert res.latency_s >= 0


def test_query_kwargs_shorthand_and_option_validation(corpus):
    with RetrievalService() as svc:
        svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
        res = svc.query(corpus["queries"][0], index="kb", k=3).result(30)
        assert res.ids.shape == (1, 3)
        with pytest.raises(TypeError):
            svc.query(corpus["queries"][:2], QueryOptions(index="kb"), k=3)
        with pytest.raises(ValueError):
            QueryOptions(index="kb", k=0)
        with pytest.raises(ValueError):
            QueryOptions(nprobe=0)
        with pytest.raises(ValueError):
            svc.query(corpus["queries"][:0], index="kb")


def test_unknown_and_duplicate_index_names(corpus):
    with RetrievalService() as svc:
        svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
        with pytest.raises(KeyError, match="unknown index 'nope'"):
            svc.query(corpus["queries"][:2], index="nope")
        with pytest.raises(ValueError, match="already registered"):
            svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
        assert svc.indexes() == ["kb"]


def test_lazy_artifact_loads_on_first_query(tmp_path, corpus):
    p1, _ = make_artifacts(tmp_path, corpus,
                           IndexSpec(method="int8", backend="jnp",
                                     post=False))
    with RetrievalService() as svc:
        svc.register("kb", artifact=p1, lazy=True)
        row = svc.stats()["indexes"]["kb"]["versions"][1]
        assert not row["loaded"]
        assert row["kind"] == "CompressedIndex"       # header was read
        assert row["n_docs"] == 400
        res = svc.query(corpus["queries"][:4], index="kb", k=K).result(30)
        _, want = expected(p1, corpus["queries"][:4])
        np.testing.assert_array_equal(res.ids, want)
        assert svc.stats()["indexes"]["kb"]["versions"][1]["loaded"]


def test_admission_control_bounds_queue_depth(corpus):
    svc = RetrievalService(start=False, max_pending_queries=10)
    svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
    q = corpus["queries"]
    svc.query(q[:6], index="kb")
    svc.query(q[6:10], index="kb")                    # exactly at the bound
    with pytest.raises(QueueFull):
        svc.query(q[10:11], index="kb")
    assert svc.pending_queries == 10
    assert svc.requests_rejected == 1
    assert svc.drain_once() == 2                      # manual dispatch mode
    assert svc.pending_queries == 0
    svc.query(q[:1], index="kb")                      # space again
    svc.close()


def test_per_request_nprobe_routes_through_options(corpus):
    spec = IndexSpec(method="int8", backend="jnp", post=False, ivf=(16, 16),
                     kmeans_iters=4)
    idx = build_index(spec, jnp.asarray(corpus["docs1"]),
                      jnp.asarray(corpus["queries"]))
    q = corpus["queries"][:8]
    with RetrievalService() as svc:
        svc.register("kb", idx)
        wide = svc.query(q, QueryOptions(index="kb", k=K)).result(30)
        narrow = svc.query(q, QueryOptions(index="kb", k=K,
                                           nprobe=1)).result(30)
    _, want_wide = idx.search(jnp.asarray(q), K)
    _, want_narrow = idx.search(jnp.asarray(q), K, nprobe=1)
    np.testing.assert_array_equal(wide.ids, np.asarray(want_wide))
    np.testing.assert_array_equal(narrow.ids, np.asarray(want_narrow))


def test_close_fails_unresolved_handles_and_rejects_queries(corpus):
    svc = RetrievalService(start=False)
    svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
    h = svc.query(corpus["queries"][:3], index="kb")
    svc.close(drain=False)
    with pytest.raises(ServiceClosed):
        h.result(timeout=1)
    with pytest.raises(ServiceClosed):
        svc.query(corpus["queries"][:2], index="kb")
    assert svc.pending_queries == 0


def test_handle_timeout(corpus):
    svc = RetrievalService(start=False)           # nobody drains
    svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
    h = svc.query(corpus["queries"][:2], index="kb")
    with pytest.raises(TimeoutError):
        h.result(timeout=0.05)
    svc.close()                                   # drains, then resolves
    assert h.done()


# ---------------------------------------------------------------------------
# hot swap: stage / canary / promote / rollback
# ---------------------------------------------------------------------------


def test_stage_promote_rollback_lifecycle(tmp_path, corpus):
    p1, p2 = make_artifacts(tmp_path, corpus,
                            IndexSpec(method="int8", backend="jnp",
                                      post=False))
    q = corpus["queries"][:8]
    _, want1 = expected(p1, q)
    _, want2 = expected(p2, q)
    assert not np.array_equal(want1, want2)
    with RetrievalService() as svc:
        svc.register("kb", artifact=p1)
        with pytest.raises(ValueError, match="nothing staged"):
            svc.promote("kb")
        with pytest.raises(ValueError, match="no previous version"):
            svc.rollback("kb")
        v2 = svc.stage("kb", artifact=p2)
        # staged serves nothing until promote
        res = svc.query(q, index="kb", k=K).result(30)
        np.testing.assert_array_equal(res.ids, want1)
        assert svc.promote("kb") == v2
        res = svc.query(q, index="kb", k=K).result(30)
        np.testing.assert_array_equal(res.ids, want2)
        table = svc.stats()["indexes"]["kb"]
        assert (table["live"], table["staged"], table["previous"]) == \
            (v2, None, 1)
        assert svc.rollback("kb") == 1
        res = svc.query(q, index="kb", k=K).result(30)
        np.testing.assert_array_equal(res.ids, want1)


def test_restage_replaces_and_gcs_old_staged(tmp_path, corpus):
    p1, p2 = make_artifacts(tmp_path, corpus, IndexSpec(method="dense"))
    with RetrievalService() as svc:
        svc.register("kb", artifact=p1)
        first = svc.stage("kb", artifact=p2)
        second = svc.stage("kb", artifact=p2)
        assert second != first
        svc.query(corpus["queries"][:2], index="kb").result(30)
        svc.drain_once()                           # runs GC
        versions = svc.stats()["indexes"]["kb"]["versions"]
        assert first not in versions               # replaced staged GC'd
        assert set(versions) == {1, second}


def test_canary_gates_promote(tmp_path, corpus):
    spec = IndexSpec(method="int8", backend="jnp", post=False)
    p1, p2 = make_artifacts(tmp_path, corpus, spec)
    q = corpus["queries"]
    with RetrievalService() as svc:
        svc.register("kb", artifact=p1)
        # identical rebuild: canary overlap must be 1.0
        svc.stage("kb", artifact=p1, canary_every=1)
        with pytest.raises(CanaryFailed, match="no traffic"):
            svc.promote("kb", min_overlap=0.5)
        for i in range(4):
            svc.query(q[i * 8:(i + 1) * 8], index="kb", k=K).result(30)
        c = svc.canary("kb")
        assert c["batches"] >= 4
        assert c["overlap"] == pytest.approx(1.0)
        v2 = svc.promote("kb", min_overlap=0.99)
        # disjoint corpus: canary overlap ≈ 0 → the gate refuses to flip
        svc.stage("kb", artifact=p2, canary_every=1)
        for i in range(4):
            svc.query(q[i * 8:(i + 1) * 8], index="kb", k=K).result(30)
        assert svc.canary("kb")["overlap"] < 0.5
        with pytest.raises(CanaryFailed, match="overlap"):
            svc.promote("kb", min_overlap=0.9)
        # still staged — an explicit un-gated promote ships it anyway
        assert svc.stats()["indexes"]["kb"]["staged"] is not None
        assert svc.promote("kb") > v2
        assert svc.canary("kb") is None            # detached after promote


def test_rollback_detaches_canary(tmp_path, corpus):
    p1, p2 = make_artifacts(tmp_path, corpus, IndexSpec(method="dense"))
    with RetrievalService() as svc:
        svc.register("kb", artifact=p1)
        svc.stage("kb", artifact=p2)
        svc.promote("kb")                              # live v2, previous v1
        svc.stage("kb", artifact=p1, canary_every=1)   # canary on v2's engine
        assert svc.canary("kb") is not None
        svc.rollback("kb")                             # live back to v1
        # the canary measured against the rolled-away-from version: gone
        assert svc.canary("kb") is None
        with pytest.raises(ValueError, match="min_overlap"):
            svc.promote("kb", min_overlap=0.5)
        # the staged version itself survives; an un-gated promote ships it
        assert svc.stats()["indexes"]["kb"]["staged"] is not None
        svc.promote("kb")


def test_stats_survive_version_gc(tmp_path, corpus):
    """Counters from a hot-swapped-away version fold into the rollup when
    the version is GC'd — service totals never go backwards."""
    p1, p2 = make_artifacts(tmp_path, corpus, IndexSpec(method="dense"))
    with RetrievalService() as svc:
        svc.register("kb", artifact=p1)
        for i in range(3):
            svc.query(corpus["queries"][i * 4:(i + 1) * 4],
                      index="kb", k=K).result(30)
        svc.stage("kb", artifact=p2)
        svc.promote("kb")
        svc.stage("kb", artifact=p1)
        svc.promote("kb")                              # v1 is now retired
        svc.query(corpus["queries"][:4], index="kb", k=K).result(30)
        svc.drain_once()                               # runs GC
        s = svc.stats()
        assert 1 not in s["indexes"]["kb"]["versions"]
        assert s["indexes"]["kb"]["retired"]["requests_served"] == 3
        assert s["requests_served"] == 4               # GC'd work still counted
        assert s["queries_served"] == 16
        assert s["count"] >= 4                         # merged latency too


def test_stats_roll_up_across_indexes(corpus):
    with RetrievalService() as svc:
        svc.register("a", DenseIndex(jnp.asarray(corpus["docs1"])))
        svc.register("b", DenseIndex(jnp.asarray(corpus["docs2"])))
        for i in range(6):
            name = "a" if i % 2 == 0 else "b"
            svc.query(corpus["queries"][i * 4:(i + 1) * 4],
                      index=name, k=K).result(30)
        s = svc.stats()
        assert s["requests_served"] == 6
        assert s["queries_served"] == 24
        assert s["pending_queries"] == 0
        per_engine = [row for t in s["indexes"].values()
                      for row in t["versions"].values()]
        assert sum(r["requests_served"] for r in per_engine) == 6
        assert s["count"] == sum(r["count"] for r in per_engine)
        for key in ("p50_ms", "p95_ms", "p99_ms"):
            assert np.isfinite(s[key])


# ---------------------------------------------------------------------------
# live updates: update() / compact() on a mutable index
# ---------------------------------------------------------------------------


def make_mutable(corpus, **spec_kw):
    spec = IndexSpec(method="pca_int8", dim=16, backend="jnp", post=False,
                     mutable=True, **spec_kw)
    return build_index(spec, jnp.asarray(corpus["docs1"]),
                       jnp.asarray(corpus["queries"]))


def test_update_add_delete_and_stats_surface(corpus):
    with RetrievalService() as svc:
        svc.register("kb", make_mutable(corpus))
        rep = svc.update("kb", add=corpus["docs2"][:50], delete=[1, 2])
        assert (rep["added"], rep["deleted"]) == (50, 2)
        assert rep["gid_range"] == (400, 450)
        assert rep["n_live"] == 448
        res = svc.query(corpus["queries"], index="kb", k=K).result(30)
        got = set(np.asarray(res.ids).ravel().tolist())
        assert not got & {1, 2}
        row = svc.stats()["indexes"]["kb"]["versions"][1]
        assert row["mutable"]["n_live"] == 448
        assert row["mutable"]["segments"] == 1
        assert row["mutable"]["drift"]["n_added"] == 50
        assert svc.stats()["updates_applied"] == 1


def test_update_requires_mutable_index(corpus):
    with RetrievalService() as svc:
        svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
        with pytest.raises(TypeError, match="immutable"):
            svc.update("kb", add=corpus["docs2"][:4])
        with pytest.raises(ValueError, match="add= .*delete="):
            svc.update("kb")


def test_compact_preserves_rankings_and_global_ids(corpus):
    q = corpus["queries"][:8]
    with RetrievalService() as svc:
        svc.register("kb", make_mutable(corpus))
        svc.update("kb", add=corpus["docs2"][:50], delete=[0, 7, 410])
        before = svc.query(q, index="kb", k=K).result(30)
        live = svc.compact("kb")
        assert live == 2
        after = svc.query(q, index="kb", k=K).result(30)
        # exact backend: the fold changes nothing about the ranking, and
        # global ids mean the same documents across the swap
        np.testing.assert_array_equal(before.ids, after.ids)
        table = svc.stats()["indexes"]["kb"]
        assert table["live"] == 2 and table["previous"] == 1
        assert svc.stats()["compactions_run"] == 1
        # the compacted version is itself mutable: keep updating
        rep = svc.update("kb", delete=[449])
        assert rep["version"] == 2 and rep["deleted"] == 1


def test_update_is_atomic_on_bad_delete_ids(corpus):
    """A bad delete id must reject the whole update — the add half must
    not land (a retry would duplicate the docs)."""
    with RetrievalService() as svc:
        svc.register("kb", make_mutable(corpus))
        with pytest.raises(KeyError, match="unknown doc ids"):
            svc.update("kb", add=corpus["docs2"][:20], delete=[999_999])
        rep = svc.update("kb", add=corpus["docs2"][:4])
        assert rep["gid_range"] == (400, 404)      # nothing leaked earlier
        assert svc.stats()["updates_applied"] == 1


def test_updates_frozen_while_compacted_version_staged(corpus):
    """compact(promote=False) stages a snapshot of live; an update landing
    on the old live version would silently vanish at the flip, so the
    service must reject it until promote (or a replacement stage)."""
    with RetrievalService() as svc:
        svc.register("kb", make_mutable(corpus))
        svc.update("kb", add=corpus["docs2"][:20], delete=[5])
        svc.compact("kb", promote=False)
        with pytest.raises(RuntimeError, match="frozen"):
            svc.update("kb", delete=[6])
        with pytest.raises(RuntimeError, match="frozen"):
            svc.compact("kb")
        svc.promote("kb")
        rep = svc.update("kb", delete=[6])         # thawed after the flip
        assert rep["deleted"] == 1


def test_compact_with_canary_gate(corpus):
    q = corpus["queries"]
    with RetrievalService() as svc:
        svc.register("kb", make_mutable(corpus))
        svc.update("kb", add=corpus["docs2"][:30], delete=[3])
        staged = svc.compact("kb", canary_every=1, promote=False)
        assert svc.stats()["indexes"]["kb"]["staged"] == staged
        for i in range(4):
            svc.query(q[i * 8:(i + 1) * 8], index="kb", k=K).result(30)
        # identical rankings + identical global ids → overlap 1.0
        assert svc.canary("kb")["overlap"] == pytest.approx(1.0)
        assert svc.promote("kb", min_overlap=0.99) == staged


# ---------------------------------------------------------------------------
# the acceptance bar: hot swap under concurrent producer load
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,spec", BACKEND_SPECS,
                         ids=[b for b, _ in BACKEND_SPECS])
def test_hot_swap_parity_under_concurrent_load(tmp_path, corpus, backend,
                                               spec):
    """≥4 producer threads submit through a mid-traffic stage+promote:
    no request is lost or duplicated, every result ranks entirely against
    the pre- or post-promote version (never a mix), and post-promote
    rankings are bit-identical to a fresh load_index of the new artifact.
    """
    p1, p2 = make_artifacts(tmp_path, corpus, spec)
    queries = corpus["queries"]
    s1, want1 = expected(p1, queries)
    s2, want2 = expected(p2, queries)
    assert not np.array_equal(want1, want2)

    svc = RetrievalService(max_batch=32)
    svc.register("kb", artifact=p1)
    # Runtime lock-discipline monitor: the whole stress run executes under
    # the sanitizer and must finish without a single violation (the dynamic
    # complement of replint's static lock pass).
    san = LockSanitizer().wrap(svc, "_lock", "_admission", "_update_lock")
    n_threads, per_thread = 4, 25
    promote_done = threading.Event()
    outcomes: list[list] = [[] for _ in range(n_threads)]
    errors: list[Exception] = []

    def producer(t):
        rng = np.random.default_rng(100 + t)
        try:
            for _ in range(per_thread):
                off = int(rng.integers(0, 56))
                n = int(rng.integers(1, 9))
                post = promote_done.is_set()
                h = svc.query(queries[off:off + n],
                              QueryOptions(index="kb", k=K))
                res = h.result(timeout=60)
                outcomes[t].append((off, n, post, res))
        except Exception as e:                     # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    with san:
        for th in threads:
            th.start()
        svc.stage("kb", artifact=p2)               # load off the hot path
        svc.promote("kb")                          # atomic flip mid-traffic
        promote_done.set()
        for th in threads:
            th.join()
        # guaranteed post-promote traffic even if producers finished early
        final = svc.query(queries, QueryOptions(index="kb", k=K)).result(60)
        svc.close()
    san.assert_clean()

    assert not errors
    n_post = 0
    for per_thread_out in outcomes:
        assert len(per_thread_out) == per_thread   # resolved exactly once
        for off, n, post, res in per_thread_out:
            ids = np.asarray(res.ids)
            m1 = np.array_equal(ids, want1[off:off + n])
            m2 = np.array_equal(ids, want2[off:off + n])
            assert m1 or m2, f"{backend}: rankings match neither version"
            if post:
                n_post += 1
                assert m2, f"{backend}: post-promote request served v1"
    np.testing.assert_array_equal(np.asarray(final.ids), want2)
    np.testing.assert_array_equal(np.asarray(final.scores), s2)

    stats = svc.stats()
    total = n_threads * per_thread + 1
    assert stats["requests_served"] == total
    assert stats["pending_queries"] == 0
    assert stats["requests_rejected"] == 0


def test_mid_traffic_update_and_compaction(corpus):
    """≥4 producers stream queries through a live add → delete → compact
    cycle: no request is lost or duplicated, a query submitted after the
    delete never serves a deleted doc id, and post-compaction rankings are
    bit-identical to the pre-compaction ones (global ids preserved)."""
    deleted_ids = [2, 5, 17, 403, 427]             # main rows + added rows
    queries = corpus["queries"]
    svc = RetrievalService(max_batch=32)
    svc.register("kb", make_mutable(corpus))

    n_threads, per_thread = 4, 25
    deleted_done = threading.Event()
    outcomes: list[list] = [[] for _ in range(n_threads)]
    errors: list[Exception] = []

    def producer(t):
        rng = np.random.default_rng(200 + t)
        try:
            for _ in range(per_thread):
                off = int(rng.integers(0, 56))
                n = int(rng.integers(1, 9))
                post_delete = deleted_done.is_set()
                h = svc.query(queries[off:off + n],
                              QueryOptions(index="kb", k=K))
                outcomes[t].append((post_delete, h.result(timeout=60)))
        except Exception as e:                     # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    rep = svc.update("kb", add=corpus["docs2"][:40])
    assert rep["gid_range"] == (400, 440)
    svc.update("kb", delete=deleted_ids)
    deleted_done.set()
    live = svc.compact("kb")                       # fold + swap mid-traffic
    for th in threads:
        th.join()
    final = svc.query(queries, QueryOptions(index="kb", k=K)).result(60)
    stats = svc.stats()
    svc.close()

    assert not errors
    assert live == 2
    dead = set(deleted_ids)
    n_post = 0
    for per_thread_out in outcomes:
        assert len(per_thread_out) == per_thread   # resolved exactly once
        for post_delete, res in per_thread_out:
            if post_delete:
                n_post += 1
                got = set(np.asarray(res.ids).ravel().tolist())
                assert not got & dead, "served a deleted doc id"
    assert n_post > 0

    # post-compaction traffic: never a deleted id, and bit-identical to
    # searching the compacted index directly (global ids preserved)
    got = set(np.asarray(final.ids).ravel().tolist())
    assert not got & dead
    live_iv = stats["indexes"]["kb"]
    assert live_iv["live"] == live
    assert stats["requests_served"] == n_threads * per_thread + 1
    assert stats["pending_queries"] == 0
    assert stats["updates_applied"] == 2
    assert stats["compactions_run"] == 1
    row = live_iv["versions"][live]["mutable"]
    assert row["n_live"] == 400 + 40 - len(deleted_ids)
    assert row["segments"] == 0                    # folded


def test_mid_traffic_update_never_serves_stale_delete(corpus):
    """Direct-search oracle: after update() returns, a fresh query must
    rank exactly like an offline SegmentedIndex with the same history."""
    oracle = make_mutable(corpus)
    served = make_mutable(corpus)
    with RetrievalService() as svc:
        svc.register("kb", served)
        svc.update("kb", add=corpus["docs2"][:25], delete=[9, 12, 404])
        oracle.add(jnp.asarray(corpus["docs2"][:25]))
        oracle.delete([9, 12, 404])
        res = svc.query(corpus["queries"], index="kb", k=K).result(30)
        ov, oi = oracle.search(jnp.asarray(corpus["queries"]), K)
        np.testing.assert_array_equal(res.ids, np.asarray(oi))
        np.testing.assert_allclose(res.scores, np.asarray(ov),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# per-request nprobe through the front door on mutable (segmented) indexes
# ---------------------------------------------------------------------------


def make_mutable_ivf(corpus, **spec_kw):
    spec = IndexSpec(method="pca_int8", dim=16, backend="jnp", post=False,
                     ivf=(8, 4), mutable=True, **spec_kw)
    return build_index(spec, jnp.asarray(corpus["docs1"]),
                       jnp.asarray(corpus["queries"]))


def test_service_nprobe_on_mutable_ivf(corpus):
    """SegmentedIndex delegates its IVF main's probe width, so a
    per-request nprobe must flow through service.query exactly as it does
    on a bare IVF index — including after live updates and compaction."""
    q = corpus["queries"][:8]
    idx = make_mutable_ivf(corpus)
    with RetrievalService() as svc:
        svc.register("kb", idx)
        res = svc.query(q, index="kb", k=K, nprobe=8).result(30)
        want_s, want_i = idx.search(q, K, nprobe=8)
        np.testing.assert_array_equal(res.ids, np.asarray(want_i))
        # narrow probe is a genuinely different (approximate) answer
        narrow = svc.query(q, index="kb", k=K, nprobe=1).result(30)
        _, want_n = idx.search(q, K, nprobe=1)
        np.testing.assert_array_equal(narrow.ids, np.asarray(want_n))

        # survives live churn: delta segments + tombstones on the side
        svc.update("kb", add=corpus["docs2"][:30], delete=[2, 5])
        res = svc.query(q, index="kb", k=K, nprobe=8).result(30)
        _, want_u = idx.search(q, K, nprobe=8)
        np.testing.assert_array_equal(res.ids, np.asarray(want_u))

        # and compaction: the folded index is again IVF-backed
        svc.compact("kb")
        res = svc.query(q, index="kb", k=K, nprobe=8).result(30)


def test_service_nprobe_rejected_on_non_ivf_mutable(corpus):
    """A mutable index whose main is flat has no probe width: the
    override must be rejected at submit, not silently ignored."""
    spec = IndexSpec(method="pca_int8", dim=16, backend="jnp", post=False,
                     mutable=True)
    idx = build_index(spec, jnp.asarray(corpus["docs1"]),
                      jnp.asarray(corpus["queries"]))
    with RetrievalService() as svc:
        svc.register("kb", idx)
        with pytest.raises(ValueError, match="nprobe"):
            svc.query(corpus["queries"][:4], index="kb", nprobe=4)
        # the rejected request must not leak admission budget
        assert svc.pending_queries == 0


# ---------------------------------------------------------------------------
# admission control: exact at the bound under concurrent producers
# ---------------------------------------------------------------------------


def test_admission_exact_at_bound_under_contention(corpus):
    """The depth check and the counter bump are one atomic step: with the
    dispatcher stopped, N concurrent 1-row producers racing for a bound
    of B admit *exactly* B requests — never one past the bound, and never
    a rejection while room remains."""
    bound = 16
    svc = RetrievalService(start=False, max_pending_queries=bound)
    svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
    n_threads, per_thread = 8, 8            # 64 competing rows for 16 slots
    admitted, rejected = [], []
    gate = threading.Barrier(n_threads)

    def producer(t):
        gate.wait()
        for i in range(per_thread):
            try:
                h = svc.query(corpus["queries"][t: t + 1], index="kb")
                admitted.append(h)
            except QueueFull:
                rejected.append((t, i))

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(admitted) == bound               # exactly at the bound
    assert len(rejected) == n_threads * per_thread - bound
    assert svc.pending_queries == bound
    s = svc.stats()
    assert s["requests_admitted"] == bound
    assert s["requests_rejected"] == len(rejected)
    assert s["queue_high_water"] == bound

    # below the bound the service must never reject: drain, then refill
    assert svc.drain_once() == bound
    for h in admitted:
        h.result(timeout=30)
    for i in range(bound):                      # sequential: full room again
        svc.query(corpus["queries"][i: i + 1], index="kb")
    assert svc.pending_queries == bound
    svc.close()


def test_admission_multirow_blocks_never_split_the_bound(corpus):
    """A block either fits whole or is rejected whole — partial admission
    would strand rows."""
    svc = RetrievalService(start=False, max_pending_queries=10)
    svc.register("kb", DenseIndex(jnp.asarray(corpus["docs1"])))
    svc.query(corpus["queries"][:6], index="kb")        # 6 of 10
    with pytest.raises(QueueFull):
        svc.query(corpus["queries"][:5], index="kb")    # 11 would overflow
    assert svc.pending_queries == 6                      # untouched
    svc.query(corpus["queries"][:4], index="kb")        # exactly fills
    assert svc.pending_queries == 10
    svc.drain_once()
    svc.close()
