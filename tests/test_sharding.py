"""Sharding-rule unit tests (no devices needed: specs are pure metadata)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (AxisRules, MULTI_POD_RULES,
                                     SINGLE_POD_RULES, spec_for_shape)
from repro.train.elastic import plan_remesh


class FakeMesh:
    """Duck-typed mesh: spec_for_shape only reads .shape dict."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh(data=16, model=16)
POD_MESH = FakeMesh(pod=2, data=16, model=16)


def test_basic_mapping():
    spec = spec_for_shape((256, 4096), ("batch", None), SINGLE_POD_RULES,
                          MESH)
    assert spec == P("data")


def test_divisibility_guard_drops_axis():
    # 24 heads cannot shard over model=16 → replicated on that dim
    spec = spec_for_shape((3072, 24, 128), ("fsdp", "heads", None),
                          SINGLE_POD_RULES, MESH)
    assert spec == P("data")
    # 48 heads can
    spec = spec_for_shape((3072, 48, 128), ("fsdp", "heads", None),
                          SINGLE_POD_RULES, MESH)
    assert spec == P("data", "model")


def test_no_axis_reuse():
    # batch and fsdp both map to "data": second use must be dropped
    spec = spec_for_shape((256, 4096, 1024), ("batch", "fsdp", "ff"),
                          SINGLE_POD_RULES, MESH)
    assert spec == P("data", None, "model")


def test_multi_pod_tuple_axes():
    spec = spec_for_shape((256, 4096), ("batch", None), MULTI_POD_RULES,
                          POD_MESH)
    assert spec == P(("pod", "data"))


def test_tuple_axis_prefix_fallback():
    # 32 divides pod*data=32 fully; 16 only divides the prefix ("pod",)? No —
    # prefix shrinks from the right: ("pod","data") → ("pod",) = 2.
    spec = spec_for_shape((16, 8), ("batch", None), MULTI_POD_RULES, POD_MESH)
    assert spec in (P(("pod",)), P(("pod", "data")))
    size = 2 if spec == P(("pod",)) else 32
    assert 16 % size == 0


def test_rules_replace():
    r = SINGLE_POD_RULES.replace(kv_seq="model")
    assert r.get("kv_seq") == "model"
    assert SINGLE_POD_RULES.get("kv_seq") is None


def test_no_mesh_is_unsharded():
    assert spec_for_shape((8, 8), ("batch", None), SINGLE_POD_RULES,
                          None) == P()


# ---------------------------------------------------------------------------
# elastic re-mesh planning
# ---------------------------------------------------------------------------


def test_plan_remesh_preserves_model_axis():
    plan = plan_remesh({"data": 16, "model": 16}, n_devices=128)
    assert plan.new_shape == {"data": 8, "model": 16}
    assert plan.microbatch_scale == 2      # keep global batch via grad accum


def test_plan_remesh_shrinks_model_axis_if_needed():
    plan = plan_remesh({"data": 16, "model": 16}, n_devices=24)
    assert plan.new_shape["model"] * plan.new_shape["data"] <= 24
    assert 24 % plan.new_shape["model"] == 0


def test_plan_remesh_multi_pod_merge():
    plan = plan_remesh({"pod": 2, "data": 16, "model": 16}, n_devices=256)
    assert plan.new_shape == {"data": 16, "model": 16}
    assert plan.microbatch_scale == 2
