"""Optional-hypothesis shim: property tests skip cleanly when it is absent.

``from hypothesis import ...`` at module scope makes *collection* fail on
machines without the package, taking every non-property test in the module
down with it.  Import ``given / settings / st`` from here instead: with
hypothesis installed they are the real thing; without it, ``@given`` turns
the test into an individual skip and the rest of the module still runs.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import assume, given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - env dependent
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # plain zero-arg wrapper: pytest must not see the original
            # signature, or it would hunt for fixtures named like the
            # hypothesis-drawn parameters
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def assume(*_args, **_kwargs):
        return True

    class _AnyStrategy:
        """st.<anything>(...) placeholder; only consumed by the stub given."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAS_HYPOTHESIS", "assume", "given", "settings", "st"]
