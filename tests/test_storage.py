"""Tiered storage: chunked (v3) artifacts, ``ListStore`` tiers, bit-identity.

Acceptance contract (ISSUE 8): a ``MmapStore``-backed index must return
*bit-identical* results (ids AND float32 score bits) to the fully-resident
index at any byte budget, on every scorer backend, through
``SegmentedIndex`` deltas, and after ``compact()`` — tiering is a memory
knob, never a quality knob.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.retrieval import (ArtifactError, IndexSpec, MmapStore,
                             SegmentedIndex, build_index,
                             is_chunked_artifact, load_index,
                             load_index_meta, save_index)
from repro.storage import ChunkReader, ChunkWriter, npz_member_nbytes
from repro.storage.format import CHUNK_ALIGN, CHUNKS_NAME

# method → (IndexSpec kwargs) exercising all four scorer storage layouts.
# post=False matters for the quantized methods: the default post-quantizer
# CenterNorm would silently promote storage back to float32.
BACKENDS = {
    "float": dict(method="dense", dim=24),
    "fp16": dict(method="fp16", post=False),
    "int8": dict(method="pca_int8", dim=24, post=False),
    "onebit": dict(method="pca_rot_onebit", dim=32, post=False),
}

K = 10


def _spec(backend):
    return IndexSpec(ivf=(16, 6), backend="jnp", **BACKENDS[backend])


def _bits(scores):
    return np.asarray(scores, np.float32).view(np.uint32)


def _assert_bit_identical(a, b):
    (va, ia), (vb, ib) = a, b
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(_bits(va), _bits(vb))


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    docs = jnp.asarray(rng.standard_normal((500, 48)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((12, 48)), jnp.float32)
    extra = jnp.asarray(rng.standard_normal((60, 48)), jnp.float32)
    return docs, queries, extra


@pytest.fixture(scope="module")
def built(corpus):
    docs, _, _ = corpus
    return {b: build_index(_spec(b), docs) for b in BACKENDS}


# ---------------------------------------------------------------------------
# ChunkWriter / ChunkReader: the raw v3 container
# ---------------------------------------------------------------------------


def _write_toy(path, n_lists=5, width=12, seed=0):
    rng = np.random.default_rng(seed)
    w = ChunkWriter(path, storage_dtype=np.uint8, storage_width=width)
    lists = []
    for lid in range(n_lists):
        n = int(rng.integers(0, 9))
        rows = rng.integers(0, 255, size=(n, width)).astype(np.uint8)
        ids = rng.permutation(1000)[:n].astype(np.int32)
        w.write_list(rows, ids)
        lists.append((rows, ids))
    w.finish({"kind": "toy"}, {"aux": np.arange(7, dtype=np.float32)})
    return lists


def test_chunk_roundtrip_and_alignment(tmp_path):
    path = str(tmp_path / "toy.v3")
    lists = _write_toy(path)
    assert is_chunked_artifact(path)
    r = ChunkReader(path)
    assert r.n_lists == len(lists)
    for lid, (rows, ids) in enumerate(lists):
        got_rows, got_ids = r.read_list(lid)
        np.testing.assert_array_equal(got_rows, rows)
        np.testing.assert_array_equal(got_ids, ids)
        assert r.chunks[lid][0] % CHUNK_ALIGN == 0     # aligned offsets
    # iter_lists walks the same data in order
    for lid, rows, ids in r.iter_lists():
        np.testing.assert_array_equal(rows, lists[lid][0])
        np.testing.assert_array_equal(ids, lists[lid][1])
    with r.load_aux() as aux:
        np.testing.assert_array_equal(aux["aux"],
                                      np.arange(7, dtype=np.float32))
    r.close()


def test_chunk_writer_validates(tmp_path):
    path = str(tmp_path / "toy.v3")
    w = ChunkWriter(path, storage_dtype=np.uint8, storage_width=4)
    with pytest.raises(ValueError, match=r"\(n, 4\)"):
        w.write_list(np.zeros((2, 5), np.uint8), np.zeros(2, np.int32))
    with pytest.raises(ValueError, match="ids"):
        w.write_list(np.zeros((2, 4), np.uint8), np.zeros(3, np.int32))
    w.write_list(np.zeros((2, 4), np.uint8), np.arange(2, dtype=np.int32))
    w.finish({}, {})
    with pytest.raises(RuntimeError, match="twice"):
        w.finish({}, {})


def test_corrupted_chunk_names_list_id(tmp_path):
    path = str(tmp_path / "toy.v3")
    _write_toy(path, seed=3)
    r = ChunkReader(path)
    victim = next(lid for lid in range(r.n_lists)
                  if r.chunks[lid][1] > 0)         # a non-empty list
    off = r.chunks[victim][0]
    r.close()
    cpath = os.path.join(path, CHUNKS_NAME)
    with open(cpath, "r+b") as f:                  # flip one storage byte
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
    r2 = ChunkReader(path)
    with pytest.raises(ArtifactError, match=f"inverted list {victim}"):
        r2.read_list(victim)
    # verify=False skips the checksum — reads the (corrupt) bytes
    rows, _ = r2.read_list(victim, verify=False)
    assert rows.shape[1] == 12


def test_truncated_chunks_file(tmp_path):
    path = str(tmp_path / "toy.v3")
    _write_toy(path, seed=5)
    cpath = os.path.join(path, CHUNKS_NAME)
    with open(cpath, "r+b") as f:
        f.truncate(os.path.getsize(cpath) - CHUNK_ALIGN)
    with pytest.raises(ArtifactError, match="truncated"):
        ChunkReader(path).read_list(0)


def test_npz_member_nbytes(tmp_path):
    path = str(tmp_path / "toy.npz")
    arrays = {"a": np.arange(100, dtype=np.float32).reshape(10, 10),
              "b": np.zeros((3, 7), np.uint8),
              "c": np.arange(5, dtype=np.int64)}
    np.savez(path, **arrays)
    sizes = npz_member_nbytes(path)
    for name, arr in arrays.items():
        assert sizes[name] == arr.nbytes


# ---------------------------------------------------------------------------
# v3 artifacts through the Index API (IVF fits → slow lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_tiered_bit_identity_all_budgets(built, corpus, backend, tmp_path):
    """The acceptance bar: any budget, same bits as fully resident."""
    _, queries, _ = corpus
    idx = built[backend]
    path = str(tmp_path / "kb.v3")
    save_index(idx, path, chunked=True)
    enc = load_index_meta(path)["encoded_nbytes"]
    ref = idx.search(queries, K)
    full = load_index(path, resident="all")
    _assert_bit_identical(full.search(queries, K), ref)
    assert full.store is None
    for budget in (0, enc // 8, enc // 2, enc):
        tiered = load_index(path, resident=budget)
        assert tiered.store is not None
        assert tiered.storage is None
        _assert_bit_identical(tiered.search(queries, K), ref)
        # odd nprobe exercises the probe-padding path; k > probed pool
        # exercises the −inf/-1 fill
        _assert_bit_identical(tiered.search(queries, K, nprobe=5),
                              full.search(queries, K, nprobe=5))
        _assert_bit_identical(tiered.search(queries, 40, nprobe=3),
                              full.search(queries, 40, nprobe=3))


@pytest.mark.slow
def test_v3_resident_all_matches_npz_load(built, corpus, tmp_path):
    """resident='all' reproduces the v1 .npz load bit-for-bit."""
    _, queries, _ = corpus
    idx = built["int8"]
    p1 = str(tmp_path / "kb.npz")
    p3 = str(tmp_path / "kb.v3")
    save_index(idx, p1)
    save_index(idx, p3, chunked=True)
    a = load_index(p1)
    b = load_index(p3, resident="all")
    np.testing.assert_array_equal(np.asarray(a.storage),
                                  np.asarray(b.storage))
    np.testing.assert_array_equal(np.asarray(a.lists), np.asarray(b.lists))
    _assert_bit_identical(a.search(queries, K), b.search(queries, K))


@pytest.mark.slow
def test_v3_resave_is_stable(built, tmp_path):
    """store-backed → chunked save reproduces the chunk stream exactly."""
    idx = built["onebit"]
    p3 = str(tmp_path / "kb.v3")
    p3b = str(tmp_path / "kb2.v3")
    save_index(idx, p3, chunked=True)
    tiered = load_index(p3, resident=0)
    save_index(tiered, p3b, chunked=True)
    with open(os.path.join(p3, CHUNKS_NAME), "rb") as f:
        blob_a = f.read()
    with open(os.path.join(p3b, CHUNKS_NAME), "rb") as f:
        blob_b = f.read()
    assert blob_a == blob_b
    ra, rb = ChunkReader(p3), ChunkReader(p3b)
    assert ra.chunks == rb.chunks


@pytest.mark.slow
def test_store_backed_is_readonly(built, corpus, tmp_path):
    docs, _, _ = corpus
    idx = built["fp16"]
    p3 = str(tmp_path / "kb.v3")
    save_index(idx, p3, chunked=True)
    tiered = load_index(p3, resident=0)
    with pytest.raises(ValueError, match="read-only"):
        tiered.add(docs[:4])
    with pytest.raises(ValueError, match="chunked=True"):
        tiered.state_dict()
    with pytest.raises(ValueError, match="chunked=True"):
        save_index(tiered, str(tmp_path / "nope.npz"))


@pytest.mark.slow
def test_corrupted_artifact_raises_through_search(built, corpus, tmp_path):
    _, queries, _ = corpus
    idx = built["float"]
    p3 = str(tmp_path / "kb.v3")
    save_index(idx, p3, chunked=True)
    r = ChunkReader(p3)
    victim = max(range(r.n_lists), key=lambda lid: r.chunks[lid][1])
    off = r.chunks[victim][0]
    r.close()
    with open(os.path.join(p3, CHUNKS_NAME), "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
    tiered = load_index(p3, resident=0)
    with pytest.raises(ArtifactError, match=f"inverted list {victim}"):
        tiered.search(queries, K, nprobe=16)    # probe everything → hit it


# ---------------------------------------------------------------------------
# load_index_meta size accounting: v1 / v2 / v3
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_meta_sizes_v1(built, tmp_path):
    idx = built["int8"]
    p = str(tmp_path / "kb.npz")
    save_index(idx, p)
    meta = load_index_meta(p)
    sizes = npz_member_nbytes(p)
    assert meta["artifact_version"] == 1
    assert meta["encoded_nbytes"] == sizes["storage"]
    assert meta["aux_nbytes"] == sum(
        n for name, n in sizes.items()
        if name not in ("storage", "__meta__"))


@pytest.mark.slow
def test_meta_sizes_v2_segmented(built, corpus, tmp_path):
    _, _, extra = corpus
    seg = SegmentedIndex(built["int8"])
    seg.add(extra)
    p = str(tmp_path / "kb.npz")
    save_index(seg, p)
    meta = load_index_meta(p)
    sizes = npz_member_nbytes(p)
    stor = [n for n in sizes
            if n == "storage" or (n.startswith("seg:")
                                  and n.endswith(":storage"))]
    assert meta["artifact_version"] == 2
    assert meta["mutable"] is True
    assert meta["encoded_nbytes"] == sum(sizes[n] for n in stor)
    assert meta["aux_nbytes"] == sum(
        n for name, n in sizes.items()
        if name not in stor and name != "__meta__")


@pytest.mark.slow
def test_meta_sizes_v3(built, tmp_path):
    idx = built["int8"]
    p3 = str(tmp_path / "kb.v3")
    save_index(idx, p3, chunked=True)
    meta = load_index_meta(p3)
    r = ChunkReader(p3)
    assert meta["artifact_version"] == 3
    assert meta["encoded_nbytes"] == sum(c[1] for c in r.chunks)
    assert meta["encoded_nbytes"] == np.asarray(idx.storage).nbytes
    aux_sizes = npz_member_nbytes(os.path.join(p3, "aux.npz"))
    ids_nbytes = sum(c[2] for c in r.chunks)
    assert meta["aux_nbytes"] == sum(aux_sizes.values()) + ids_nbytes
    assert meta["n_docs"] == len(idx)
    r.close()


# ---------------------------------------------------------------------------
# MmapStore: hot-tier admission, eviction, pinning, counters
# ---------------------------------------------------------------------------


def _toy_reader(tmp_path, n_lists=6, width=16, rows_per=8):
    path = str(tmp_path / "store.v3")
    rng = np.random.default_rng(1)
    w = ChunkWriter(path, storage_dtype=np.uint8, storage_width=width)
    for lid in range(n_lists):
        rows = rng.integers(0, 255, (rows_per, width)).astype(np.uint8)
        w.write_list(rows, np.arange(rows_per, dtype=np.int32) + lid * 100)
        rows_per += 0
    w.finish({}, {})
    return ChunkReader(path)


def test_mmap_store_admission_and_counters(tmp_path):
    r = _toy_reader(tmp_path)
    per_list = r.list_nbytes(0)
    store = MmapStore(r, per_list * 2, admit_after=2)
    store.get(0)                       # miss, touch 1 → not admitted
    s = store.stats()
    assert (s["hits"], s["misses"], s["resident_lists"]) == (0, 1, 0)
    store.get(0)                       # miss, touch 2 → admitted
    assert store.stats()["resident_lists"] == 1
    store.get(0)                       # now a hit
    s = store.stats()
    assert s["hits"] == 1 and s["misses"] == 2
    assert 0 < s["hit_rate"] < 1
    assert s["bytes_resident"] <= s["budget_bytes"]


def test_mmap_store_eviction_respects_budget_and_pins(tmp_path):
    r = _toy_reader(tmp_path)
    per_list = r.list_nbytes(0)
    store = MmapStore(r, per_list * 2, admit_after=1)
    store.pin([5])                     # pinned lists admit on first touch
    store.get(5)
    assert store.stats()["resident_lists"] == 1
    for lid in range(4):               # LRU churn around the pin
        store.get(lid)
        assert store.stats()["bytes_resident"] <= per_list * 2
    s = store.stats()
    assert s["evictions"] > 0
    assert s["pinned_lists"] == 1
    before = s["bytes_read"]
    store.get(5)                       # the pin never left the hot tier
    assert store.stats()["bytes_read"] == before
    store.unpin([5])
    for lid in range(4):
        store.get(lid)
    store.get(5)                       # evictable now → re-read from disk
    assert store.stats()["bytes_read"] > before


def test_mmap_store_prefetch_and_zero_budget(tmp_path):
    r = _toy_reader(tmp_path)
    store = MmapStore(r, 0, admit_after=1)
    rows, ids = store.get(3)           # budget 0 → served straight off map
    np.testing.assert_array_equal(ids, np.arange(8, dtype=np.int32) + 300)
    assert store.stats()["resident_lists"] == 0
    assert not store.fully_resident
    big = MmapStore(_toy_reader(tmp_path / "b"), 1 << 20, admit_after=2)
    big.prefetch(range(big.n_lists))   # force-admits, ignores admit_after
    s = big.stats()
    assert s["resident_lists"] == big.n_lists
    assert big.fully_resident


@pytest.mark.slow
def test_index_prefetch_warms_hot_tier(built, corpus, tmp_path):
    _, queries, _ = corpus
    idx = built["float"]
    p3 = str(tmp_path / "kb.v3")
    save_index(idx, p3, chunked=True)
    enc = load_index_meta(p3)["encoded_nbytes"]
    tiered = load_index(p3, resident=enc)
    n = tiered.prefetch(queries)
    assert n > 0
    before = tiered.store.stats()["bytes_read"]
    _assert_bit_identical(tiered.search(queries, K),
                          idx.search(queries, K))
    s = tiered.store.stats()
    assert s["hits"] > 0
    assert s["bytes_read"] == before   # everything came from the hot tier


# ---------------------------------------------------------------------------
# SegmentedIndex over a tiered main: deltas, deletes, compaction
# ---------------------------------------------------------------------------


def _mutated(seg, extra):
    seg.add(extra[:40])
    seg.delete([3, 17, 180, 420, 510])
    seg.add(extra[40:])
    return seg


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["int8", "onebit"])
def test_segmented_over_tiered_main(built, corpus, backend, tmp_path):
    _, queries, extra = corpus
    p3 = str(tmp_path / "kb.v3")
    save_index(built[backend], p3, chunked=True)
    enc = load_index_meta(p3)["encoded_nbytes"]

    ref = _mutated(SegmentedIndex(load_index(p3, resident="all")), extra)
    seg = _mutated(SegmentedIndex(load_index(p3, resident=enc // 4)), extra)
    _assert_bit_identical(seg.search(queries, K), ref.search(queries, K))
    rv, ri = ref.search(queries, K)

    # in-memory compact folds the store-backed main without decoding.
    # Folding moves delta rows into the big lists matmul, so scores can
    # shift by ULPs vs the layered index (same contract as resident
    # compaction in test_segments) — ids must survive exactly.
    comp = seg.compact()
    assert comp.main.store is None
    cv, ci = comp.search(queries, K)
    np.testing.assert_allclose(np.asarray(cv), np.asarray(rv),
                               rtol=1e-5, atol=1e-6)
    if backend == "int8":
        # onebit's coarsely-tied hamming scores may break ties on the
        # folded positional order; fine-grained scores pin ids exactly
        np.testing.assert_array_equal(np.asarray(ci), np.asarray(ri))

    # chunked compact streams straight to a fresh v3 artifact; on the
    # folded artifact the tiered/resident bit-identity bar applies again
    out = str(tmp_path / "compacted.v3")
    comp2 = seg.compact(out_path=out, resident=enc // 4)
    assert is_chunked_artifact(out)
    assert comp2.main.store is not None
    # the folded artifact stores positional ids; comp2 wraps it with the
    # position → global-id map, so compare at the raw-IVF level
    again = load_index(out, resident="all")
    _assert_bit_identical(comp2.main.search(queries, K),
                          again.search(queries, K))
    # both compact flavours produce the same folded layout → same bits
    _assert_bit_identical(comp2.search(queries, K), (cv, ci))


@pytest.mark.slow
def test_segmented_v3_roundtrip_with_deltas(built, corpus, tmp_path):
    """save(chunked) of a segmented index keeps deltas + tombstones."""
    _, queries, extra = corpus
    seg = _mutated(SegmentedIndex(built["int8"]), extra)
    p3 = str(tmp_path / "seg.v3")
    save_index(seg, p3, chunked=True)
    meta = load_index_meta(p3)
    assert meta["artifact_version"] == 3 and meta["mutable"] is True
    for resident in ("all", 0):
        back = load_index(p3, resident=resident)
        assert isinstance(back, SegmentedIndex)
        assert len(back) == len(seg)
        _assert_bit_identical(back.search(queries, K),
                              seg.search(queries, K))


# ---------------------------------------------------------------------------
# Serving layer: resident_budget knob + tier gauges
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_service_resident_budget_and_tier_stats(built, corpus, tmp_path):
    from repro.serve.service import RetrievalService
    _, queries, _ = corpus
    p3 = str(tmp_path / "kb.v3")
    save_index(built["int8"], p3, chunked=True)
    enc = load_index_meta(p3)["encoded_nbytes"]
    with RetrievalService(max_batch=32) as svc:
        svc.register("kb", artifact=p3, resident_budget=enc // 4)
        r1 = svc.query(np.asarray(queries), index="kb").result()
        row = svc.stats()["indexes"]["kb"]["versions"][1]
        tier = row["tier"]
        assert tier["kind"] == "mmap"
        assert tier["budget_bytes"] == enc // 4
        assert tier["misses"] > 0
        assert tier["bytes_resident"] <= enc // 4
        # staging fully resident drops the tier gauges and keeps the bits
        svc.stage("kb", artifact=p3, resident_budget="all")
        svc.promote("kb")
        r2 = svc.query(np.asarray(queries), index="kb").result()
        np.testing.assert_array_equal(np.asarray(r1.ids),
                                      np.asarray(r2.ids))
        np.testing.assert_array_equal(_bits(r1.scores), _bits(r2.scores))
        assert "tier" not in svc.stats()["indexes"]["kb"]["versions"][2]


@pytest.mark.slow
def test_v3_manifest_is_json_inspectable(built, tmp_path):
    p3 = str(tmp_path / "kb.v3")
    save_index(built["float"], p3, chunked=True)
    with open(os.path.join(p3, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == 3
    assert manifest["n_lists"] == len(manifest["chunks"])
    assert manifest["meta"]["kind"] in ("IVFIndex", "IVFFlatIndex")
