import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.quantization import (FloatCast, Int8Quantizer,
                                     OneBitQuantizer, compression_ratio,
                                     pack_bits, unpack_bits)
from repro.core.pca import PCA
from repro.core.preprocess import CenterNorm


@pytest.fixture
def data():
    rng = np.random.default_rng(2)
    return jnp.asarray(rng.standard_normal((100, 64)), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(rows, words, seed):
    rng = np.random.default_rng(seed)
    d = words * 32
    x = rng.standard_normal((rows, d)).astype(np.float32)
    signs = unpack_bits(pack_bits(jnp.asarray(x)), d)
    np.testing.assert_array_equal(np.asarray(signs),
                                  np.where(x >= 0, 1, -1).astype(np.int8))


def test_pack_requires_mult32():
    with pytest.raises(ValueError):
        pack_bits(jnp.zeros((2, 31)))


def test_float_cast(data):
    t = FloatCast(jnp.float16).fit(data)
    enc = t.encode(data)
    assert enc.dtype == jnp.float16
    np.testing.assert_allclose(np.asarray(t(data)), np.asarray(data),
                               rtol=1e-3, atol=1e-3)
    assert t.bits_per_dim(32.0) == 16


def test_int8_bounds_and_error(data):
    t = Int8Quantizer().fit(data)
    enc = t.encode(data)
    assert enc.dtype == jnp.uint8
    err = np.abs(np.asarray(t(data)) - np.asarray(data))
    scale = np.asarray(t.state["scale"])
    assert np.all(err <= scale * 0.51 + 1e-6)   # ≤ half a quantization step


def test_onebit_offsets(data):
    for offset in (0.5, 0.0):
        t = OneBitQuantizer(offset=offset).fit(data)
        vals = np.unique(np.asarray(t(data)))
        assert set(vals) <= {1.0 - offset, -offset}


def test_onebit_encode_packs(data):
    t = OneBitQuantizer().fit(data)
    enc = t.encode(data)
    assert enc.dtype == jnp.uint32 and enc.shape == (100, 2)
    dec = t.decode(enc, d=64)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(t(data)))


def test_paper_compression_ratios():
    """Table 2 storage factors."""
    assert compression_ratio(768, [PCA(128)]) == pytest.approx(6.0)
    assert compression_ratio(768, [Int8Quantizer()]) == pytest.approx(4.0)
    assert compression_ratio(768, [FloatCast()]) == pytest.approx(2.0)
    assert compression_ratio(768, [OneBitQuantizer()]) == pytest.approx(32.0)
    assert compression_ratio(
        768, [PCA(128), Int8Quantizer()]) == pytest.approx(24.0)
    assert compression_ratio(
        768, [PCA(245), OneBitQuantizer()]) == pytest.approx(
            100.0, rel=0.01)


def test_onebit_offset_equivalence_after_centernorm(data):
    """Paper §4.4: offsets 0.5 and 0.0 are equivalent once post-processed."""
    t5 = OneBitQuantizer(0.5).fit(data)
    t0 = OneBitQuantizer(0.0).fit(data)
    post = CenterNorm()
    y5 = post.fit(t5(data))(t5(data))
    y0 = post.fit(t0(data))(t0(data))
    np.testing.assert_allclose(np.asarray(y5), np.asarray(y0), atol=1e-5)
