import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.preprocess import (Center, CenterNorm, Normalize,
                                   PreprocessSpec, ZScore, fit_apply)


@pytest.fixture
def data():
    rng = np.random.default_rng(0)
    docs = jnp.asarray(rng.standard_normal((200, 16)) + 3.0, jnp.float32)
    queries = jnp.asarray(rng.standard_normal((50, 16)) - 1.0, jnp.float32)
    return docs, queries


def test_center_separate_populations(data):
    docs, queries = data
    t = Center().fit(docs, queries)
    np.testing.assert_allclose(np.asarray(t(docs, "docs").mean(0)), 0,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(t(queries, "queries").mean(0)), 0,
                               atol=1e-5)
    # doc mean applied to queries would NOT center them
    assert abs(float(t(queries, "docs").mean())) > 0.5


def test_normalize_unit_rows(data):
    docs, _ = data
    y = Normalize().fit(docs)(docs)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=1)), 1.0,
                               rtol=1e-5)


def test_zscore(data):
    docs, queries = data
    t = ZScore().fit(docs, queries)
    y = t(docs, "docs")
    np.testing.assert_allclose(np.asarray(y.mean(0)), 0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y.std(0)), 1, atol=1e-3)


def test_center_norm_equals_composition(data):
    docs, queries = data
    fused = CenterNorm().fit(docs, queries)
    c = Center().fit(docs, queries)
    n = Normalize().fit(docs)
    np.testing.assert_allclose(np.asarray(fused(docs, "docs")),
                               np.asarray(n(c(docs, "docs"))), rtol=1e-5)


def test_preprocess_spec_modes(data):
    docs, queries = data
    for mode in ("none", "center", "norm", "center_norm", "zscore",
                 "zscore_norm"):
        ts = PreprocessSpec(mode).build()
        d, q = fit_apply(ts, docs, queries)
        assert d.shape == docs.shape and q.shape == queries.shape
        assert not bool(jnp.any(jnp.isnan(d)))
    with pytest.raises(ValueError):
        PreprocessSpec("bogus").build()


def test_state_dict_roundtrip(data):
    docs, queries = data
    t = CenterNorm().fit(docs, queries)
    t2 = CenterNorm().load_state(t.state_dict())
    np.testing.assert_allclose(np.asarray(t(docs, "docs")),
                               np.asarray(t2(docs, "docs")))
