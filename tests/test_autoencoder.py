import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autoencoder import (Autoencoder, AutoencoderConfig,
                                    init_autoencoder, reconstruction_loss)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(4)
    z = rng.standard_normal((400, 8)).astype(np.float32)
    mix = rng.standard_normal((8, 48)).astype(np.float32)
    return jnp.asarray(z @ mix)


@pytest.mark.parametrize("variant", ["linear", "full", "shallow_decoder"])
def test_variants_shapes(variant, data):
    ae = Autoencoder(AutoencoderConfig(variant=variant, bottleneck=8,
                                       epochs=2))
    ae.fit(data)
    assert ae(data).shape == (400, 8)
    assert ae.inverse(ae(data)).shape == (400, 48)


def test_loss_decreases(data):
    ae = Autoencoder(AutoencoderConfig(variant="linear", bottleneck=8,
                                       epochs=30, lr=3e-3))
    ae.fit(data)
    assert ae.loss_history[-1] < ae.loss_history[0] * 0.7


def test_linear_ae_recovers_low_rank(data):
    """8-dim latent data → 8-dim linear AE reconstructs near-perfectly."""
    ae = Autoencoder(AutoencoderConfig(variant="linear", bottleneck=8,
                                       epochs=200, lr=5e-3))
    ae.fit(data)
    rec = np.asarray(ae.inverse(ae(data)))
    x = np.asarray(data)
    rel = np.mean((rec - x) ** 2) / np.mean(x ** 2)
    assert rel < 0.1


def test_l1_regularization_shrinks_weights(data):
    cfg = dict(variant="linear", bottleneck=8, epochs=10, seed=1)
    plain = Autoencoder(AutoencoderConfig(**cfg)).fit(data)
    l1 = Autoencoder(AutoencoderConfig(l1=1e-2, **cfg)).fit(data)
    w_plain = float(jnp.mean(jnp.abs(plain.params["enc"][0]["w"])))
    w_l1 = float(jnp.mean(jnp.abs(l1.params["enc"][0]["w"])))
    assert w_l1 < w_plain


def test_state_roundtrip(data):
    ae = Autoencoder(AutoencoderConfig(variant="shallow_decoder",
                                       bottleneck=8, epochs=1))
    ae.fit(data)
    ae2 = Autoencoder(AutoencoderConfig(variant="shallow_decoder",
                                        bottleneck=8))
    ae2.load_state(ae.state_dict())
    np.testing.assert_allclose(np.asarray(ae(data)), np.asarray(ae2(data)))


def test_nondefault_input_dim():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 100)), jnp.float32)
    params = init_autoencoder(jax.random.PRNGKey(0), "full", 100, 16)
    loss = reconstruction_loss(params, x)
    assert np.isfinite(float(loss))
