"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="purely property-based module; needs hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CenterNorm, OneBitQuantizer, PCA
from repro.core.quantization import pack_bits, unpack_bits
from repro.retrieval.rprecision import r_precision_from_scores
from repro.retrieval.topk import merge_topk, similarity


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(2, 12), st.integers(0, 10_000))
def test_rprecision_bounded(n_docs, n_q, seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.standard_normal((n_q, n_docs)), jnp.float32)
    rel = rng.integers(0, n_docs, (n_q, 2)).astype(np.int32)
    rp = float(r_precision_from_scores(scores, jnp.asarray(rel)))
    assert 0.0 <= rp <= 1.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_rprecision_perfect_when_relevant_scores_highest(seed):
    rng = np.random.default_rng(seed)
    n_q, n_docs = 5, 40
    scores = jnp.asarray(rng.uniform(0, 1, (n_q, n_docs)), jnp.float32)
    rel = np.stack([np.arange(n_q) * 2, np.arange(n_q) * 2 + 1], 1)
    s = np.array(scores)          # writable copy
    for i in range(n_q):
        s[i, rel[i]] = 10.0 + rng.uniform(0, 1, 2)
    rp = float(r_precision_from_scores(jnp.asarray(s),
                                       jnp.asarray(rel.astype(np.int32))))
    assert rp == 1.0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(0, 10_000))
def test_ranking_invariant_under_positive_scaling(words, seed):
    """1-bit scoring: rankings are invariant to any per-call positive scale
    (the kernels may fold constants; rank order must not change)."""
    rng = np.random.default_rng(seed)
    d = words * 32
    q = jnp.asarray(rng.standard_normal((3, d)), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((17, d)), jnp.float32)
    s1 = similarity(q, docs, "ip")
    s2 = similarity(q * 3.7, docs, "ip")
    np.testing.assert_array_equal(np.asarray(jnp.argsort(-s1, 1)),
                                  np.asarray(jnp.argsort(-s2, 1)))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_merge_topk_equals_global_topk(k, seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.standard_normal((4, 40)), jnp.float32)
    idx = jnp.arange(40)[None].repeat(4, 0)
    va, ia = merge_topk(scores[:, :20], idx[:, :20],
                        scores[:, 20:], idx[:, 20:], k)
    want, _ = jax.lax.top_k(scores, k)
    np.testing.assert_allclose(np.asarray(va), np.asarray(want), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 30), st.integers(0, 10_000))
def test_pack_bits_involution(words, rows, seed):
    rng = np.random.default_rng(seed)
    d = words * 32
    x = rng.standard_normal((rows, d)).astype(np.float32)
    signs = unpack_bits(pack_bits(jnp.asarray(x)), d).astype(np.float32)
    repacked = pack_bits(jnp.asarray(signs))
    np.testing.assert_array_equal(np.asarray(pack_bits(jnp.asarray(x))),
                                  np.asarray(repacked))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_onebit_scoring_affine_in_sign_dot(seed):
    """IP of offset-encoded vectors is affine in the ±1 sign dot — the
    identity the binary kernel relies on (ops.py)."""
    rng = np.random.default_rng(seed)
    d = 64
    alpha = float(rng.uniform(0, 1))
    x = rng.standard_normal((5, d)).astype(np.float32)
    y = rng.standard_normal((7, d)).astype(np.float32)
    bx, by = (x >= 0).astype(np.float32), (y >= 0).astype(np.float32)
    vx, vy = bx - alpha, by - alpha
    want = vx @ vy.T
    sx, sy = np.where(x >= 0, 1.0, -1.0), np.where(y >= 0, 1.0, -1.0)
    c = 0.5 - alpha
    got = (0.25 * (sx @ sy.T)
           + (c / 2) * (sx.sum(1)[:, None] + sy.sum(1)[None, :])
           + d * c * c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000))
def test_pca_projection_is_isometry_on_components(seed):
    """PCA with orthonormal columns: ‖(x−μ)W‖ ≤ ‖x−μ‖, equality at full d."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((50, 12)), jnp.float32)
    full = PCA(12).fit(x)
    z = full(x)
    xc = np.asarray(x) - np.asarray(full.state["mean"])
    np.testing.assert_allclose(np.linalg.norm(np.asarray(z), axis=1),
                               np.linalg.norm(xc, axis=1), rtol=1e-4)
    part = PCA(4).fit(x)
    zp = np.asarray(part(x))
    assert np.all(np.linalg.norm(zp, axis=1)
                  <= np.linalg.norm(xc, axis=1) + 1e-4)
