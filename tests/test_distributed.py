"""Multi-device behaviour (8 forced host devices, subprocess-isolated so the
main test process keeps its single-device jax)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_search_matches_single_host():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.retrieval.sharded import make_distributed_search, shard_index
        from repro.retrieval.topk import topk_search

        rng = np.random.default_rng(0)
        docs = jnp.asarray(rng.standard_normal((512, 32)), jnp.float32)
        queries = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
        mesh = make_test_mesh(8, model=4)           # data=2, model=4
        search = make_distributed_search(mesh, k=10)
        docs_sharded = shard_index(docs, mesh, doc_axis="model")
        vals, idx = search(queries, docs_sharded)
        want_vals, want_idx = topk_search(queries, docs, 10)
        np.testing.assert_allclose(np.asarray(vals), np.asarray(want_vals),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_idx))
        print("SHARDED_SEARCH_OK")
    """)
    assert "SHARDED_SEARCH_OK" in out


def test_distributed_pca_matches_local():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.pca import PCA, fit_pca_distributed
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((800, 24)), jnp.float32)
        mesh = make_test_mesh(8, model=2)
        xs = jax.device_put(x, NamedSharding(mesh, P("data")))
        dist = fit_pca_distributed(xs, 6, mesh)
        local = PCA(6).fit(x)
        cos = np.abs(np.sum(np.asarray(dist.state["components"])
                            * np.asarray(local.state["components"]), axis=0))
        np.testing.assert_allclose(cos, 1.0, atol=1e-3)
        print("DIST_PCA_OK")
    """)
    assert "DIST_PCA_OK" in out


def test_compressed_grad_exchange_error_feedback():
    out = run_with_devices("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.compression_comm import (
            make_compressed_grad_exchange, init_residual)

        mesh = make_test_mesh(8, model=1)       # pure DP over "data"
        rng = np.random.default_rng(2)
        grads_steps = jnp.asarray(rng.standard_normal((20, 8, 64)),
                                  jnp.float32)   # (steps, shards, dim)

        def run(scheme):
            exchange = make_compressed_grad_exchange(scheme, "data")
            def one_host(gs):                       # gs (steps, 1, dim)
                res = jnp.zeros((64,))
                acc = jnp.zeros((64,))
                for t in range(20):
                    g = {"w": gs[t, 0]}
                    mean, res = exchange(g, res)
                    acc = acc + mean["w"]
                return acc[None]
            from repro.parallel.compat import shard_map
            fn = shard_map(one_host, mesh=mesh,
                           in_specs=P(None, "data", None),
                           out_specs=P("data", None))
            return np.asarray(fn(grads_steps))[0]

        exact = run("none")
        for scheme in ("int8", "onebit"):
            approx = run(scheme)
            # error feedback keeps the accumulated mean close to exact
            rel = np.linalg.norm(approx - exact) / np.linalg.norm(exact)
            print(scheme, "rel", rel)
            assert rel < (0.02 if scheme == "int8" else 0.35), (scheme, rel)
        print("COMPRESSED_COMM_OK")
    """)
    assert "COMPRESSED_COMM_OK" in out


def test_small_mesh_dryrun_lm():
    """End-to-end mini dry-run: reduced LM train on an 8-device mesh."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.configs.registry import get_arch
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import build_step
        from repro.parallel.sharding import SINGLE_POD_RULES

        mesh = make_test_mesh(8, model=2)
        arch = get_arch("dbrx-132b")
        bundle = build_step(arch, arch.shape("train_4k"), mesh,
                            SINGLE_POD_RULES, reduced=True)
        with mesh:
            compiled = bundle.lower(mesh).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # older jax: one dict per device
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        hlo = compiled.as_text()
        assert any(c in hlo for c in ("all-reduce", "all-gather")), \
            "expected collectives in sharded train step"
        print("MINI_DRYRUN_OK")
    """)
    assert "MINI_DRYRUN_OK" in out


def test_collective_bytes_parser_on_real_hlo():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.launch.roofline import collective_bytes

        mesh = make_test_mesh(8, model=4)
        x = jnp.ones((32, 64), jnp.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))

        @jax.jit
        def f(a):
            return jnp.sum(a)          # cross-device reduction

        compiled = f.lower(xs).compile()
        coll = collective_bytes(compiled.as_text())
        assert coll["total"] > 0, compiled.as_text()[:2000]
        print("COLL_PARSE_OK", coll["total"])
    """)
    assert "COLL_PARSE_OK" in out
