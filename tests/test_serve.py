"""repro.serve: micro-batcher, engine, shadow scoring, metrics."""

import threading
from collections import Counter

import numpy as np
import pytest

from repro.core import CenterNorm, CompressionPipeline, Int8Quantizer, PCA
from repro.data import make_dpr_like_kb
from repro.retrieval import CompressedIndex, DenseIndex, IVFFlatIndex
from repro.serve import (LatencyStats, MicroBatcher, ServeEngine,
                         ShadowScorer)
from repro.serve.batcher import bucket_rows


@pytest.fixture(scope="module")
def kb():
    return make_dpr_like_kb(n_queries=256, n_docs=2000, d=64, r_eff=32)


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------


def test_bucket_rows_powers_of_two():
    assert [bucket_rows(n, 64) for n in (1, 2, 3, 5, 8, 9, 33, 64)] == \
        [1, 2, 4, 8, 8, 16, 64, 64]
    assert bucket_rows(100, 64) == 64          # capped at max_batch


def test_batcher_coalesces_small_requests():
    b = MicroBatcher(max_batch=32)
    pending = [(i, np.ones((5, 8), np.float32) * i) for i in range(4)]
    batches = b.form(pending)
    assert len(batches) == 1                   # 20 rows fit one micro-batch
    (mb,) = batches
    assert mb.n_valid == 20
    assert mb.queries.shape[0] == 32           # padded to the next bucket
    # rows land where the slices claim
    for s in mb.slices:
        np.testing.assert_array_equal(mb.queries[s.start: s.stop],
                                      s.request_id)


def test_batcher_splits_large_request():
    b = MicroBatcher(max_batch=16)
    batches = b.form([(7, np.arange(40 * 4, dtype=np.float32).reshape(40, 4))])
    assert [mb.n_valid for mb in batches] == [16, 16, 8]
    # reassembly covers every source row exactly once, in order
    rows = []
    for mb in batches:
        for s in mb.slices:
            assert s.request_id == 7
            rows.extend(range(s.req_start, s.req_start + s.stop - s.start))
    assert rows == list(range(40))


def test_batcher_no_padding_mode():
    b = MicroBatcher(max_batch=32, pad_batches=False)
    (mb,) = b.form([(0, np.ones((5, 4), np.float32))])
    assert mb.queries.shape[0] == mb.n_valid == 5


def test_batcher_1d_query_promoted():
    b = MicroBatcher(max_batch=8)
    (mb,) = b.form([(0, np.ones(4, np.float32))])
    assert mb.queries.shape == (1, 4)
    assert mb.n_valid == 1


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def test_engine_results_match_direct_search(kb):
    idx = DenseIndex(kb.docs)
    engine = ServeEngine(idx, k=5, batcher=MicroBatcher(max_batch=64))
    queries = np.asarray(kb.queries)
    sizes = [1, 3, 32, 7, 64, 17]              # mixed request shapes
    rids, offs = [], []
    off = 0
    for n in sizes:
        rids.append(engine.submit(queries[off: off + n]))
        offs.append(off)
        off += n
    results = engine.drain()
    assert set(results) == set(rids)
    _, want_all = idx.search(queries[:off], 5)
    want_all = np.asarray(want_all)
    for rid, o, n in zip(rids, offs, sizes):
        got = results[rid]
        assert got.ids.shape == (n, 5)
        np.testing.assert_array_equal(got.ids, want_all[o: o + n])
        assert got.latency_s >= 0


def test_engine_50_request_stream_with_shadow(kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(32), Int8Quantizer()])
    idx = CompressedIndex.build(kb.docs, kb.queries[:64], pipe,
                                backend="jnp")
    shadow = ShadowScorer.for_compressed(idx, kb.docs, every=5)
    engine = ServeEngine(idx, k=10, batcher=MicroBatcher(max_batch=16),
                         shadow=shadow)
    queries = np.asarray(kb.queries)
    for r in range(50):
        engine.submit(queries[(r * 5) % 200: (r * 5) % 200 + 4])
        engine.drain()
    stats = engine.stats()
    assert stats["requests_served"] == 50
    assert stats["queries_served"] == 200
    for key in ("p50_ms", "p95_ms", "p99_ms"):
        assert np.isfinite(stats[key]) and stats[key] >= 0
    assert stats["shadow_batches"] == 10       # every=5 of 50 batches
    assert stats["shadow_overlap"] > 0.9       # int8 ≈ exact on this KB


def test_engine_coalesced_drain_fewer_batches(kb):
    idx = DenseIndex(kb.docs)
    engine = ServeEngine(idx, k=5, batcher=MicroBatcher(max_batch=64))
    queries = np.asarray(kb.queries)
    for r in range(8):
        engine.submit(queries[r * 8: (r + 1) * 8])   # 64 rows pending
    results = engine.drain()
    assert len(results) == 8
    assert engine.batches_served == 1          # one fused micro-batch
    assert engine.pending == 0


def test_engine_rejects_bad_shapes(kb):
    engine = ServeEngine(DenseIndex(kb.docs), k=5)
    with pytest.raises(ValueError):
        engine.submit(np.ones((2, 3, 4), np.float32))


def test_engine_rejects_empty_query_block(kb):
    """A (0, d) block must be refused at submit — enqueued, it would fall
    through the micro-batcher without a slice and the request id would
    never resolve."""
    engine = ServeEngine(DenseIndex(kb.docs), k=5)
    with pytest.raises(ValueError, match="empty query block"):
        engine.submit(np.ones((0, 64), np.float32))
    assert engine.pending == 0
    assert engine.drain() == {}
    # the batcher itself also refuses, in case a caller bypasses submit
    with pytest.raises(ValueError, match="empty query block"):
        MicroBatcher().form([(0, np.ones((0, 64), np.float32))])


def test_engine_per_request_k(kb):
    """k overrides batch per (k, nprobe) group and each request's output
    width follows its own k."""
    idx = DenseIndex(kb.docs)
    engine = ServeEngine(idx, k=5, batcher=MicroBatcher(max_batch=64))
    q = np.asarray(kb.queries[:6])
    r_default = engine.submit(q)
    r_wide = engine.submit(q, k=9)
    with pytest.raises(ValueError):
        engine.submit(q, k=0)
    results = engine.drain()
    assert engine.batches_served == 2          # k groups never coalesce
    assert results[r_default].ids.shape == (6, 5)
    assert results[r_wide].ids.shape == (6, 9)
    _, want = idx.search(q, 9)
    np.testing.assert_array_equal(results[r_wide].ids, np.asarray(want))
    np.testing.assert_array_equal(results[r_default].ids,
                                  np.asarray(want)[:, :5])


def test_engine_concurrent_producers_lose_nothing(kb):
    """Many producer threads submit while the main thread drains: every
    request must come back exactly once and the counters must balance."""
    idx = DenseIndex(kb.docs)
    engine = ServeEngine(idx, k=5, batcher=MicroBatcher(max_batch=32))
    queries = np.asarray(kb.queries)
    n_threads, per_thread = 8, 25
    submitted: list[dict[int, int]] = [dict() for _ in range(n_threads)]

    def producer(t):
        rng = np.random.default_rng(t)
        for _ in range(per_thread):
            n = int(rng.integers(1, 5))
            off = int(rng.integers(0, 200))
            rid = engine.submit(queries[off: off + n])
            submitted[t][rid] = n

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    seen: Counter = Counter()
    results = {}
    while any(th.is_alive() for th in threads) or engine.pending:
        out = engine.drain()
        seen.update(out.keys())
        results.update(out)
    for th in threads:
        th.join()
    out = engine.drain()                       # anything racing the last check
    seen.update(out.keys())
    results.update(out)

    want = {}
    for d in submitted:
        want.update(d)
    assert len(want) == n_threads * per_thread          # ids never collided
    assert set(results) == set(want)                    # nothing lost
    assert all(c == 1 for c in seen.values())           # nothing duplicated
    for rid, n in want.items():
        assert results[rid].ids.shape == (n, 5)
    total_rows = sum(want.values())
    stats = engine.stats()
    assert stats["requests_served"] == n_threads * per_thread
    assert stats["queries_served"] == total_rows
    assert stats["count"] == stats["batches_served"]    # LatencyStats agrees
    assert engine.pending == 0


def test_engine_ivf_per_request_nprobe(kb):
    """An IVF-backed engine honours a per-request probe-width override and
    batches per nprobe value (one compiled graph per batch)."""
    ivf = IVFFlatIndex(nlist=16, nprobe=16, kmeans_iters=5).fit(kb.docs)
    engine = ServeEngine(ivf, k=5, batcher=MicroBatcher(max_batch=64))
    q = np.asarray(kb.queries[:8])
    r_default = engine.submit(q)
    r_narrow = engine.submit(q, nprobe=1)
    results = engine.drain()
    assert engine.batches_served == 2          # nprobe groups never coalesce
    _, want_default = ivf.search(q, 5)
    _, want_narrow = ivf.search(q, 5, nprobe=1)
    np.testing.assert_array_equal(results[r_default].ids,
                                  np.asarray(want_default))
    np.testing.assert_array_equal(results[r_narrow].ids,
                                  np.asarray(want_narrow))


def test_engine_rejects_nprobe_on_non_ivf_index(kb):
    engine = ServeEngine(DenseIndex(kb.docs), k=5)
    with pytest.raises(ValueError):
        engine.submit(np.ones(64, np.float32), nprobe=4)


def test_latency_stats_empty_and_filled():
    ls = LatencyStats()
    assert np.isnan(ls.percentile(50))
    for v in (0.001, 0.002, 0.003):
        ls.record(v)
    s = ls.summary()
    assert s["count"] == 3
    assert s["p50_ms"] == pytest.approx(2.0)
    assert s["p99_ms"] <= 3.0 + 1e-6


def test_shadow_sampling_cadence(kb):
    idx = DenseIndex(kb.docs)
    shadow = ShadowScorer(DenseIndex(kb.docs), every=3)
    q = np.asarray(kb.queries[:4])
    _, ids = idx.search(q, 5)
    seen = [shadow.observe(q, np.asarray(ids), 5) for _ in range(7)]
    assert [o is not None for o in seen] == [True, False, False,
                                             True, False, False, True]
    assert shadow.mean_overlap == 1.0          # identical indexes


# ---------------------------------------------------------------------------
# latency attribution + lock-consistent stats (the accounting bugfixes)
# ---------------------------------------------------------------------------


class _SlowOnWideK:
    """Index wrapper: searches with k >= threshold stall for ``delay_s`` —
    two request groups with very different per-batch cost."""

    def __init__(self, inner, wide_k, delay_s):
        self.inner = inner
        self.wide_k = wide_k
        self.delay_s = delay_s

    def search(self, queries, k, **kw):
        import time
        if k >= self.wide_k:
            time.sleep(self.delay_s)
        return self.inner.search(queries, k, **kw)

    def __len__(self):
        return len(self.inner)


def test_engine_latency_attributed_per_batch_not_per_drain(kb):
    """A cheap request answered by the first micro-batch of a drain must
    not be charged for an expensive batch that happens to share the same
    drain call: latency stamps at the request's own last batch."""
    delay = 0.25
    idx = _SlowOnWideK(DenseIndex(kb.docs), wide_k=9, delay_s=delay)
    engine = ServeEngine(idx, k=5, batcher=MicroBatcher(max_batch=64))
    q = np.asarray(kb.queries[:4])
    r_cheap = engine.submit(q)              # k=5 group: fast, drains first
    r_slow = engine.submit(q, k=9)          # k=9 group: sleeps in search
    results = engine.drain()
    assert engine.batches_served == 2
    assert results[r_slow].latency_s >= delay
    # before the fix the cheap request inherited the whole drain's wall
    # time (>= delay); now it sees only its own fast batch
    assert results[r_cheap].latency_s < delay / 2
    # the request-level collector recorded both, separately
    s = engine.stats()
    assert s["request_count"] == 2
    assert s["request_p99_ms"] >= delay * 1000.0


def test_engine_stats_conservation_on_every_snapshot(kb):
    """Multi-producer stress: counters are mutated under the engine lock,
    so *every* stats() snapshot satisfies exact request conservation
    (submitted == served + pending + inflight) — not only at quiesce."""
    idx = DenseIndex(kb.docs)
    engine = ServeEngine(idx, k=5, batcher=MicroBatcher(max_batch=32))
    queries = np.asarray(kb.queries)
    n_threads, per_thread = 6, 40
    stop = threading.Event()
    violations = []

    def producer(t):
        rng = np.random.default_rng(t)
        for _ in range(per_thread):
            n = int(rng.integers(1, 6))
            off = int(rng.integers(0, 200))
            engine.submit(queries[off: off + n])

    def watcher():
        while not stop.is_set():
            s = engine.stats()
            req_balance = s["requests_submitted"] - (
                s["requests_served"] + s["pending_requests"]
                + s["inflight_requests"])
            if req_balance != 0:
                violations.append(("requests", s))
            # row-level conservation is an inequality mid-drain (a half-
            # served multi-batch request counts rows on both sides) but
            # may never go negative
            row_balance = s["queries_submitted"] - (
                s["queries_served"] + s["pending_rows"]
                + s["inflight_rows"])
            if row_balance > 0:
                violations.append(("rows", s))

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    w = threading.Thread(target=watcher)
    w.start()
    for th in threads:
        th.start()
    while any(th.is_alive() for th in threads) or engine.pending:
        engine.drain()
    for th in threads:
        th.join()
    engine.drain()
    stop.set()
    w.join()
    assert not violations, violations[:3]
    s = engine.stats()
    assert s["requests_submitted"] == n_threads * per_thread
    assert s["requests_served"] == s["requests_submitted"]   # quiesce
    assert s["queries_served"] == s["queries_submitted"]
    assert s["pending_requests"] == s["inflight_requests"] == 0
    assert s["request_count"] == s["requests_served"]


def test_latency_stats_thread_safe_record_vs_summary():
    """record() racing summary()/merge() must never crash or produce an
    inconsistent window (the pre-fix list could resize mid-read)."""
    ls = LatencyStats(window=256)
    stop = threading.Event()
    errors = []

    def writer():
        i = 0
        while not stop.is_set():
            ls.record(i * 1e-6)
            i += 1

    def reader():
        while not stop.is_set():
            try:
                s = ls.summary()
                assert s["count"] >= 0
                LatencyStats.merge([ls, LatencyStats()])
                ls.percentile(99)
            except Exception as e:          # pragma: no cover
                errors.append(e)
                return

    ths = [threading.Thread(target=writer) for _ in range(2)] + \
        [threading.Thread(target=reader) for _ in range(2)]
    for t in ths:
        t.start()
    import time
    time.sleep(0.3)
    stop.set()
    for t in ths:
        t.join()
    assert not errors
    assert len(ls.samples) <= 256


# ---------------------------------------------------------------------------
# adaptive micro-batch sizing
# ---------------------------------------------------------------------------


def test_adaptive_batcher_follows_depth():
    from repro.serve import AdaptiveBatcher
    b = AdaptiveBatcher(min_batch=8, max_batch=128)
    assert b.batch_cap == 8                     # idle: smallest bucket
    assert b.observe_depth(3) == 8              # clamped up to min_batch
    assert b.observe_depth(20) == 32            # pow2 round-up
    assert b.observe_depth(1000) == 128         # clamped to max_batch
    assert b.observe_depth(64) == 64
    with pytest.raises(ValueError):
        AdaptiveBatcher(min_batch=0)
    with pytest.raises(ValueError):
        AdaptiveBatcher(min_batch=64, max_batch=32)


def test_adaptive_batcher_shapes_stay_pow2(kb):
    """Under a deep queue the adaptive cap widens and the formed batches
    use it; under a shallow queue they shrink — but every padded shape is
    still a power-of-two bucket."""
    from repro.serve import AdaptiveBatcher
    b = AdaptiveBatcher(min_batch=8, max_batch=64)
    rows = [(i, np.ones((10, 4), np.float32)) for i in range(10)]  # 100 rows
    b.observe_depth(100)
    deep = b.form(rows)
    assert max(mb.queries.shape[0] for mb in deep) == 64
    b.observe_depth(10)
    shallow = b.form([(0, np.ones((10, 4), np.float32))])
    assert [mb.queries.shape[0] for mb in shallow] == [16]
    for mb in deep + shallow:
        assert mb.queries.shape[0] & (mb.queries.shape[0] - 1) == 0


def test_engine_drives_adaptive_batcher(kb):
    """The engine reports popped depth to an adaptive batcher before
    forming batches: a deep backlog widens the cap with no manual step."""
    from repro.serve import AdaptiveBatcher
    idx = DenseIndex(kb.docs)
    b = AdaptiveBatcher(min_batch=8, max_batch=64)
    engine = ServeEngine(idx, k=5, batcher=b)
    queries = np.asarray(kb.queries)
    engine.submit(queries[:2])
    engine.drain()
    assert b.batch_cap == 8                     # 2 rows popped → min bucket
    for r in range(10):
        engine.submit(queries[r * 10: r * 10 + 10])
    engine.drain()                              # 100 rows popped
    assert b.batch_cap == 64
