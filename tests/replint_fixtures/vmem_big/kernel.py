"""Fixture: intentionally-oversized Pallas kernel for the VMEM budget pass.

Two f32 blocks of (1024, 4096) double-buffered = 2 × 16 MiB × 2 — far past
the 16 MiB budget — plus a lane-misaligned (128, 100) output block.

Parsed by tests/test_replint.py — never imported or executed.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, out_ref):
    out_ref[...] = (a_ref[...] * b_ref[...]).sum(axis=1)[:, None]


def oversized_pallas(a, b):
    grid = (a.shape[0] // 1024,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1024, 4096), lambda i: (i, 0)),
            pl.BlockSpec((1024, 4096), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((128, 100), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], 1024), jnp.float32),
    )(a, b)
