"""Fixture: every lock-discipline rule fires exactly where marked.

Parsed by tests/test_replint.py — never imported or executed.
"""

import threading
import time


class BadCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self._aux = threading.Lock()
        self._count = 0
        self._items = []

    def bump(self):
        with self._lock:
            self._count += 1          # establishes: _count guarded by _lock
            self._items.append(1)     # establishes: _items guarded by _lock

    def peek(self):
        return self._count            # lock-bare-read

    def reset(self):
        self._count = 0               # lock-bare-write

    def slow_bump(self):
        with self._lock:
            time.sleep(0.1)           # lock-blocking-call
            self._count += 1

    def _drop_locked(self):
        self._items.clear()           # exempt: *_locked convention

    def drop(self):
        self._drop_locked()           # lock-helper-unlocked (no lock held)

    def ab(self):
        with self._lock:
            with self._aux:           # order edge: _lock -> _aux
                self._count += 1

    def ba(self):
        with self._aux:
            with self._lock:          # lock-order: conflicting edge
                self._count += 1
