"""Fixture: tie-order violations vs. clean routing.

Parsed by tests/test_replint.py — never imported or executed.
"""

import jax
import jax.numpy as jnp


def rank_naive(scores, k):
    return jax.lax.top_k(scores, k)          # tieorder-raw-rank


def order_by_sim(similarities):
    return jnp.argsort(-similarities)        # tieorder-raw-rank


def bucket_labels(labels):
    return jnp.argsort(labels)               # audit-only (not score-like)


def rank_clean(scores, ids, k):
    from repro.retrieval.topk import topk_score_then_id
    return topk_score_then_id(scores, ids, k)   # canonical route: no finding
