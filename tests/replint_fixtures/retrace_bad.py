"""Fixture: every retrace rule fires exactly where marked.

Parsed by tests/test_replint.py — never imported or executed.
"""

import jax
import jax.numpy as jnp
import numpy as np


def per_request(xs):
    out = []
    for x in xs:
        f = jax.jit(lambda v: v * 2)      # retrace-in-loop
        out.append(f(x))
    return out


class Scorer:
    def __init__(self, scale):
        self.scale = scale

    def build(self):
        @jax.jit
        def fn(x):
            return x * self.scale          # retrace-self-capture
        return fn


@jax.jit
def syncs(x):
    y = float(x.sum())                     # retrace-host-sync (float)
    z = np.asarray(x)                      # retrace-host-sync (np.asarray)
    return y + z.sum() + x.sum().item()    # retrace-host-sync (.item)


def scan_body(carry, x):
    return carry + int(x), x               # retrace-host-sync (int)


def run(xs):
    return jax.lax.scan(scan_body, 0, xs)


def good_builder(scale):
    s = jnp.asarray(scale)                 # snapshot: no finding

    @jax.jit
    def fn(x):
        return x * s
    return fn
