"""Fixture: disciplined locking — the locks pass must stay silent.

Parsed by tests/test_replint.py — never imported or executed.
"""

import threading


class GoodCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self._done = threading.Event()   # sync primitive: exempt
        self._count = 0
        self._label = "idle"             # only assigned in __init__: exempt

    def bump(self):
        with self._lock:
            self._count += 1
            self._flush_locked()

    def peek(self):
        with self._lock:
            return self._count

    def _flush_locked(self):
        self._count = max(self._count, 0)

    def wait_done(self):
        self._done.wait()                # no lock held: fine

    def describe(self):
        return self._label               # immutable after init: fine
