"""ShardedIVFIndex ≡ IVFIndex on a 1×8 CPU mesh, per backend and nprobe.

Same subprocess pattern as tests/test_sharded_index.py: forced host devices
in a child process, one run checks every scorer backend at several probe
widths, parametrized tests assert on the per-backend verdict lines.  Exact
id equality is required — the (score desc, id asc) total order makes the
shard merge deterministic even for the tie-heavy 1-bit backend.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, SRC)

from repro.retrieval import backend_tail_stages  # noqa: E402

BACKENDS = tuple(backend_tail_stages())

_CHECK_ALL = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import CenterNorm, CompressionPipeline, PCA
    from repro.launch.mesh import make_test_mesh
    from repro.retrieval import (IVFIndex, ShardedIVFIndex,
                                 backend_tail_stages)

    rng = np.random.default_rng(0)
    docs = jnp.asarray(rng.standard_normal((515, 64)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    mesh = make_test_mesh(8, model=8)          # 1 x 8: pure doc sharding

    for name, tail in backend_tail_stages().items():
        pipe = CompressionPipeline([CenterNorm(), PCA(32)] + tail)
        single = IVFIndex.build(docs, queries, pipe, nlist=12, nprobe=6,
                                kmeans_iters=8, backend="jnp")
        sharded = ShardedIVFIndex(single, mesh)
        ok_ids = ok_vals = True
        for nprobe in (3, 6, 12):
            v1, i1 = single.search(queries, 10, nprobe=nprobe)
            v2, i2 = sharded.search(queries, 10, nprobe=nprobe)
            ok_ids &= np.array_equal(np.asarray(i1), np.asarray(i2))
            ok_vals &= np.allclose(np.asarray(v1), np.asarray(v2),
                                   rtol=1e-5, atol=1e-5)
        print(f"BACKEND {name} ids={ok_ids} vals={ok_vals}")
"""


@pytest.fixture(scope="module")
def parity_output():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CHECK_ALL)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_ivf_matches_single_host(parity_output, backend):
    assert f"BACKEND {backend} ids=True vals=True" in parity_output


def test_nprobe_guards_single_and_sharded():
    """nprobe resolution mirrors resolve_k: ``None`` → configured default,
    over-wide requests clamp to nlist, and nprobe < 1 is a loud error on
    both the single-host index and the sharded wrapper."""
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.mesh import make_test_mesh
    from repro.retrieval import IVFIndex, ShardedIVFIndex

    rng = np.random.default_rng(5)
    docs = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
    queries = docs[:4]
    ivf = IVFIndex(nlist=6, nprobe=3, kmeans_iters=3).fit(docs)
    assert ivf._resolve_nprobe(None) == 3
    assert ivf._resolve_nprobe(999) == 6       # clamped to nlist
    # over-wide nprobe behaves exactly like full probe
    v_full, i_full = ivf.search(queries, 5, nprobe=6)
    v_wide, i_wide = ivf.search(queries, 5, nprobe=999)
    np.testing.assert_array_equal(np.asarray(i_full), np.asarray(i_wide))
    for bad in (0, -2):
        with pytest.raises(ValueError, match="nprobe must be ≥ 1"):
            ivf.search(queries, 5, nprobe=bad)
    sharded = ShardedIVFIndex(ivf, make_test_mesh(1, model=1))
    with pytest.raises(ValueError, match="nprobe must be ≥ 1"):
        sharded.search(queries, 5, nprobe=0)


def test_mutating_wrapped_ivf_is_rejected():
    """The list partition is frozen at construction: growing the wrapped
    IVFIndex afterwards must fail loudly, not silently drop the new docs."""
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.mesh import make_test_mesh
    from repro.retrieval import IVFIndex, ShardedIVFIndex

    rng = np.random.default_rng(3)
    docs = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    ivf = IVFIndex(nlist=4, nprobe=4, kmeans_iters=3).fit(docs)
    sharded = ShardedIVFIndex(ivf, make_test_mesh(1, model=1))
    ivf.add(jnp.asarray(rng.standard_normal((8, 16)), jnp.float32))
    with pytest.raises(ValueError, match="changed since sharding"):
        sharded.search(docs[:2], 3)
