"""Hot-query result cache: per-row LRU, epoch invalidation, bit-identity
through the serving front door."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.retrieval import IndexSpec, build_index
from repro.serve import ResultCache, RetrievalService
from repro.serve.cache import hash_query_row

D = 32
K = 5


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    return {
        "docs1": rng.standard_normal((400, D)).astype(np.float32),
        "docs2": rng.standard_normal((400, D)).astype(np.float32),
        "queries": rng.standard_normal((64, D)).astype(np.float32),
    }


def make_mutable(corpus):
    spec = IndexSpec(method="pca_int8", dim=16, backend="jnp", post=False,
                     mutable=True)
    return build_index(spec, jnp.asarray(corpus["docs1"]),
                       jnp.asarray(corpus["queries"]))


# ---------------------------------------------------------------------------
# ResultCache mechanics
# ---------------------------------------------------------------------------


def test_row_hash_exact_bytes():
    a = np.ones(8, np.float32)
    assert hash_query_row(a) == hash_query_row(a.copy())
    b = a.copy()
    b[3] += 1e-7                                # any bit flip → new key
    assert hash_query_row(a) != hash_query_row(b)


def test_lookup_is_all_rows_or_nothing():
    c = ResultCache(max_rows=64)
    q = np.arange(12, dtype=np.float32).reshape(3, 4)
    keys = ResultCache.keys_for("kb", 0, 1, K, None, q)
    assert c.lookup(keys) is None
    c.put(keys[:2], np.zeros((2, K), np.float32), np.zeros((2, K), np.int32))
    assert c.lookup(keys) is None               # one row missing → miss
    c.put(keys[2:], np.ones((1, K), np.float32), np.ones((1, K), np.int32))
    scores, ids = c.lookup(keys)
    assert scores.shape == ids.shape == (3, K)
    np.testing.assert_array_equal(ids[:2], 0)
    np.testing.assert_array_equal(ids[2], 1)
    st = c.stats()
    assert st["hits"] == 3 and st["misses"] == 6


def test_rows_reassemble_across_block_compositions():
    """Rows cached from one block composition answer any other block that
    wants them, in any order — per-row entries, not per-block."""
    c = ResultCache(max_rows=64)
    q = np.random.default_rng(0).standard_normal((4, 8)).astype(np.float32)
    keys = ResultCache.keys_for("kb", 0, 1, K, 4, q)
    scores = np.arange(4 * K, dtype=np.float32).reshape(4, K)
    ids = np.arange(4 * K, dtype=np.int32).reshape(4, K)
    c.put(keys, scores, ids)
    perm = [2, 0, 3]
    got_s, got_i = c.lookup(ResultCache.keys_for("kb", 0, 1, K, 4, q[perm]))
    np.testing.assert_array_equal(got_s, scores[perm])
    np.testing.assert_array_equal(got_i, ids[perm])


def test_key_isolation():
    """index, epoch, version, k and nprobe all partition the cache."""
    c = ResultCache(max_rows=64)
    q = np.ones((1, 8), np.float32)
    base = ("kb", 0, 1, K, 4)
    c.put(ResultCache.keys_for(*base, q),
          np.zeros((1, K), np.float32), np.zeros((1, K), np.int32))
    assert c.lookup(ResultCache.keys_for(*base, q)) is not None
    for variant in [("other", 0, 1, K, 4), ("kb", 1, 1, K, 4),
                    ("kb", 0, 2, K, 4), ("kb", 0, 1, K + 1, 4),
                    ("kb", 0, 1, K, 8), ("kb", 0, 1, K, None)]:
        assert c.lookup(ResultCache.keys_for(*variant, q)) is None


def test_lru_eviction_bounded():
    c = ResultCache(max_rows=4)
    for i in range(8):
        q = np.full((1, 4), i, np.float32)
        c.put(ResultCache.keys_for("kb", 0, 1, K, None, q),
              np.zeros((1, K), np.float32), np.zeros((1, K), np.int32))
    assert len(c) == 4
    assert c.stats()["evictions"] == 4
    # oldest rows gone, newest retained
    q_old = np.full((1, 4), 0, np.float32)
    q_new = np.full((1, 4), 7, np.float32)
    assert c.lookup(ResultCache.keys_for("kb", 0, 1, K, None, q_old)) is None
    assert c.lookup(ResultCache.keys_for("kb", 0, 1, K, None, q_new)) \
        is not None


def test_invalidate_by_index():
    c = ResultCache(max_rows=64)
    q = np.arange(8, dtype=np.float32).reshape(2, 4)   # two distinct rows
    for name in ("a", "b"):
        c.put(ResultCache.keys_for(name, 0, 1, K, None, q),
              np.zeros((2, K), np.float32), np.zeros((2, K), np.int32))
    assert c.invalidate("a") == 2
    assert len(c) == 2                          # b untouched
    assert c.invalidate() == 2                  # None → everything
    assert len(c) == 0


def test_cached_arrays_are_isolated_copies():
    """Mutating a returned array must not corrupt the cache (and vice
    versa): results are copied in and out."""
    c = ResultCache(max_rows=16)
    q = np.ones((1, 4), np.float32)
    keys = ResultCache.keys_for("kb", 0, 1, K, None, q)
    src = np.zeros((1, K), np.float32)
    c.put(keys, src, np.zeros((1, K), np.int32))
    src[:] = 99.0                               # caller reuses its buffer
    s1, _ = c.lookup(keys)
    np.testing.assert_array_equal(s1, 0.0)
    s1[:] = 42.0                                # reader scribbles on result
    s2, _ = c.lookup(keys)
    np.testing.assert_array_equal(s2, 0.0)


# ---------------------------------------------------------------------------
# through the service: hits, bit-identity, epoch invalidation
# ---------------------------------------------------------------------------


def test_service_cache_hit_bit_identical(corpus):
    with RetrievalService(start=False, cache_rows=512) as svc:
        svc.register("kb", make_mutable(corpus))
        q = corpus["queries"][:8]
        h1 = svc.query(q, index="kb", k=K)
        assert not h1.done()                    # miss: must dispatch
        svc.drain_once()
        r1 = h1.result(30)
        h2 = svc.query(q, index="kb", k=K)
        assert h2.done()                        # hit: resolves at submit
        r2 = h2.result()
        assert r2.request_id == -1
        np.testing.assert_array_equal(r1.scores, r2.scores)
        np.testing.assert_array_equal(r1.ids, r2.ids)
        s = svc.stats()
        assert s["cache_hits"] == 1
        assert s["cache"]["hits"] == 8
        # a cache hit bypasses admission: engine conservation undisturbed
        assert s["requests_submitted"] == s["requests_served"] == 1


def test_service_cache_subset_rows_hit(corpus):
    """Per-row caching: a new block made of already-seen rows (different
    order, different composition) is served from cache and matches a
    direct dispatch bit for bit."""
    with RetrievalService(start=False, cache_rows=512) as svc:
        idx = make_mutable(corpus)
        svc.register("kb", idx)
        q = corpus["queries"][:8]
        h = svc.query(q, index="kb", k=K)
        svc.drain_once()
        h.result(30)
        sub = q[[5, 1, 6]]
        h2 = svc.query(sub, index="kb", k=K)
        assert h2.done()
        want_s, want_i = idx.search(sub, K)
        np.testing.assert_array_equal(h2.result().ids, np.asarray(want_i))
        np.testing.assert_array_equal(h2.result().scores,
                                      np.asarray(want_s))


def test_service_cache_invalidated_on_update_promote_rollback(corpus):
    with RetrievalService(start=False, cache_rows=512) as svc:
        svc.register("kb", make_mutable(corpus))
        q = corpus["queries"][:4]

        def prime():
            h = svc.query(q, index="kb", k=K)
            if not h.done():
                svc.drain_once()
            return h.result(30)

        # update() must invalidate: the deleted doc may not resurface
        # from cache even though the query bytes are identical
        r1 = prime()
        doomed = int(np.asarray(r1.ids)[0, 0])
        svc.update("kb", delete=[doomed])
        h = svc.query(q, index="kb", k=K)
        assert not h.done()                     # stale rows unreachable
        svc.drain_once()
        assert doomed not in set(np.asarray(h.result(30).ids).ravel())

        # compact → promote: new live version, fresh cache space
        prime()
        svc.compact("kb")
        h = svc.query(q, index="kb", k=K)
        assert not h.done()
        svc.drain_once()
        h.result(30)

        # rollback flips live again: must not serve the other version's
        # rows
        prime()
        svc.rollback("kb")
        h = svc.query(q, index="kb", k=K)
        assert not h.done()
        svc.drain_once()
        h.result(30)
        assert svc.stats()["cache"]["invalidations"] > 0


def test_service_cache_disabled_by_default(corpus):
    with RetrievalService(start=False) as svc:
        svc.register("kb", make_mutable(corpus))
        q = corpus["queries"][:4]
        for _ in range(2):
            h = svc.query(q, index="kb", k=K)
            assert not h.done()                 # identical block: no cache
            svc.drain_once()
            h.result(30)
        s = svc.stats()
        assert s["cache_hits"] == 0
        assert "cache" not in s
        assert s["requests_submitted"] == s["requests_served"] == 2
