"""Scorer backend layer: registry, kernel-path equivalence, index wiring."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CenterNorm, CompressionPipeline, FloatCast,
                        Int8Quantizer, OneBitQuantizer, PCA)
from repro.data import make_dpr_like_kb
from repro.retrieval import CompressedIndex, scorer_names
from repro.retrieval.scorers import (FloatCastScorer, Int8Scorer,
                                     OneBitScorer, Scorer, get_scorer,
                                     scorer_for_pipeline, split_pipeline)
from repro.retrieval.topk import similarity, topk_search


@pytest.fixture(scope="module")
def kb():
    return make_dpr_like_kb(n_queries=64, n_docs=2000, d=64, r_eff=32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_names():
    assert set(scorer_names()) >= {"float", "fp16", "int8", "onebit"}


def test_get_scorer_unknown_raises():
    with pytest.raises(KeyError):
        get_scorer("nope")


@pytest.mark.parametrize("tail,cls", [
    ([], Scorer),
    ([FloatCast()], FloatCastScorer),
    ([Int8Quantizer()], Int8Scorer),
    ([OneBitQuantizer(0.5)], OneBitScorer),
])
def test_scorer_for_pipeline_dispatch(tail, cls):
    pipe = CompressionPipeline([CenterNorm(), PCA(16)] + tail)
    float_stages, scorer = scorer_for_pipeline(pipe)
    assert type(scorer) is cls
    assert len(float_stages) == 2


def test_trailing_float_stage_means_no_quantizer():
    # post-processing AFTER the quantizer → storage is the float output of
    # the full chain (the paper's evaluation representation)
    pipe = CompressionPipeline([CenterNorm(), Int8Quantizer(), CenterNorm()])
    float_stages, quantizer = split_pipeline(pipe)
    assert quantizer is None
    assert len(float_stages) == 3


# ---------------------------------------------------------------------------
# per-scorer score equivalence (jnp oracle path)
# ---------------------------------------------------------------------------


def _fit_stages(kb, stages):
    docs, queries = kb.docs, kb.queries
    for t in stages:
        t.fit(docs, queries)
        docs, queries = t(docs, "docs"), t(queries, "queries")
    return docs, queries


def test_int8_scorer_matches_dequantized_gemm(kb):
    quant = Int8Quantizer().fit(kb.docs)
    scorer = Int8Scorer(quant, backend="jnp")
    storage = scorer.encode_docs(kb.docs)
    got = scorer.scores(kb.queries[:8], storage)
    want = similarity(kb.queries[:8], quant.decode(storage), "ip")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_onebit_scorer_matches_symmetric_oracle(kb):
    quant = OneBitQuantizer(0.5).fit(kb.docs)
    scorer = OneBitScorer(quant, backend="jnp")
    storage = scorer.encode_docs(kb.docs)
    q_enc = scorer.encode_queries(kb.queries[:8])
    got = scorer.scores(q_enc, storage)
    want = similarity(quant(kb.queries[:8], "queries"),
                      quant(kb.docs, "docs"), "ip")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fp16_scorer_roundtrip(kb):
    scorer = FloatCastScorer(FloatCast(), backend="jnp")
    storage = scorer.encode_docs(kb.docs)
    assert storage.dtype == jnp.float16
    got = scorer.scores(kb.queries[:8], storage)
    want = similarity(kb.queries[:8], storage.astype(jnp.float32), "ip")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_scorer_params_are_explicit(kb):
    """params() must carry everything scores() reads (shard_map contract)."""
    quant = Int8Quantizer().fit(kb.docs)
    scorer = Int8Scorer(quant, backend="jnp")
    storage = scorer.encode_docs(kb.docs)
    params = {k: jnp.asarray(v) for k, v in scorer.params().items()}
    got = scorer.scores(kb.queries[:4], storage, params=params)
    want = scorer.scores(kb.queries[:4], storage)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# CompressedIndex orchestration
# ---------------------------------------------------------------------------


def test_index_fused_search_matches_manual_pipeline(kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(32), CenterNorm(),
                                Int8Quantizer()])
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    _, ids = idx.search(kb.queries[:16], 8)
    d = pipe.transform(kb.docs, "docs")          # quant→dequant oracle
    q = idx.encode_queries(kb.queries[:16])
    _, want = topk_search(q, d, 8)
    overlap = np.mean([len(set(np.asarray(ids)[i].tolist()) &
                           set(np.asarray(want)[i].tolist())) / 8
                       for i in range(16)])
    assert overlap > 0.97


def test_index_fp16_decode_cached(kb):
    pipe = CompressionPipeline([CenterNorm(), FloatCast()])
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    assert idx.storage.dtype == jnp.float16
    _, i1 = idx.search(kb.queries[:4], 5)
    cached = idx._decoded_cache
    assert cached is not None and cached.dtype == jnp.float32
    _, i2 = idx.search(kb.queries[:4], 5)
    assert idx._decoded_cache is cached          # no per-call re-decode
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    idx.add(kb.docs[:32])
    assert idx._decoded_cache is None            # invalidated by add


def test_index_add_after_build_grows_search_space(kb):
    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5)])
    idx = CompressedIndex.build(kb.docs[:1000], kb.queries, pipe,
                                backend="jnp")
    assert len(idx) == 1000
    idx.add(kb.docs[1000:2000])
    assert len(idx) == 2000
    _, ids = idx.search(kb.queries[:8], 10)
    assert int(np.asarray(ids).max()) >= 1000 or ids.shape == (8, 10)


# ---------------------------------------------------------------------------
# pipeline state-dict validation (satellite)
# ---------------------------------------------------------------------------


def test_load_state_dict_roundtrip(kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(16)])
    pipe.fit(kb.docs, kb.queries)
    sd = pipe.state_dict()
    other = CompressionPipeline([CenterNorm(), PCA(16)])
    other.load_state_dict(sd)
    np.testing.assert_array_equal(
        np.asarray(pipe.transform(kb.docs[:8], "docs")),
        np.asarray(other.transform(kb.docs[:8], "docs")))


def test_load_state_dict_rejects_mismatched_stage_types(kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(16)])
    pipe.fit(kb.docs, kb.queries)
    sd = pipe.state_dict()
    wrong = CompressionPipeline([CenterNorm(), Int8Quantizer()])
    with pytest.raises(ValueError, match="mismatch"):
        wrong.load_state_dict(sd)


def test_load_state_dict_rejects_wrong_length(kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(16)])
    pipe.fit(kb.docs, kb.queries)
    sd = pipe.state_dict()
    del sd["types"]                        # legacy dict without types
    short = CompressionPipeline([CenterNorm()])
    with pytest.raises(ValueError, match="length mismatch"):
        short.load_state_dict(sd)
