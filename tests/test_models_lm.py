import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig, MoEConfig
from repro.models import transformer as T


DENSE = LMConfig("t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                 d_ff=64, vocab_size=128, attn_q_chunk=8, qkv_bias=True,
                 loss_chunk=None)
MOE = LMConfig("tm", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
               d_ff=16, vocab_size=128, attn_q_chunk=16,
               moe=MoEConfig(n_experts=4, top_k=2), loss_chunk=None)


@pytest.fixture(scope="module")
def batch():
    rng = jax.random.PRNGKey(0)
    toks = jax.random.randint(rng, (2, 16), 0, 128)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("cfg", [DENSE, MOE], ids=["dense", "moe"])
def test_loss_and_grads_finite(cfg, batch):
    params = T.init(jax.random.PRNGKey(0), cfg)
    (loss, metrics), grads = jax.value_and_grad(
        T.loss_fn, has_aux=True)(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert float(loss) == pytest.approx(float(metrics["ce"]), rel=0.2)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_chunked_ce_equals_full(batch):
    """loss_chunk must not change the loss value."""
    import dataclasses
    params = T.init(jax.random.PRNGKey(0), DENSE)
    full = T.loss_fn(params, batch, DENSE)[0]
    chunked = T.loss_fn(params, batch,
                        dataclasses.replace(DENSE, loss_chunk=8))[0]
    assert float(full) == pytest.approx(float(chunked), rel=1e-5)


def test_scan_equals_unrolled(batch):
    import dataclasses
    params = T.init(jax.random.PRNGKey(0), DENSE)
    a, _ = T.forward(params, batch["tokens"], DENSE)
    b, _ = T.forward(params, batch["tokens"],
                     dataclasses.replace(DENSE, scan_layers=False))
    # bf16 fusion/rounding differs between the two compilations; require
    # near-perfect correlation + matching greedy decisions instead of
    # elementwise equality
    av, bv = np.asarray(a).ravel(), np.asarray(b).ravel()
    assert np.corrcoef(av, bv)[0, 1] > 0.999
    agree = np.mean(np.argmax(np.asarray(a), -1)
                    == np.argmax(np.asarray(b), -1))
    assert agree > 0.9


@pytest.mark.parametrize("cfg", [DENSE, MOE], ids=["dense", "moe"])
def test_prefill_matches_forward(cfg, batch):
    params = T.init(jax.random.PRNGKey(1), cfg)
    logits_f, _ = T.forward(params, batch["tokens"], cfg)
    logits_p, cache = T.prefill(params, batch["tokens"], cfg, cache_len=24)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_f[:, -1]),
                               rtol=2e-2, atol=1e-3)
    assert cache[0].shape == (cfg.n_layers, 2, 24, cfg.n_kv_heads,
                              cfg.resolved_head_dim)


def test_decode_matches_teacher_forcing(batch):
    """Greedy decode step-by-step == forward on the extended sequence."""
    cfg = DENSE
    params = T.init(jax.random.PRNGKey(2), cfg)
    toks = batch["tokens"]
    _, cache = T.prefill(params, toks, cfg, cache_len=20)
    s = toks.shape[1]
    new_tok = jnp.full((2,), 7, jnp.int32)
    logits_d, cache = T.decode_step(params, cache, new_tok,
                                    jnp.asarray(s), cfg)
    ext = jnp.concatenate([toks, new_tok[:, None]], axis=1)
    logits_full, _ = T.forward(params, ext, cfg)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_full[:, -1]),
                               rtol=5e-2, atol=5e-3)


def test_moe_aux_loss_positive(batch):
    params = T.init(jax.random.PRNGKey(0), MOE)
    _, metrics = T.loss_fn(params, batch, MOE)
    assert float(metrics["aux"]) >= 1.0   # Switch aux loss ≥ 1 by Cauchy-Schwarz


def test_param_count_close_to_formula():
    from repro.models.layers import param_count
    spec = T.lm_spec(DENSE)
    n = param_count(spec)
    # formula covers matmul params; norms/biases add < 1%
    assert DENSE.params_dense() <= n <= DENSE.params_dense() * 1.01 + 1000
