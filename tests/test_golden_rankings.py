"""Golden-ranking regressions: frozen top-k ids for every search path.

A fixed-seed corpus is searched through exact float, int8, 1-bit, and IVF
paths; the resulting top-k ids (and scores) are frozen in
``tests/golden/rankings.json``.  Any ranking drift from a future kernel or
refactor PR fails these tests loudly instead of silently shifting quality.

Regenerate (only when a ranking change is *intended*)::

    PYTHONPATH=src python tests/test_golden_rankings.py --regen

Regeneration refuses corpora whose score gaps at the k-boundary are inside
float noise, so the frozen ids stay stable across BLAS/XLA versions.
"""

import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "rankings.json")
K = 5
N_QUERIES = 8


def _kb():
    from repro.data import make_dpr_like_kb
    return make_dpr_like_kb(n_queries=16, n_docs=800, d=64, r_eff=32,
                            seed=2026)


def _probe_margin(ivf, q) -> float:
    """Min gap between the last-probed and first-unprobed centroid score —
    the routing decision's distance from float noise."""
    from repro.retrieval.topk import similarity
    cs = np.asarray(similarity(ivf.encode_queries(q), ivf.centroids,
                               ivf.sim), np.float64)
    cs = np.sort(cs, axis=1)[:, ::-1]
    return float(np.min(cs[:, ivf.nprobe - 1] - cs[:, ivf.nprobe]))


def _build_indexes():
    """{case: fitted index} — every frozen search path, one object each."""
    from repro.core import (CenterNorm, CompressionPipeline, Int8Quantizer,
                            OneBitQuantizer, PCA)
    from repro.retrieval import CompressedIndex, DenseIndex, IVFFlatIndex

    kb = _kb()
    indexes = {}
    indexes["exact_float"] = DenseIndex(kb.docs)

    pipe = CompressionPipeline([CenterNorm(), PCA(32), Int8Quantizer()])
    indexes["exact_int8"] = CompressedIndex.build(kb.docs, kb.queries, pipe,
                                                  backend="jnp")

    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5)])
    onebit = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    indexes["exact_onebit"] = onebit

    indexes["ivf_float"] = IVFFlatIndex(nlist=16, nprobe=8,
                                        kmeans_iters=10).fit(kb.docs)
    indexes["ivf_onebit"] = onebit.to_ivf(nlist=16, nprobe=8,
                                          kmeans_iters=10)
    return indexes, kb.queries[:N_QUERIES]


def _build_cases():
    """({case: (scores (Q, K), ids (Q, K))}, {ivf case: probe margin})."""
    indexes, q = _build_indexes()
    out = {name: idx.search(q, K) for name, idx in indexes.items()}
    margins = {name: _probe_margin(indexes[name], q)
               for name in ("ivf_float", "ivf_onebit")}
    return ({name: (np.asarray(v, np.float64), np.asarray(i, np.int64))
             for name, (v, i) in out.items()}, margins)


def _load_golden() -> dict:
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def built_cases():
    return _build_cases()[0]


@pytest.mark.slow
@pytest.mark.parametrize("case", ["exact_float", "exact_int8",
                                  "exact_onebit", "ivf_float",
                                  "ivf_onebit"])
def test_golden_ranking(built_cases, case):
    golden = _load_golden()["cases"][case]
    vals, ids = built_cases[case]
    np.testing.assert_array_equal(
        ids, np.asarray(golden["ids"]),
        err_msg=f"{case}: top-{K} ids drifted from tests/golden/ — if the "
                "ranking change is intended, regenerate with "
                "`python tests/test_golden_rankings.py --regen`")
    np.testing.assert_allclose(vals, np.asarray(golden["scores"]),
                               rtol=1e-4, atol=1e-4)


@pytest.fixture(scope="module")
def built_indexes():
    return _build_indexes()


@pytest.mark.slow
@pytest.mark.parametrize("case", ["exact_int8", "exact_onebit",
                                  "ivf_float", "ivf_onebit"])
def test_golden_ranking_survives_save_load(tmp_path, built_indexes, case):
    """Artifact round trip reproduces the frozen golden ids exactly —
    persistence is held to the same regression bar as live search."""
    from repro.retrieval import load_index

    indexes, q = built_indexes
    path = str(tmp_path / f"{case}.npz")
    indexes[case].save(path)
    vals, ids = load_index(path).search(q, K)
    golden = _load_golden()["cases"][case]
    np.testing.assert_array_equal(
        np.asarray(ids, np.int64), np.asarray(golden["ids"]),
        err_msg=f"{case}: reloaded index drifted from tests/golden/")
    np.testing.assert_allclose(np.asarray(vals, np.float64),
                               np.asarray(golden["scores"]),
                               rtol=1e-4, atol=1e-4)


def _regen() -> None:
    cases, margins = _build_cases()
    payload = {"corpus": {"n_docs": 800, "d": 64, "seed": 2026,
                          "n_queries": N_QUERIES, "k": K},
               "cases": {}}
    for name, margin in margins.items():
        # IVF probe sets must also clear noise, or a BLAS/XLA upgrade could
        # flip which lists are probed and shift ids with no intended change
        assert margin > 1e-4, f"{name}: probe boundary inside float noise"
    for name, (vals, ids) in cases.items():
        if name in ("exact_float", "ivf_float"):
            # float-GEMM boundary gaps must clear cross-platform noise
            # (int8/sign-dot scores live on coarse discrete grids and are
            # covered by the probe-margin check above instead)
            finite = vals[np.isfinite(vals)]
            gaps = np.abs(np.diff(np.sort(finite)))
            assert np.min(gaps[gaps > 0]) > 1e-4, f"{name}: tie-prone corpus"
        payload["cases"][name] = {
            "ids": ids.tolist(),
            "scores": [[round(float(v), 6) for v in row] for row in vals]}
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH} ({len(cases)} cases)")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
