"""Quantized IVF search: backend parity, nprobe semantics, degenerate
corpora, and property-based invariants (hypothesis optional)."""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import assume, given, settings, st

from repro.core import CenterNorm, CompressionPipeline, OneBitQuantizer, PCA
from repro.data import make_dpr_like_kb
from repro.retrieval import (CompressedIndex, DenseIndex, IVFFlatIndex,
                             IVFIndex, backend_tail_stages,
                             recall_at_k as _recall)

BACKENDS = tuple(backend_tail_stages())


@pytest.fixture(scope="module")
def kb():
    return make_dpr_like_kb(n_queries=64, n_docs=1500, d=64, r_eff=32)


# ---------------------------------------------------------------------------
# full-probe == exact, per scorer backend
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_full_probe_matches_exact_search(kb, backend):
    """nprobe == nlist scores every stored doc: rankings must equal the
    backend's exact search bit-for-bit (ties break on doc id in both)."""
    tail = backend_tail_stages()[backend]
    pipe = CompressionPipeline([CenterNorm(), PCA(32)] + tail)
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    _, want = idx.search(kb.queries[:16], 10)
    ivf = idx.to_ivf(nlist=16, nprobe=16, kmeans_iters=8)
    vals, got = ivf.search(kb.queries[:16], 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.all(np.asarray(got) >= 0)


@pytest.mark.slow
def test_onebit_ivf_recall_acceptance():
    """1-bit IVF at nprobe = nlist/2 keeps ≥ 0.9 recall@10 vs exact 1-bit
    search (the PR's acceptance bar) on the synthetic DPR-like corpus."""
    kb = make_dpr_like_kb(n_queries=64, n_docs=4000, d=128, r_eff=48)
    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5)])
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    _, want = idx.search(kb.queries[:32], 10)
    ivf = idx.to_ivf(nlist=32, nprobe=16)
    _, got = ivf.search(kb.queries[:32], 10)
    assert _recall(got, want) >= 0.9
    # the promotion shares storage — no re-encode, no extra copy
    assert ivf.storage is idx.storage
    assert ivf.nbytes == idx.nbytes


# ---------------------------------------------------------------------------
# nprobe semantics
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_per_call_nprobe_override(kb):
    exact = DenseIndex(kb.docs)
    _, want = exact.search(kb.queries[:32], 10)
    ivf = IVFIndex(nlist=32, nprobe=4, kmeans_iters=8).fit(kb.docs)
    recalls = [_recall(ivf.search(kb.queries[:32], 10, nprobe=p)[1], want)
               for p in (1, 8, 32)]
    assert recalls == sorted(recalls)          # wider probe never hurts
    assert recalls[-1] == 1.0                  # nprobe == nlist is exact
    # the constructor default is used when no override is given
    _, d4 = ivf.search(kb.queries[:32], 10)
    _, e4 = ivf.search(kb.queries[:32], 10, nprobe=4)
    np.testing.assert_array_equal(np.asarray(d4), np.asarray(e4))


def test_bad_nprobe_rejected(kb):
    ivf = IVFFlatIndex(nlist=4, nprobe=2, kmeans_iters=2).fit(kb.docs[:64])
    with pytest.raises(ValueError):
        ivf.search(kb.queries[:4], 3, nprobe=0)
    with pytest.raises(ValueError):
        IVFIndex(nlist=0)


# ---------------------------------------------------------------------------
# degenerate corpora (the seed's empty-bucket / padding crash path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_docs", [1, 2, 5])
def test_small_corpus_nlist_exceeds_docs(n_docs):
    """nlist > n_docs must fit cleanly (effective nlist clamps to the
    corpus) and full-probe search must return every doc, no −1 ids."""
    rng = np.random.default_rng(3)
    docs = jnp.asarray(rng.standard_normal((n_docs, 32)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    ivf = IVFFlatIndex(nlist=16, nprobe=16, kmeans_iters=3).fit(docs)
    assert ivf.nlist == n_docs                 # clamped
    vals, ids = ivf.search(queries, 10)
    assert ids.shape == (4, n_docs)            # min(k, n_docs) columns
    assert np.all(np.asarray(ids) >= 0)
    _, want = DenseIndex(docs).search(queries, 10)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


def test_mutating_source_index_after_to_ivf_is_rejected():
    """to_ivf shares the source index's storage: growing the source
    afterwards must fail loudly, not silently miss the new docs."""
    rng = np.random.default_rng(11)
    docs = jnp.asarray(rng.standard_normal((100, 16)), jnp.float32)
    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5)])
    idx = CompressedIndex.build(docs, docs[:8], pipe)
    ivf = idx.to_ivf(nlist=4, nprobe=4, kmeans_iters=3)
    ivf.search(docs[:2], 3)                    # fine while in sync
    idx.add(jnp.asarray(rng.standard_normal((5, 16)), jnp.float32))
    with pytest.raises(ValueError, match="changed since to_ivf"):
        ivf.search(docs[:2], 3)
    ivf.fit(docs)                              # refit owns fresh storage
    ivf.search(docs[:2], 3)


def test_refit_on_larger_corpus_restores_requested_nlist():
    """The per-fit nlist clamp must not stick: a small first fit followed
    by a refit on a big corpus gets the configured list count back."""
    rng = np.random.default_rng(9)
    ivf = IVFFlatIndex(nlist=16, nprobe=16, kmeans_iters=3)
    ivf.fit(jnp.asarray(rng.standard_normal((3, 8)), jnp.float32))
    assert ivf.nlist == 3
    ivf.fit(jnp.asarray(rng.standard_normal((200, 8)), jnp.float32))
    assert ivf.nlist == 16


def test_partial_probe_pads_unreachable_slots():
    """With a deliberately narrow probe the candidate pool can be smaller
    than k: those slots must come back as (−inf, −1), not garbage."""
    rng = np.random.default_rng(4)
    docs = jnp.asarray(rng.standard_normal((40, 16)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((3, 16)), jnp.float32)
    ivf = IVFFlatIndex(nlist=20, nprobe=1, kmeans_iters=5).fit(docs)
    vals, ids = ivf.search(queries, 10)
    vals, ids = np.asarray(vals), np.asarray(ids)
    assert ids.shape == (3, 10)
    assert np.all((ids >= 0) == np.isfinite(vals))
    assert np.all(np.isneginf(vals[ids < 0]))


def test_empty_corpus_raises():
    with pytest.raises(ValueError):
        IVFFlatIndex(nlist=4).fit(jnp.zeros((0, 8), jnp.float32))


def test_add_routes_to_existing_centroids(kb):
    docs = kb.docs[:600]
    ivf = IVFFlatIndex(nlist=8, nprobe=8, kmeans_iters=5).fit(docs[:500])
    ivf.add(docs[500:])
    assert len(ivf) == 600
    _, want = DenseIndex(docs).search(kb.queries[:8], 5)
    _, got = ivf.search(kb.queries[:8], 5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# property-based invariants (skip cleanly without hypothesis)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_recall_monotone_in_nprobe(seed):
    """recall@k vs exact is non-decreasing in nprobe: probe sets are nested
    (stable top-k prefix) and the (score, id) ranking is a total order."""
    rng = np.random.default_rng(seed)
    docs = jnp.asarray(rng.standard_normal((300, 32)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    _, want = DenseIndex(docs).search(queries, 5)
    ivf = IVFIndex(nlist=8, nprobe=8, kmeans_iters=5).fit(docs)
    recalls = [_recall(ivf.search(queries, 5, nprobe=p)[1], want)
               for p in (1, 2, 4, 8)]
    assert recalls == sorted(recalls)


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_full_probe_reproduces_exact_rankings(seed):
    """nprobe == nlist equals exact search on ties-free inputs."""
    rng = np.random.default_rng(seed)
    docs = jnp.asarray(rng.standard_normal((200, 24)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((6, 24)), jnp.float32)
    scores = np.asarray(queries @ docs.T)
    top = -np.sort(-scores, axis=1)[:, :7]
    assume(float(np.min(np.abs(np.diff(top, axis=1)))) > 1e-4)  # ties-free
    _, want = DenseIndex(docs).search(queries, 6)
    ivf = IVFIndex(nlist=6, nprobe=6, kmeans_iters=5).fit(docs)
    _, got = ivf.search(queries, 6)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
