"""Index.add() across all five index classes.

Contract under test: adding docs to a live index (through its *already
fitted* pipeline) must rank identically to building an index over the
concatenated corpus with the same fitted pipeline — per scorer backend —
and ``add`` on a ``load_index``-restored artifact must round-trip through
``save_index``/``load_index``.

The sharded classes run in a subprocess with forced host devices (same
pattern as tests/test_sharded_index.py).
"""

import copy
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CenterNorm, CompressionPipeline, FloatCast,
                        Int8Quantizer, OneBitQuantizer, PCA)
from repro.retrieval import load_index
from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFIndex

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

D = 48
K = 9
BACKEND_TAILS = {
    "float": [],
    "fp16": [FloatCast()],
    "int8": [Int8Quantizer()],
    "onebit": [OneBitQuantizer(0.5)],
}


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    return {
        "base": jnp.asarray(rng.standard_normal((240, D)), jnp.float32),
        "more": jnp.asarray(rng.standard_normal((70, D)), jnp.float32),
        "queries": jnp.asarray(rng.standard_normal((11, D)), jnp.float32),
    }


def make_pipeline(backend):
    return CompressionPipeline([CenterNorm(), PCA(24)] +
                               copy.deepcopy(BACKEND_TAILS[backend]))


def assert_same_ranking(a, b, rtol=1e-5, atol=1e-6):
    (va, ia), (vb, ib) = a, b
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# DenseIndex
# ---------------------------------------------------------------------------


def test_dense_add_matches_concat_build(data):
    idx = DenseIndex(data["base"]).add(data["more"])
    ref = DenseIndex(jnp.concatenate([data["base"], data["more"]]))
    assert len(idx) == 310
    assert_same_ranking(idx.search(data["queries"], K),
                        ref.search(data["queries"], K))


def test_dense_add_on_loaded_artifact_round_trips(tmp_path, data):
    path = str(tmp_path / "dense.npz")
    DenseIndex(data["base"]).save(path)
    loaded = load_index(path).add(data["more"])
    ref = DenseIndex(jnp.concatenate([data["base"], data["more"]]))
    assert_same_ranking(loaded.search(data["queries"], K),
                        ref.search(data["queries"], K))
    path2 = str(tmp_path / "dense2.npz")
    loaded.save(path2)
    assert_same_ranking(load_index(path2).search(data["queries"], K),
                        loaded.search(data["queries"], K), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# CompressedIndex, per scorer backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(BACKEND_TAILS))
def test_compressed_add_matches_concat_build(data, backend):
    pipe = make_pipeline(backend)
    idx = CompressedIndex.build(data["base"], data["queries"], pipe,
                                backend="jnp")
    idx.add(data["more"])
    # same *fitted* pipeline, one encode over the concatenated corpus
    ref = CompressedIndex(pipe, backend="jnp")
    ref.add(jnp.concatenate([data["base"], data["more"]]))
    assert len(idx) == len(ref) == 310
    assert_same_ranking(idx.search(data["queries"], K),
                        ref.search(data["queries"], K))


@pytest.mark.parametrize("backend", sorted(BACKEND_TAILS))
def test_compressed_add_on_loaded_artifact_round_trips(tmp_path, data,
                                                       backend):
    pipe = make_pipeline(backend)
    built = CompressedIndex.build(data["base"], data["queries"], pipe,
                                  backend="jnp")
    path = str(tmp_path / "c.npz")
    built.save(path)
    loaded = load_index(path)
    loaded.add(data["more"])
    built.add(data["more"])
    assert_same_ranking(loaded.search(data["queries"], K),
                        built.search(data["queries"], K), rtol=0, atol=0)
    path2 = str(tmp_path / "c2.npz")
    loaded.save(path2)
    again = load_index(path2)
    assert len(again) == 310
    assert_same_ranking(again.search(data["queries"], K),
                        loaded.search(data["queries"], K), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# IVFIndex: add routes to the existing centroids; full probe == exact
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("backend", sorted(BACKEND_TAILS))
def test_ivf_add_full_probe_matches_exact_concat(data, backend):
    pipe = make_pipeline(backend)
    ivf = IVFIndex.build(data["base"], data["queries"], pipe, nlist=12,
                         nprobe=4, backend="jnp", kmeans_iters=4)
    ivf.add(data["more"])
    assert len(ivf) == 310
    ref = CompressedIndex(pipe, backend="jnp")
    ref.add(jnp.concatenate([data["base"], data["more"]]))
    # probing every list makes IVF exhaustive: must equal exact search
    assert_same_ranking(ivf.search(data["queries"], K, nprobe=ivf.nlist),
                        ref.search(data["queries"], K))


@pytest.mark.slow
def test_ivf_add_on_loaded_artifact_round_trips(tmp_path, data):
    pipe = make_pipeline("int8")
    built = IVFIndex.build(data["base"], data["queries"], pipe, nlist=12,
                           nprobe=5, backend="jnp", kmeans_iters=4)
    path = str(tmp_path / "ivf.npz")
    built.save(path)
    loaded = load_index(path)
    loaded.add(data["more"])
    built.add(data["more"])
    # identical centroids (loaded from the artifact) → identical routing
    assert_same_ranking(loaded.search(data["queries"], K),
                        built.search(data["queries"], K), rtol=0, atol=0)
    path2 = str(tmp_path / "ivf2.npz")
    loaded.save(path2)
    assert_same_ranking(load_index(path2).search(data["queries"], K),
                        loaded.search(data["queries"], K), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# sharded classes (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

_CHECK_SHARDED = """
    import copy, os, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (CenterNorm, CompressionPipeline, FloatCast,
                            Int8Quantizer, OneBitQuantizer, PCA)
    from repro.launch.mesh import make_test_mesh
    from repro.retrieval import (CompressedIndex, IVFIndex,
                                 ShardedCompressedIndex, ShardedIVFIndex,
                                 load_index)

    rng = np.random.default_rng(7)
    base = jnp.asarray(rng.standard_normal((240, 48)), jnp.float32)
    more = jnp.asarray(rng.standard_normal((70, 48)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((11, 48)), jnp.float32)
    mesh = make_test_mesh(8, model=8)
    tails = {"float": [], "fp16": [FloatCast()],
             "int8": [Int8Quantizer()], "onebit": [OneBitQuantizer(0.5)]}

    for name, tail in tails.items():
        p1 = CompressionPipeline([CenterNorm(), PCA(24)] + copy.deepcopy(tail))
        p2 = CompressionPipeline([CenterNorm(), PCA(24)] + copy.deepcopy(tail))
        sharded = ShardedCompressedIndex.build(base, queries, p1, mesh,
                                               backend="jnp")
        sharded.add(more)
        single = CompressedIndex.build(base, queries, p2, backend="jnp")
        single.add(more)
        v1, i1 = single.search(queries, 9)
        v2, i2 = sharded.search(queries, 9)
        ok = (np.array_equal(np.asarray(i1), np.asarray(i2)) and
              np.allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5,
                          atol=1e-5))
        # add on a loaded sharded artifact round-trips
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "s.npz")
            sharded.save(path)
            back = load_index(path, mesh=mesh)
            back.add(more)
            sharded.add(more)
            v3, i3 = sharded.search(queries, 9)
            v4, i4 = back.search(queries, 9)
            ok_rt = (np.array_equal(np.asarray(i3), np.asarray(i4)) and
                     np.allclose(np.asarray(v3), np.asarray(v4)))
        print(f"SHARDED {name} add={ok} roundtrip={ok_rt}")

    # ShardedIVFIndex: in-place add refuses; the documented path is
    # ivf.add + re-wrap, and it must match the single-host ranking
    pipe = CompressionPipeline([CenterNorm(), PCA(24), Int8Quantizer()])
    ivf = IVFIndex.build(base, queries, pipe, nlist=12, nprobe=5,
                         backend="jnp", kmeans_iters=4)
    siv = ShardedIVFIndex(ivf, mesh)
    try:
        siv.add(more)
        print("SHARDED_IVF add_raises=False")
    except NotImplementedError:
        ivf.add(more)
        try:
            siv.search(queries, 9)          # stale wrapper must refuse
            stale_guard = False
        except ValueError:
            stale_guard = True
        rewrapped = ShardedIVFIndex(ivf, mesh)
        v1, i1 = ivf.search(queries, 9)
        v2, i2 = rewrapped.search(queries, 9)
        ok = (np.array_equal(np.asarray(i1), np.asarray(i2)) and
              np.allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5,
                          atol=1e-5))
        print(f"SHARDED_IVF add_raises=True stale_guard={stale_guard} "
              f"rewrap={ok}")
"""


@pytest.fixture(scope="module")
def sharded_output():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHECK_SHARDED)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("backend", sorted(BACKEND_TAILS))
def test_sharded_compressed_add_parity(sharded_output, backend):
    assert f"SHARDED {backend} add=True roundtrip=True" in sharded_output


@pytest.mark.slow
def test_sharded_ivf_add_rewrap_parity(sharded_output):
    assert ("SHARDED_IVF add_raises=True stale_guard=True rewrap=True"
            in sharded_output)
