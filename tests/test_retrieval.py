import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CenterNorm, CompressionPipeline, Int8Quantizer,
                        OneBitQuantizer, PCA)
from repro.data import make_dpr_like_kb
from repro.retrieval import (CompressedIndex, DenseIndex, IVFFlatIndex,
                             r_precision, topk_search)
from repro.retrieval.topk import merge_topk, similarity


@pytest.fixture(scope="module")
def kb():
    return make_dpr_like_kb(n_queries=100, n_docs=4000, d=128, r_eff=48)


def test_topk_matches_bruteforce(kb):
    q = kb.queries[:10]
    scores = np.asarray(similarity(q, kb.docs, "ip"))
    want = np.argsort(-scores, axis=1)[:, :5]
    vals, idx = topk_search(q, kb.docs, 5, doc_chunk=700)
    np.testing.assert_array_equal(np.asarray(idx), want)


def test_topk_l2(kb):
    q = kb.queries[:5]
    d2 = np.asarray(similarity(q, kb.docs, "l2"))
    want = np.argsort(-d2, axis=1)[:, :3]
    _, idx = topk_search(q, kb.docs, 3, sim="l2", doc_chunk=1000)
    np.testing.assert_array_equal(np.asarray(idx), want)


def test_merge_topk_associative():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((4, 20)), jnp.float32)
    i = jnp.arange(20)[None, :].repeat(4, 0)
    va, ia = merge_topk(v[:, :10], i[:, :10], v[:, 10:], i[:, 10:], 5)
    vb, ib = merge_topk(v[:, 10:], i[:, 10:], v[:, :10], i[:, :10], 5)
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb))


def test_r_precision_perfect_and_zero():
    docs = jnp.eye(4, dtype=jnp.float32)
    queries = jnp.eye(4, dtype=jnp.float32)
    rel = np.arange(4, dtype=np.int32)[:, None]
    assert r_precision(queries, docs, rel, "ip") == 1.0
    rel_wrong = ((np.arange(4) + 1) % 4).astype(np.int32)[:, None]
    assert r_precision(queries, docs, rel_wrong, "ip") == 0.0


def test_dense_index(kb):
    idx = DenseIndex(kb.docs)
    vals, ids = idx.search(kb.queries[:8], 4)
    assert ids.shape == (8, 4)
    assert np.all(np.diff(np.asarray(vals), axis=1) <= 1e-6)


def test_compressed_index_int8_matches_float_pipeline(kb):
    pipe = CompressionPipeline([CenterNorm(), PCA(32), CenterNorm(),
                                Int8Quantizer()])
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    vals, ids = idx.search(kb.queries[:16], 8)
    # oracle: ASYMMETRIC scoring — docs dequantized, queries through the
    # float stages only (the index never quantizes queries)
    d = pipe.transform(kb.docs, "docs")            # includes quant→dequant
    q = idx.encode_queries(kb.queries[:16])
    _, want = topk_search(q, d, 8)
    overlap = np.mean([len(set(np.asarray(ids)[i]) &
                           set(np.asarray(want)[i])) / 8
                       for i in range(16)])
    assert overlap > 0.97        # < 1.0 only via float ties at the k-cut
    assert idx.nbytes == 4000 * 32                  # 16× smaller (128→32+int8)


def test_compressed_index_onebit(kb):
    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5)])
    idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    vals, ids = idx.search(kb.queries[:8], 4)
    assert ids.shape == (8, 4)
    assert idx.nbytes == 4000 * 128 // 8            # exactly 32× smaller


def test_compressed_index_pallas_backend_agrees(kb):
    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5)])
    a = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    b = CompressedIndex.build(kb.docs, kb.queries,
                              CompressionPipeline([CenterNorm(),
                                                   OneBitQuantizer(0.5)]),
                              backend="pallas")
    _, ia = a.search(kb.queries[:8], 5)
    _, ib = b.search(kb.queries[:8], 5)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


@pytest.mark.slow
def test_ivf_recall(kb):
    exact = DenseIndex(kb.docs)
    _, want = exact.search(kb.queries[:32], 10)
    ivf = IVFFlatIndex(nlist=32, nprobe=16).fit(kb.docs)
    _, got = ivf.search(kb.queries[:32], 10)
    recall = np.mean([len(set(np.asarray(got)[i]) & set(np.asarray(want)[i]))
                      / 10 for i in range(32)])
    assert recall > 0.8


@pytest.mark.slow
def test_ivf_full_probe_is_exact(kb):
    exact = DenseIndex(kb.docs)
    _, want = exact.search(kb.queries[:16], 5)
    ivf = IVFFlatIndex(nlist=16, nprobe=16).fit(kb.docs)
    _, got = ivf.search(kb.queries[:16], 5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
