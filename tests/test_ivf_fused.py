"""Fused IVF hot path: interpret-mode Pallas vs jnp-reference parity across
all scorer backends (alone and under SegmentedIndex delta layers), streaming
blockwise top-k properties, and the recall satellites (residual encoding,
learned rotation, kmeans++ / balanced lists)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (CenterNorm, CompressionPipeline, LearnedRotation,
                        OneBitQuantizer, PCA, build_method)
from repro.data import make_dpr_like_kb
from repro.retrieval import (CompressedIndex, IVFIndex, SegmentedIndex,
                             backend_tail_stages, recall_at_k)
from repro.retrieval.kmeans import assign, assign_balanced, kmeans_fit
from repro.retrieval.topk import (masked_topk_by_id, resolve_nprobe,
                                  streaming_masked_topk)

BACKENDS = tuple(backend_tail_stages())


@pytest.fixture(scope="module")
def kb():
    return make_dpr_like_kb(n_queries=32, n_docs=1200, d=64, r_eff=24)


def _build_fused(kb, backend, **kw):
    tail = backend_tail_stages()[backend]
    pipe = CompressionPipeline([CenterNorm(), PCA(32)] + tail)
    idx = IVFIndex.build(kb.docs, kb.queries, pipe, nlist=24, nprobe=6,
                         backend="pallas", kmeans_iters=6, **kw)
    assert idx._use_fused_kernel
    return idx


def _ref_search(idx, queries, k, nprobe=None):
    """Same index, searched through the interpret-mode jnp reference."""
    idx._fused_reference_only = True
    idx._search_fn = None
    try:
        return idx.search(queries, k, nprobe=nprobe)
    finally:
        idx._fused_reference_only = False
        idx._search_fn = None


# ---------------------------------------------------------------------------
# fused kernel ≡ reference, bitwise, per backend and at any nprobe
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("nprobe", [1, 5, 24])
def test_fused_matches_reference_bitwise(kb, backend, nprobe):
    """The fused Pallas kernel (interpret mode on CPU) must reproduce the
    jnp reference mirror *bit-identically* — both ids and scores — for
    every scorer backend, from a single probed list up to full probe."""
    idx = _build_fused(kb, backend)
    q = kb.queries[:16]
    vals_p, ids_p = idx.search(q, 10, nprobe=nprobe)
    vals_r, ids_r = _ref_search(idx, q, 10, nprobe=nprobe)
    np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_r))
    np.testing.assert_array_equal(np.asarray(vals_p), np.asarray(vals_r))


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_agrees_with_jnp_path(kb, backend):
    """Cross-path sanity: the fused kernel ranks (nearly) the same docs as
    the streaming jnp path on the same fitted index.  Exact id equality is
    *not* required here — int8 scores in bf16 inside the kernel while the
    jnp oracle decodes to f32, so near-ties may flip — but scores must
    agree to tolerance and the candidate sets must overlap heavily."""
    idx = _build_fused(kb, backend)
    jnp_view = IVFIndex(idx.pipeline, nlist=idx.nlist, nprobe=idx.nprobe,
                        backend="jnp")
    jnp_view.load_state_dict(idx.state_dict())
    q = kb.queries[:16]
    vals_p, ids_p = idx.search(q, 10, nprobe=8)
    vals_j, ids_j = jnp_view.search(q, 10, nprobe=8)
    np.testing.assert_allclose(np.asarray(vals_p), np.asarray(vals_j),
                               rtol=1e-2, atol=1e-2)
    overlap = np.mean([len(set(a) & set(b)) / len(a)
                       for a, b in zip(np.asarray(ids_p), np.asarray(ids_j))])
    assert overlap >= 0.9


def test_fused_full_probe_matches_exact(kb):
    """nprobe == nlist through the fused float kernel reproduces exact
    search rankings (every doc reachable, shared tie order)."""
    pipe = CompressionPipeline([CenterNorm(), PCA(32)])
    exact = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    _, want = exact.search(kb.queries[:16], 10)
    ivf = IVFIndex(pipe, nlist=16, nprobe=16, backend="pallas",
                   kmeans_iters=6)
    ivf.fit(kb.docs)
    assert ivf._use_fused_kernel
    _, got = ivf.search(kb.queries[:16], 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# parity through SegmentedIndex delta layers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("nprobe", [3, 20])
def test_segmented_delta_parity(kb, backend, nprobe):
    """Fused vs reference stays bit-identical when the IVF main sits under
    SegmentedIndex delta segments and tombstones: the delta layer scores
    through the same jnp path either way, so any divergence isolates the
    kernel."""
    base = np.asarray(kb.docs)
    tail = backend_tail_stages()[backend]
    pipe = CompressionPipeline([CenterNorm(), PCA(32)] + tail)
    main = IVFIndex.build(base[:1000], kb.queries, pipe, nlist=20, nprobe=6,
                          backend="pallas", kmeans_iters=6)
    assert main._use_fused_kernel
    seg = SegmentedIndex(main)
    seg.add(base[1000:1100])
    seg.add(base[1100:])
    seg.delete([3, 17, 1005])
    q = kb.queries[:16]
    vals_p, ids_p = seg.search(q, 10, nprobe=nprobe)
    main._fused_reference_only = True
    main._search_fn = None
    try:
        vals_r, ids_r = seg.search(q, 10, nprobe=nprobe)
    finally:
        main._fused_reference_only = False
        main._search_fn = None
    np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_r))
    np.testing.assert_array_equal(np.asarray(vals_p), np.asarray(vals_r))


# ---------------------------------------------------------------------------
# streaming blockwise top-k ≡ monolithic top-k (any block size)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 60), st.integers(1, 12), st.integers(1, 4),
       st.integers(0, 10_000))
def test_streaming_topk_matches_monolithic(block, k, n_q, seed):
    """The strict (score desc, id asc) order is total, so folding blocks
    into a running top-k is associative: any block size must reproduce the
    monolithic result exactly, pads and −inf included."""
    rng = np.random.default_rng(seed)
    n = 37
    s = rng.standard_normal((n_q, n)).astype(np.float32)
    ids = rng.integers(0, 500, (n_q, n)).astype(np.int32)
    s[rng.random((n_q, n)) < 0.2] = -np.inf     # invalid / padded slots
    want_v, want_i = masked_topk_by_id(jnp.asarray(s), jnp.asarray(ids), k)
    got_v, got_i = streaming_masked_topk(jnp.asarray(s), jnp.asarray(ids),
                                         k, block)
    np.testing.assert_array_equal(np.asarray(want_i), np.asarray(got_i))
    np.testing.assert_array_equal(np.asarray(want_v), np.asarray(got_v))


@pytest.mark.parametrize("block", [1, 2, 3, 5, 8, 36, 37, 50])
def test_streaming_topk_block_sweep(block):
    """Deterministic counterpart of the hypothesis property (runs even
    without hypothesis installed): every block size, including 1, a
    non-divisor, the exact width, and an over-width block."""
    rng = np.random.default_rng(7)
    s = rng.standard_normal((3, 37)).astype(np.float32)
    ids = rng.integers(0, 200, (3, 37)).astype(np.int32)
    s[rng.random((3, 37)) < 0.25] = -np.inf
    want_v, want_i = masked_topk_by_id(jnp.asarray(s), jnp.asarray(ids), 9)
    got_v, got_i = streaming_masked_topk(jnp.asarray(s), jnp.asarray(ids),
                                         9, block)
    np.testing.assert_array_equal(np.asarray(want_i), np.asarray(got_i))
    np.testing.assert_array_equal(np.asarray(want_v), np.asarray(got_v))


def test_streaming_topk_rejects_bad_block():
    s = jnp.zeros((2, 8))
    ids = jnp.arange(16, dtype=jnp.int32).reshape(2, 8)
    with pytest.raises(ValueError, match="block"):
        streaming_masked_topk(s, ids, 3, 0)


def test_resolve_nprobe_semantics():
    assert resolve_nprobe(None, 16, default=7) == 7
    assert resolve_nprobe(100, 16) == 16           # clamps to nlist
    assert resolve_nprobe(3, 16) == 3
    with pytest.raises(ValueError, match="nprobe must be ≥ 1"):
        resolve_nprobe(0, 16)


# ---------------------------------------------------------------------------
# residual encoding
# ---------------------------------------------------------------------------


def test_residual_float_full_probe_is_exact(kb):
    """Float residual storage is mathematically exact: q·(x−c) + q·c = q·x,
    so full probe must reproduce exact search bit-for-bit on the jnp path
    and id-for-id on the fused path."""
    pipe = CompressionPipeline([CenterNorm(), PCA(32)])
    exact = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    _, want = exact.search(kb.queries[:16], 10)
    for backend in ("jnp", "pallas"):
        ivf = IVFIndex(pipe, nlist=16, nprobe=16, backend=backend,
                       kmeans_iters=6, residual=True)
        ivf.fit(kb.docs)
        _, got = ivf.search(kb.queries[:16], 10)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_residual_quantized_search_and_roundtrip(kb):
    """Quantized residual IVF searches, persists, and survives add()."""
    pipe = CompressionPipeline([CenterNorm(), PCA(32), OneBitQuantizer(0.5)])
    ivf = IVFIndex(pipe, nlist=16, nprobe=8, backend="jnp", kmeans_iters=6,
                   residual=True)
    base = np.asarray(kb.docs)
    pipe.fit(base[:1000], kb.queries)
    ivf.fit(base[:1000])
    v0, i0 = ivf.search(kb.queries[:8], 5)
    assert np.all(np.asarray(i0) >= 0)
    sd = ivf.state_dict()
    ivf2 = IVFIndex(pipe, backend="jnp").load_state_dict(sd)
    assert ivf2.residual
    v1, i1 = ivf2.search(kb.queries[:8], 5)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    ivf.add(base[1000:])
    v2, i2 = ivf.search(kb.queries[:8], 5)
    assert len(ivf) == base.shape[0]
    assert np.all(np.asarray(i2) >= 0)


def test_residual_guards(kb):
    with pytest.raises(ValueError, match="IP-only"):
        IVFIndex(None, sim="l2", residual=True)
    pipe = CompressionPipeline([CenterNorm(), PCA(32)])
    ivf = IVFIndex(pipe, nlist=8, backend="jnp", residual=True)
    pipe.fit(kb.docs, kb.queries)
    x = pipe(kb.docs, "docs")
    with pytest.raises(ValueError, match="pre-encoded"):
        ivf._install(x, x)
    ivf.fit(kb.docs)
    with pytest.raises(TypeError, match="residual"):
        SegmentedIndex(ivf)


# ---------------------------------------------------------------------------
# learned rotation (OPQ-style) before 1-bit quantization
# ---------------------------------------------------------------------------


def test_learned_rotation_is_orthogonal_and_ip_preserving(kb):
    rot = LearnedRotation(n_iters=5)
    rot.fit(kb.docs)
    r = np.asarray(rot.state["rotation"])
    np.testing.assert_allclose(r @ r.T, np.eye(r.shape[0]), atol=1e-4)
    q = np.asarray(kb.queries[:8], np.float32)
    x = np.asarray(kb.docs[:64], np.float32)
    want = q @ x.T
    got = np.asarray(rot(jnp.asarray(q), "queries")) @ \
        np.asarray(rot(jnp.asarray(x), "docs")).T
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_pca_rot_onebit_recall_at_least_pca_onebit():
    """The registry's pca_rot_onebit method must not lose recall vs plain
    pca_onebit — the rotation re-aims the sign grid after PCA concentrates
    variance on few axes, and is free at search time (orthogonal)."""
    kb = make_dpr_like_kb(n_queries=48, n_docs=2500, d=64, r_eff=24)
    from repro.retrieval import DenseIndex
    dense = DenseIndex(kb.docs)
    _, want = dense.search(kb.queries, 10)
    recalls = {}
    for method in ("pca_onebit", "pca_rot_onebit"):
        pipe = build_method(method, dim=24, post=False)
        idx = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
        _, got = idx.search(kb.queries, 10)
        recalls[method] = recall_at_k(got, want)
    assert recalls["pca_rot_onebit"] >= recalls["pca_onebit"]


# ---------------------------------------------------------------------------
# kmeans++ seeding and balanced list assignment
# ---------------------------------------------------------------------------


def test_kmeanspp_seeding_shapes_and_guard():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((600, 16)), jnp.float32)
    c = kmeans_fit(x, 12, 5, jax.random.PRNGKey(0), init="++")
    assert c.shape == (12, 16)
    assert bool(jnp.all(jnp.isfinite(c)))
    with pytest.raises(ValueError, match="init"):
        kmeans_fit(x, 4, 2, init="nope")


def test_balanced_assignment_caps_list_skew():
    rng = np.random.default_rng(3)
    # deliberately skewed corpus: one heavy cluster plus background noise
    heavy = rng.standard_normal((1500, 32)) * 0.05 + 2.0
    rest = rng.standard_normal((1500, 32))
    x = jnp.asarray(np.concatenate([heavy, rest]), jnp.float32)
    c = kmeans_fit(x, 16, 8, jax.random.PRNGKey(0))
    plain = np.bincount(np.asarray(assign(x, c)), minlength=16)
    bal = np.bincount(np.asarray(assign_balanced(x, c)), minlength=16)
    assert bal.sum() == plain.sum() == x.shape[0]
    assert bal.max() <= plain.max()


def test_ivf_with_quality_options_full_probe_exact(kb):
    """kmeans++ + balanced lists change *which* list holds a doc, never
    which docs are reachable at full probe: still exact."""
    pipe = CompressionPipeline([CenterNorm(), PCA(32)])
    exact = CompressedIndex.build(kb.docs, kb.queries, pipe, backend="jnp")
    _, want = exact.search(kb.queries[:16], 10)
    ivf = IVFIndex(pipe, nlist=16, nprobe=16, backend="jnp", kmeans_iters=6,
                   kmeans_init="++", balanced=True)
    ivf.fit(kb.docs)
    _, got = ivf.search(kb.queries[:16], 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    sd = ivf.state_dict()
    assert sd["kmeans_init"] == "++" and sd["balanced"]
