import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pca import (PCA, PAPER_COMPONENT_SCALES,
                            covariance_from_moments, fit_pca_from_cov,
                            moments)


@pytest.fixture
def aniso():
    rng = np.random.default_rng(1)
    # anisotropic: strong variance in 4 latent dirs, weak elsewhere
    z = rng.standard_normal((500, 4)).astype(np.float32) * [10, 5, 2, 1]
    mix = rng.standard_normal((4, 32)).astype(np.float32)
    x = z @ mix + 0.05 * rng.standard_normal((500, 32)).astype(np.float32)
    return jnp.asarray(x + 2.0)       # non-centered


def test_components_orthonormal(aniso):
    pca = PCA(8).fit(aniso)
    w = np.asarray(pca.state["components"])
    np.testing.assert_allclose(w.T @ w, np.eye(8), atol=1e-4)


def test_eigenvalues_descending(aniso):
    pca = PCA(8).fit(aniso)
    ev = np.asarray(pca.state["eigenvalues"])
    assert np.all(np.diff(ev) <= 1e-5)


def test_reconstruction_captures_variance(aniso):
    pca = PCA(4).fit(aniso)
    z = pca(aniso)
    rec = pca.inverse(z)
    x = np.asarray(aniso)
    resid = np.mean((np.asarray(rec) - x) ** 2)
    total = np.mean((x - x.mean(0)) ** 2)
    assert resid / total < 0.01       # 4 latent dims → near-lossless


def test_full_rank_pca_preserves_distances(aniso):
    """d' = d: PCA is a rotation+shift — pairwise IP of centered data kept."""
    pca = PCA(32).fit(aniso)
    z = pca(aniso)
    x = np.asarray(aniso) - np.asarray(pca.state["mean"])
    np.testing.assert_allclose(np.asarray(z @ z.T), x @ x.T,
                               rtol=2e-2, atol=2e-2)


def test_moments_accumulate_like_batch_fit(aniso):
    """Distributed fit contract: summed shard moments == full-data fit."""
    a, b = aniso[:200], aniso[200:]
    n1, s1, ss1 = moments(a)
    n2, s2, ss2 = moments(b)
    mean, cov = covariance_from_moments(n1 + n2, s1 + s2, ss1 + ss2)
    direct_mean, direct_cov = covariance_from_moments(*moments(aniso))
    np.testing.assert_allclose(np.asarray(mean), np.asarray(direct_mean),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(cov), np.asarray(direct_cov),
                               rtol=1e-3, atol=1e-4)

    p1 = PCA(4)
    p1.fit_from_moments(n1 + n2, s1 + s2, ss1 + ss2)
    p2 = PCA(4).fit(aniso)
    # eigenvectors defined up to sign
    w1, w2 = np.asarray(p1.state["components"]), np.asarray(
        p2.state["components"])
    cos = np.abs(np.sum(w1 * w2, axis=0))
    np.testing.assert_allclose(cos, 1.0, atol=1e-3)


def test_component_scaling(aniso):
    pca = PCA(8, scale_components="paper").fit(aniso)
    assert tuple(np.asarray(pca.state["scales"][:5])) == pytest.approx(
        PAPER_COMPONENT_SCALES)
    plain = PCA(8).fit(aniso)
    z_scaled = np.asarray(pca(aniso))
    z_plain = np.asarray(plain(aniso))
    # scaled projection = plain projection × per-component scale (up to sign)
    ratio = np.abs(z_scaled[:, 0]) / np.maximum(np.abs(z_plain[:, 0]), 1e-9)
    np.testing.assert_allclose(ratio, 0.5, rtol=1e-2)


def test_fit_on_subsample(aniso):
    pca = PCA(4, max_fit_samples=64).fit(aniso, rng=jax.random.PRNGKey(0))
    assert pca(aniso).shape == (500, 4)


def test_fit_on_queries_vs_docs(aniso):
    queries = aniso[:100] * 0.5
    for fit_on in ("docs", "queries", "both"):
        pca = PCA(4, fit_on=fit_on).fit(aniso, queries)
        assert pca(queries, "queries").shape == (100, 4)
    with pytest.raises(ValueError):
        PCA(4, fit_on="nonsense")
