import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import (PreemptionHandler, StragglerMonitor,
                                         with_retries)
from repro.train import optimizer as O
from repro.train import trainer


def _state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,))},
            "opt": (jnp.zeros(()),),
            "step": jnp.asarray(5, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(state, 5, blocking=True)
    restored = ck.restore(state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(), 1, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(_state(), s, blocking=True)
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]


def test_no_partial_checkpoints_visible(tmp_path):
    """Staged tmp dirs must never be listed as checkpoints."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(tmp_path / ".tmp-step_00000009")
    assert ck.all_steps() == []
    assert ck.latest_step() is None


def test_restore_missing_key_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(), 1, blocking=True)
    bigger = dict(_state())
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ck.restore(bigger)


def test_stale_latest_recovers(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(), 3, blocking=True)
    with open(tmp_path / "LATEST", "w") as f:
        f.write("99")              # points at a checkpoint that doesn't exist
    assert ck.latest_step() == 3


def test_resume_training_loop(tmp_path):
    """Kill training mid-run; resume reproduces the uninterrupted run."""
    tx = O.sgd(0.1)

    def loss(params, batch):
        l = jnp.sum(jnp.square(params["w"] - 4.0))
        return l, {}

    def fresh():
        return {"params": {"w": jnp.zeros((2,))},
                "opt": tx.init({"w": jnp.zeros((2,))}),
                "step": jnp.zeros((), jnp.int32)}

    step = jax.jit(trainer.make_train_step(loss, tx))

    # uninterrupted 10 steps
    s = fresh()
    for _ in range(10):
        s, _ = step(s, {})
    want = np.asarray(s["params"]["w"])

    # interrupted at 6 + resumed
    ck = Checkpointer(str(tmp_path))
    s = fresh()
    for _ in range(6):
        s, _ = step(s, {})
    ck.save(s, 6, blocking=True)
    restored = ck.restore(fresh())
    assert int(restored["step"]) == 6
    for _ in range(4):
        restored, _ = step(restored, {})
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), want,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_preemption_handler_stops_loop(tmp_path):
    tx = O.sgd(0.1)

    def loss(params, batch):
        return jnp.sum(params["w"]), {}

    state = trainer.init_state(jax.random.PRNGKey(0),
                               lambda _: {"w": jnp.ones((2,))}, tx)
    step = trainer.make_train_step(loss, tx)
    handler = PreemptionHandler(signals=())
    ck = Checkpointer(str(tmp_path))

    def batches():
        while True:
            yield {}

    handler.trigger()
    cfg = trainer.TrainLoopConfig(total_steps=50, log_every=0)
    state, _ = trainer.run_train_loop(step, state, batches(), cfg,
                                      checkpointer=ck, preemption=handler,
                                      log_fn=lambda *_: None)
    assert int(state["step"]) == 1          # stopped after first step
    assert ck.latest_step() == 1            # emergency checkpoint written


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=2)
    for _ in range(5):
        assert not mon.observe(0.1)
    assert mon.observe(0.5)                 # 5× slower → flagged
    assert mon.flagged


def test_with_retries():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise IOError("transient")
        return "ok"

    assert with_retries(flaky, retries=3, backoff=0.0,
                        log_fn=lambda *_: None) == "ok"
    assert len(calls) == 3

    def hard_fail():
        raise ValueError("logic error")

    with pytest.raises(ValueError):
        with_retries(hard_fail, retries=2, backoff=0.0,
                     log_fn=lambda *_: None)
