import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_NAMES, get_arch
from repro.data import batches as B
from repro.data.synthetic import add_distractors, make_dpr_like_kb


def test_kb_matches_paper_statistics():
    """Table 1: doc L2 12.3±0.6, query L2 9.3±0.2 (we match the ordering
    and magnitudes; exact values depend on noise knobs)."""
    kb = make_dpr_like_kb(n_queries=200, n_docs=5000)
    assert 10.0 < kb.meta["doc_l2"] < 16.0
    assert 8.0 < kb.meta["query_l2"] < 13.0
    assert kb.meta["query_l2"] < kb.meta["doc_l2"]      # queries more centered
    assert kb.meta["query_l1"] < kb.meta["doc_l1"]


def test_kb_deterministic():
    a = make_dpr_like_kb(n_queries=20, n_docs=100, seed=7)
    b = make_dpr_like_kb(n_queries=20, n_docs=100, seed=7)
    np.testing.assert_array_equal(np.asarray(a.docs), np.asarray(b.docs))
    c = make_dpr_like_kb(n_queries=20, n_docs=100, seed=8)
    assert not np.array_equal(np.asarray(a.docs), np.asarray(c.docs))


def test_kb_relevance_valid():
    kb = make_dpr_like_kb(n_queries=50, n_docs=500)
    rel = kb.relevant
    assert rel.shape == (50, 2)
    assert rel.min() >= 0 and rel.max() < 500
    # multi-hop: the two relevant docs differ
    assert np.all(rel[:, 0] != rel[:, 1])


def test_add_distractors():
    kb = make_dpr_like_kb(n_queries=20, n_docs=200)
    bigger = add_distractors(kb, 300)
    assert bigger.docs.shape == (500, 768)
    np.testing.assert_array_equal(np.asarray(bigger.docs[:200]),
                                  np.asarray(kb.docs))


@pytest.mark.parametrize("arch_name", ALL_NAMES)
def test_batches_match_specs(arch_name):
    arch = get_arch(arch_name)
    rng = np.random.default_rng(0)
    for shape in arch.shapes:
        specs = B.input_specs(arch, shape, reduced=True)
        batch = B.make_batch(rng, arch, shape, reduced=True)
        for k, s in specs.items():
            assert batch[k].shape == s.shape, (arch_name, shape.name, k)
            assert batch[k].dtype == s.dtype, (arch_name, shape.name, k)


def test_full_specs_have_production_dims():
    arch = get_arch("dbrx-132b")
    spec = B.input_specs(arch, arch.shape("train_4k"), reduced=False)
    assert spec["tokens"].shape == (256, 4096)
    spec = B.input_specs(arch, arch.shape("long_500k"), reduced=False)
    assert spec["tokens"].shape == (1,)
