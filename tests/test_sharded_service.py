"""RetrievalService over sharded indexes: bit-parity, atomic staging.

Acceptance contract (ISSUE 10): the front door serving a sharded index
returns results bit-identical to single-host — ids AND raw score bytes —
on every scorer backend, *including* through a mid-traffic ``update()``
and ``compact()``; multi-shard staging promotes all shards or none; the
stats rollup reports per-shard docs/lists/delta.

The parity matrix runs in a subprocess with forced host devices (the
``XLA_FLAGS`` flag must land before jax initialises, which the pytest
process is long past); parametrized tests assert on its per-backend
verdict lines.  The in-process tests cover the pieces that work on any
device count: shard=1 placement, the all-or-none staging seam
(``SHARD_PLACEMENT_HOOK``), register atomicity, and the typed stats
schema.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, SRC)

BACKENDS = ("float", "fp16", "int8", "onebit")

_CHECK_ALL = """
    import dataclasses
    import os
    import tempfile

    import numpy as np

    import repro.parallel.placement as placement
    from repro.retrieval.api import (IndexSpec, ShardSpec, build_index,
                                     save_index)
    from repro.serve import QueryOptions, RetrievalService

    rng = np.random.default_rng(0)
    docs = np.asarray(rng.standard_normal((515, 64)), np.float32)
    queries = np.asarray(rng.standard_normal((64, 64)), np.float32)
    extra = np.asarray(rng.standard_normal((24, 64)), np.float32)
    BASE = (("CenterNorm", {}), ("PCA", {"dim": 32}))
    TAILS = {"float": (), "fp16": (("FloatCast", {}),),
             "int8": (("Int8Quantizer", {}),),
             "onebit": (("OneBitQuantizer", {"offset": 0.5}),)}

    for name, tail in TAILS.items():
        spec = IndexSpec(stages=BASE + tail, ivf=(12, 6), backend="jnp",
                         mutable=True)
        svc = RetrievalService()
        svc.register("single", index=build_index(spec, docs, queries[:16]))
        svc.register("sharded", index=build_index(
            dataclasses.replace(spec, shard=ShardSpec(shards=4)),
            docs, queries[:16]))
        ok = True

        def check():
            global ok
            out = {}
            for ix in ("single", "sharded"):
                res = svc.query(queries[:12],
                                QueryOptions(index=ix, k=10)).result(
                                    timeout=600)
                out[ix] = (np.asarray(res.ids), res.scores.tobytes())
            ok &= np.array_equal(out["single"][0], out["sharded"][0])
            ok &= out["single"][1] == out["sharded"][1]

        check()                                     # clean stream
        for ix in ("single", "sharded"):            # live delta lands
            svc.update(ix, add=extra)
        for ix in ("single", "sharded"):
            svc.update(ix, delete=range(515, 527))
        check()
        for ix in ("single", "sharded"):            # fold + re-shard
            svc.compact(ix)
        check()
        stats = svc.stats()
        lost = (stats["requests_submitted"] - stats["requests_served"]
                + stats["queue_depth"])
        svc.close()
        print(f"BACKEND {name} parity={ok} lost={lost}")

    # all-or-none staging: shard 2 of 4 fails placement → registry
    # untouched; the retried stage promotes and serves identically
    spec = IndexSpec(stages=BASE + (("Int8Quantizer", {}),), ivf=(12, 6),
                     backend="jnp")
    idx = build_index(spec, docs, queries[:16])
    art = os.path.join(tempfile.mkdtemp(), "kb.npz")
    save_index(idx, art)
    svc = RetrievalService()
    svc.register("kb", artifact=art, shard=ShardSpec(shards=4))

    def hook(shard_id, n_shards):
        if shard_id == 2:
            raise RuntimeError("injected shard-2 placement failure")

    placement.SHARD_PLACEMENT_HOOK = hook
    failed = False
    try:
        svc.stage("kb", artifact=art, shard=ShardSpec(shards=4))
    except RuntimeError:
        failed = True
    placement.SHARD_PLACEMENT_HOOK = None
    st = svc.stats()["indexes"]["kb"]
    clean = (st["staged"] is None and st["live"] == 1
             and sorted(st["versions"]) == [1])
    vid = svc.stage("kb", artifact=art, shard=ShardSpec(shards=4))
    svc.promote("kb")
    res = svc.query(queries[:8],
                    QueryOptions(index="kb", k=10)).result(timeout=600)
    v0, i0 = idx.search(queries[:8], 10)
    same = (np.array_equal(np.asarray(i0), res.ids)
            and np.asarray(v0).tobytes() == res.scores.tobytes())
    rollup = svc.stats()["indexes"]["kb"]["versions"][vid].get("shards")
    svc.close()
    print(f"ATOMIC failed={failed} clean={clean} promoted_same={same} "
          f"shards={len(rollup or [])}")
"""


@pytest.fixture(scope="module")
def service_parity_output():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CHECK_ALL)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_service_bit_parity(service_parity_output, backend):
    """Sharded serving ≡ single-host in ids and score bytes, through a
    live update and a compaction, with zero lost requests."""
    assert f"BACKEND {backend} parity=True lost=0" in service_parity_output


@pytest.mark.slow
def test_multi_shard_promote_all_or_none(service_parity_output):
    """One failing shard aborts the whole stage (registry untouched); the
    retried stage promotes and serves the same bytes as the artifact."""
    assert ("ATOMIC failed=True clean=True promoted_same=True shards=4"
            in service_parity_output)


# ---------------------------------------------------------------------------
# in-process: placement seam, register atomicity, typed stats
# ---------------------------------------------------------------------------


@pytest.fixture()
def corpus():
    rng = np.random.default_rng(7)
    docs = rng.standard_normal((200, 32)).astype(np.float32)
    queries = rng.standard_normal((8, 32)).astype(np.float32)
    return docs, queries


@pytest.fixture()
def artifact(tmp_path, corpus):
    from repro.retrieval.api import IndexSpec, build_index, save_index
    docs, queries = corpus
    idx = build_index(IndexSpec(method="int8", backend="jnp", post=False),
                      docs, queries)
    path = str(tmp_path / "kb.npz")
    save_index(idx, path)
    return path, idx


def test_register_shard_places_and_rolls_up(artifact, corpus):
    from repro.retrieval.api import ShardSpec
    from repro.serve import QueryOptions, RetrievalService
    path, idx = artifact
    _, queries = corpus
    with RetrievalService(start=False) as svc:
        svc.register("kb", artifact=path, shard=ShardSpec(shards=1))
        h = svc.query(queries, QueryOptions(index="kb", k=5))
        svc.drain_once()
        res = h.result(timeout=30)
        want_v, want_i = idx.search(queries, 5)
        np.testing.assert_array_equal(res.ids, np.asarray(want_i))
        assert res.scores.tobytes() == np.asarray(want_v).tobytes()
        row = svc.stats()["indexes"]["kb"]["versions"][1]
        assert [s["n_docs"] for s in row["shards"]] == [len(idx)]


def test_register_failure_leaves_registry_clean(tmp_path):
    from repro.serve import RetrievalService
    with RetrievalService(start=False) as svc:
        with pytest.raises(Exception):
            svc.register("kb", artifact=str(tmp_path / "missing.npz"))
        assert svc.indexes() == []
        with pytest.raises(ValueError, match="exactly one"):
            svc.register("kb")                 # neither index nor artifact
        assert svc.indexes() == []


def test_stage_placement_failure_is_all_or_none(artifact):
    import repro.parallel.placement as placement
    from repro.retrieval.api import ShardSpec
    from repro.serve import RetrievalService
    path, _ = artifact
    sh = ShardSpec(shards=1)
    with RetrievalService(start=False) as svc:
        svc.register("kb", artifact=path, shard=sh)
        before = svc.stats()["indexes"]["kb"]

        def hook(shard_id, n_shards):
            raise RuntimeError("injected placement failure")

        placement.SHARD_PLACEMENT_HOOK = hook
        try:
            with pytest.raises(RuntimeError, match="injected"):
                svc.stage("kb", artifact=path, shard=sh)
        finally:
            placement.SHARD_PLACEMENT_HOOK = None
        after = svc.stats()["indexes"]["kb"]
        assert after["staged"] is None
        assert after["live"] == before["live"]
        assert sorted(after["versions"]) == sorted(before["versions"])
        # the seam clears → the same stage succeeds and promotes
        svc.stage("kb", artifact=path, shard=sh)
        assert svc.promote("kb") == 3          # vid 2 was burned by the abort


def test_stats_typed_matches_dict_shape(artifact, corpus):
    from repro.retrieval.api import ShardSpec
    from repro.serve import (QueryOptions, RetrievalService, ServiceStats,
                             ShardStats, VersionStats)
    path, _ = artifact
    _, queries = corpus
    with RetrievalService(start=False) as svc:
        svc.register("kb", artifact=path, shard=ShardSpec(shards=1))
        h = svc.query(queries, QueryOptions(index="kb", k=5))
        svc.drain_once()
        h.result(timeout=30)
        typed = svc.stats_typed()
        assert isinstance(typed, ServiceStats)
        vs = typed.indexes["kb"].versions[1]
        assert isinstance(vs, VersionStats)
        assert all(isinstance(s, ShardStats) for s in vs.shards)
        # the plain dict is exactly the typed snapshot flattened — same
        # keys, same values (no traffic can land between the two calls
        # on a start=False service)
        assert typed.to_dict() == svc.stats()


def test_mesh_kwarg_deprecated_on_load(artifact):
    import jax
    from repro.retrieval.api import ShardSpec, load_index
    from repro.retrieval.sharded import ShardedCompressedIndex
    path, idx = artifact
    mesh = jax.make_mesh((jax.device_count(),), ("model",))
    with pytest.warns(DeprecationWarning, match="mesh"):
        out = load_index(path, mesh=mesh, shard=ShardSpec())
    assert isinstance(out, ShardedCompressedIndex)
