"""Open-loop load generator: workload statistics and a miniature trial."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

import loadgen  # noqa: E402
from repro.retrieval import IndexSpec, build_index  # noqa: E402
from repro.serve import AdaptiveBatcher, RetrievalService  # noqa: E402

D = 32
MENU = (
    loadgen.MenuItem(0.7, 1, 5, None, "interactive"),
    loadgen.MenuItem(0.3, 8, 5, None, "bulk"),
)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(5)
    return {
        "docs": rng.standard_normal((300, D)).astype(np.float32),
        "fresh": rng.standard_normal((64, D)).astype(np.float32),
        "queries": rng.standard_normal((32, D)).astype(np.float32),
    }


# ---------------------------------------------------------------------------
# workload construction
# ---------------------------------------------------------------------------


def test_poisson_schedule_hits_offered_rate():
    rng = np.random.default_rng(0)
    wl = loadgen.build_workload(rng, duration_s=20.0, rows_per_s=100.0,
                                arrival="poisson", menu=MENU,
                                pool_size=64, zipf_alpha=1.1)
    mean_rows = 0.7 * 1 + 0.3 * 8
    want_requests = 100.0 * 20.0 / mean_rows
    assert len(wl.arrivals) == pytest.approx(want_requests, rel=0.01)
    assert np.all(np.diff(wl.arrivals) >= 0)            # sorted
    # realised mean arrival rate within sampling noise of the request rate
    assert len(wl.arrivals) / wl.arrivals[-1] == \
        pytest.approx(want_requests / 20.0, rel=0.15)
    total_rows = sum(len(r) for r in wl.row_ids)
    assert total_rows == pytest.approx(100.0 * 20.0, rel=0.1)


def test_bursty_schedule_same_mean_meaner_peaks():
    rng = np.random.default_rng(1)
    kw = dict(duration_s=20.0, rows_per_s=200.0, menu=MENU,
              pool_size=64, zipf_alpha=1.1)
    smooth = loadgen.build_workload(rng, arrival="poisson", **kw)
    bursty = loadgen.build_workload(rng, arrival="bursty", **kw)
    assert len(bursty.arrivals) == len(smooth.arrivals)
    # same request count, but arrivals concentrate: count the busiest
    # 50ms window of each — the bursty one must be markedly taller
    def peak(arr):
        bins = np.bincount((arr / 0.05).astype(int))
        return bins.max()
    assert peak(bursty.arrivals) > 2 * peak(smooth.arrivals)


def test_bursty_respects_duty_windows():
    rng = np.random.default_rng(2)
    period, duty = 0.25, 0.25
    wl = loadgen.build_workload(rng, duration_s=10.0, rows_per_s=100.0,
                                arrival="bursty", menu=MENU, pool_size=64,
                                zipf_alpha=1.1, burst_period_s=period,
                                burst_duty=duty)
    phase = np.mod(wl.arrivals, period)
    assert np.all(phase <= duty * period + 1e-9)


def test_unknown_arrival_process_rejected():
    with pytest.raises(ValueError, match="arrival"):
        loadgen.build_workload(np.random.default_rng(0), duration_s=1.0,
                               rows_per_s=10.0, arrival="constant",
                               menu=MENU, pool_size=8, zipf_alpha=1.0)


def test_zipf_popularity_is_skewed():
    rng = np.random.default_rng(3)
    wl = loadgen.build_workload(rng, duration_s=50.0, rows_per_s=100.0,
                                arrival="poisson", menu=MENU,
                                pool_size=128, zipf_alpha=1.1)
    counts = np.bincount(np.concatenate(wl.row_ids), minlength=128)
    # the head dominates: rank-0 beats the whole bottom half combined
    assert counts[0] > counts[64:].sum()
    # but the tail is not empty (it is a distribution, not a constant)
    assert (counts[64:] > 0).any()


def test_menu_mix_follows_weights():
    rng = np.random.default_rng(4)
    wl = loadgen.build_workload(rng, duration_s=100.0, rows_per_s=100.0,
                                arrival="poisson", menu=MENU,
                                pool_size=16, zipf_alpha=1.0)
    frac_bulk = np.mean(wl.menu_ids == 1)
    assert frac_bulk == pytest.approx(0.3, abs=0.05)
    for mid, rows in zip(wl.menu_ids, wl.row_ids):
        assert len(rows) == MENU[mid].rows


# ---------------------------------------------------------------------------
# a miniature end-to-end trial (the CI smoke in-process)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_mini_trial_zero_lost_and_conserved(corpus):
    spec = IndexSpec(method="pca_int8", dim=16, backend="jnp", post=False,
                     mutable=True)
    idx = build_index(spec, corpus["docs"], corpus["queries"])
    svc = RetrievalService(cache_rows=256,
                           batcher=AdaptiveBatcher(min_batch=8,
                                                   max_batch=32))
    svc.register("kb", idx)
    pool = corpus["queries"]
    try:
        loadgen.warmup(svc, "kb", pool, MENU, max_batch=32, timeout_s=60.0)
        rng = np.random.default_rng(6)
        wl = loadgen.build_workload(rng, duration_s=1.0, rows_per_s=150.0,
                                    arrival="bursty", menu=MENU,
                                    pool_size=len(pool), zipf_alpha=1.2)
        mut = loadgen.Mutator(svc, "kb", corpus["fresh"], interval_s=0.15,
                              rng=np.random.default_rng(7))
        r = loadgen.run_trial(svc, "kb", pool, MENU, wl, timeout_s=60.0,
                              mutator=mut)
        assert r["lost"] == 0
        assert r["conserved"]
        assert r["deleted_ids_resurfaced"] == 0
        assert r["admitted"] + r["shed_queue_full"] + \
            r["shed_rate_limited"] == r["arrivals"]
        assert r["updates"] >= 1                 # mutator really ran
        assert np.isfinite(r["p99_ms"])
        assert loadgen.verify_cache_identity(svc, "kb", pool, MENU) > 0
    finally:
        svc.close()
