"""ShardedCompressedIndex ≡ CompressedIndex on a 1×N CPU mesh, per backend.

Runs in a subprocess with forced host devices (same pattern as
tests/test_distributed.py) so the main test process keeps its single-device
jax.  One subprocess checks every scorer backend; the parametrized tests
assert on its per-backend verdict lines.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

BACKENDS = ("float", "fp16", "int8", "onebit")

_CHECK_ALL = """
    import copy
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (CenterNorm, CompressionPipeline, FloatCast,
                            Int8Quantizer, OneBitQuantizer, PCA)
    from repro.launch.mesh import make_test_mesh
    from repro.retrieval import CompressedIndex, ShardedCompressedIndex

    rng = np.random.default_rng(0)
    docs = jnp.asarray(rng.standard_normal((515, 64)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    mesh = make_test_mesh(8, model=8)          # 1 x 8: pure doc sharding

    tails = {"float": [], "fp16": [FloatCast()],
             "int8": [Int8Quantizer()], "onebit": [OneBitQuantizer(0.5)]}
    for name, tail in tails.items():
        p1 = CompressionPipeline([CenterNorm(), PCA(32)] + copy.deepcopy(tail))
        p2 = CompressionPipeline([CenterNorm(), PCA(32)] + copy.deepcopy(tail))
        single = CompressedIndex.build(docs, queries, p1, backend="jnp")
        sharded = ShardedCompressedIndex.build(docs, queries, p2, mesh,
                                               backend="jnp")
        v1, i1 = single.search(queries, 10)
        v2, i2 = sharded.search(queries, 10)
        ids_equal = np.array_equal(np.asarray(i1), np.asarray(i2))
        vals_close = np.allclose(np.asarray(v1), np.asarray(v2),
                                 rtol=1e-5, atol=1e-5)
        print(f"BACKEND {name} ids={ids_equal} vals={vals_close}")
"""


@pytest.fixture(scope="module")
def parity_output():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CHECK_ALL)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_matches_single_host(parity_output, backend):
    assert f"BACKEND {backend} ids=True vals=True" in parity_output
