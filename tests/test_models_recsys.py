import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DCNConfig, DINConfig, FMConfig, TwoTowerConfig
from repro.models import layers as L
from repro.models import recsys as R


RNG = np.random.default_rng(0)


def test_embedding_bag_modes():
    table = jnp.asarray(RNG.standard_normal((20, 4)), jnp.float32)
    ids = jnp.asarray([0, 1, 2, 5, 5], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    s = R.embedding_bag(table, ids, seg, 2, "sum")
    np.testing.assert_allclose(np.asarray(s[0]),
                               np.asarray(table[0] + table[1]), rtol=1e-6)
    m = R.embedding_bag(table, ids, seg, 2, "mean")
    np.testing.assert_allclose(np.asarray(m[1]),
                               np.asarray((table[2] + 2 * table[5]) / 3),
                               rtol=1e-6)
    mx = R.embedding_bag(table, ids, seg, 2, "max")
    np.testing.assert_allclose(
        np.asarray(mx[0]), np.maximum(np.asarray(table[0]),
                                      np.asarray(table[1])), rtol=1e-6)


def test_fm_sum_square_trick_matches_bruteforce():
    """FM O(nk) formulation == explicit Σᵢ<ⱼ ⟨vᵢ,vⱼ⟩."""
    cfg = FMConfig(n_sparse=6, embed_dim=4, vocab_per_field=50)
    params = L.init_params(jax.random.PRNGKey(0), R.fm_spec(cfg))
    ids = jnp.asarray(RNG.integers(0, 50, (3, 6)), jnp.int32)
    got = R.fm_logits(params, {"sparse_ids": ids}, cfg)
    v = R.fused_field_lookup(params["v"], ids, 50)       # (3, 6, 4)
    brute = []
    for b in range(3):
        s = 0.0
        for i in range(6):
            for j in range(i + 1, 6):
                s += float(v[b, i] @ v[b, j])
        lin = R.fused_field_lookup(params["w_lin"], ids, 50)[b, :, 0]
        brute.append(float(params["w0"][0]) + float(jnp.sum(lin)) + s)
    np.testing.assert_allclose(np.asarray(got), brute, rtol=1e-4)


def test_fm_candidate_scores_match_full():
    cfg = FMConfig(n_sparse=5, embed_dim=4, vocab_per_field=30)
    params = L.init_params(jax.random.PRNGKey(1), R.fm_spec(cfg))
    ctx = jnp.asarray(RNG.integers(0, 30, (1, 4)), jnp.int32)
    cands = jnp.asarray(RNG.integers(0, 30, (7,)), jnp.int32)
    got = R.fm_candidate_scores(params, {"context_ids": ctx,
                                         "cand_ids": cands}, cfg)
    full_ids = jnp.concatenate(
        [jnp.broadcast_to(ctx, (7, 4)), cands[:, None]], axis=1)
    want = R.fm_logits(params, {"sparse_ids": full_ids}, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def test_din_candidate_scores_match_batch():
    cfg = DINConfig(item_vocab=100, context_vocab=20, seq_len=6,
                    attn_mlp=(8,), mlp=(12,), n_context_features=2,
                    embed_dim=6)
    params = L.init_params(jax.random.PRNGKey(2), R.din_spec(cfg))
    hist = jnp.asarray(RNG.integers(0, 100, (1, 6)), jnp.int32)
    ctx = jnp.asarray(RNG.integers(0, 20, (1, 2)), jnp.int32)
    cands = jnp.asarray(RNG.integers(0, 100, (5,)), jnp.int32)
    got = R.din_candidate_scores(params, {"history_ids": hist,
                                          "context_ids": ctx,
                                          "cand_ids": cands}, cfg)
    want = R.din_logits(params, {
        "target_ids": cands,
        "history_ids": jnp.broadcast_to(hist, (5, 6)),
        "context_ids": jnp.broadcast_to(ctx, (5, 2))}, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2,
                               atol=1e-3)


def test_dcn_cross_layer_math():
    """x1 = x0 ⊙ (W x0 + b) + x0 for a single cross layer."""
    cfg = DCNConfig(n_dense=2, n_sparse=2, embed_dim=2, n_cross_layers=1,
                    mlp=(4,), vocab_per_field=10)
    params = L.init_params(jax.random.PRNGKey(3), R.dcn_spec(cfg))
    batch = {"dense": jnp.asarray(RNG.standard_normal((1, 2)), jnp.float32),
             "sparse_ids": jnp.asarray(RNG.integers(0, 10, (1, 2)),
                                       jnp.int32)}
    emb = R.fused_field_lookup(params["table"], batch["sparse_ids"], 10)
    x0 = np.concatenate([np.asarray(batch["dense"]),
                         np.asarray(emb).reshape(1, -1)], -1)
    w = np.asarray(params["cross"][0]["w"])
    b = np.asarray(params["cross"][0]["b"])
    x1 = x0 * (x0 @ w + b) + x0
    # check via monkey forward (bf16 tolerance)
    logits = R.dcn_logits(params, batch, cfg)
    w_m = [np.asarray(l["w"]) for l in params["mlp"]]
    b_m = [np.asarray(l["b"]) for l in params["mlp"]]
    h = np.maximum(x1 @ w_m[0] + b_m[0], 0)
    want = (h @ w_m[1] + b_m[1])[:, 0]
    np.testing.assert_allclose(np.asarray(logits), want, rtol=5e-2,
                               atol=1e-2)


def test_two_tower_loss_and_retrieval():
    cfg = TwoTowerConfig(user_vocab=50, item_vocab=60, embed_dim=8,
                         tower_mlp=(16, 8), n_user_features=3,
                         n_item_features=3)
    params = L.init_params(jax.random.PRNGKey(4), R.two_tower_spec(cfg))
    batch = {"user_ids": jnp.asarray(RNG.integers(0, 50, (4, 3))),
             "item_ids": jnp.asarray(RNG.integers(0, 60, (4, 3)))}
    loss, _ = R.two_tower_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    # retrieval scores == pairwise dot of tower outputs
    scores = R.retrieval_scores(params, {"user_ids": batch["user_ids"][:2],
                                         "cand_ids": batch["item_ids"]}, cfg)
    u = R.user_embedding(params, batch["user_ids"][:2], cfg)
    v = R.item_embedding(params, batch["item_ids"], cfg)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(u @ v.T),
                               rtol=1e-5)


def test_bce_loss_known_value():
    logits = jnp.asarray([0.0, 100.0, -100.0])
    labels = jnp.asarray([0.5, 1.0, 0.0])
    loss, _ = R.bce_loss(logits, labels)
    assert float(loss) == pytest.approx(np.log(2) / 3, rel=1e-4)
