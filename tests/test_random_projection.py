import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.random_projection import (DimensionDrop, GaussianProjection,
                                          GreedyDimensionDrop,
                                          SparseProjection)
from repro.data import make_dpr_like_kb
from repro.retrieval.rprecision import make_dim_drop_scorer


@pytest.fixture
def data():
    rng = np.random.default_rng(3)
    return jnp.asarray(rng.standard_normal((300, 64)), jnp.float32)


def test_dimension_drop(data):
    t = DimensionDrop(16).fit(data, rng=jax.random.PRNGKey(0))
    y = t(data)
    assert y.shape == (300, 16)
    keep = np.asarray(t.state["keep"])
    assert len(np.unique(keep)) == 16
    np.testing.assert_array_equal(np.asarray(y), np.asarray(data)[:, keep])


def test_gaussian_projection_jl(data):
    """JL property: projected IPs approximate original IPs on average."""
    t = GaussianProjection(48).fit(data, rng=jax.random.PRNGKey(1))
    y = np.asarray(t(data))
    x = np.asarray(data)
    corr = np.corrcoef((x @ x.T).ravel(), (y @ y.T).ravel())[0, 1]
    assert corr > 0.6


def test_sparse_projection_density(data):
    t = SparseProjection(32, s=3.0).fit(data, rng=jax.random.PRNGKey(2))
    m = np.asarray(t.state["matrix"])
    density = np.mean(m != 0)
    assert 0.2 < density < 0.5      # expected 1/3


def test_greedy_dim_drop_uses_scorer():
    kb = make_dpr_like_kb(n_queries=50, n_docs=1000, d=64, r_eff=16)
    scorer = make_dim_drop_scorer(kb.relevant, n_queries=32, n_docs=256,
                                  dim_chunk=16)
    t = GreedyDimensionDrop(16, scorer=scorer)
    t.fit(kb.docs, kb.queries)
    assert t(kb.docs).shape == (1000, 16)
    assert t.state["per_dim_quality"].shape == (64,)
    # deterministic
    t2 = GreedyDimensionDrop(16, scorer=scorer).fit(kb.docs, kb.queries)
    np.testing.assert_array_equal(np.asarray(t.state["keep"]),
                                  np.asarray(t2.state["keep"]))


def test_greedy_requires_scorer(data):
    with pytest.raises(ValueError):
        GreedyDimensionDrop(8).fit(data)
