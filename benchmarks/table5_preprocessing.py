"""Paper Table 5 / Figure 2: pre-processing transformations on raw DPR-like
embeddings (no dimension reduction)."""

from __future__ import annotations


from benchmarks.common import base_parser, default_kb, print_csv
from repro.core.preprocess import PreprocessSpec, fit_apply
from repro.retrieval import r_precision

MODES = ("none", "center", "zscore", "norm", "center_norm", "zscore_norm")


def main(argv=None) -> list[dict]:
    ap = base_parser("Paper Table 5: preprocessing effects")
    args = ap.parse_args(argv)
    kb = default_kb(args.dataset, args.n_docs, args.n_queries)

    rows = []
    for mode in MODES:
        ts = PreprocessSpec(mode).build()
        d, q = fit_apply(ts, kb.docs, kb.queries)
        row = {"mode": mode,
               "ip": r_precision(q, d, kb.relevant, sim="ip"),
               "l2": r_precision(q, d, kb.relevant, sim="l2")}
        rows.append(row)
        print(f"  {mode:12s} ip={row['ip']:.3f} l2={row['l2']:.3f}",
              flush=True)
    print()
    print_csv(rows, ["mode", "ip", "l2"])
    return rows


if __name__ == "__main__":
    main()
