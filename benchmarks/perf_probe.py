import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
"""Perf-iteration probe: one (arch × shape) cell with config overrides.

    PYTHONPATH=src python -m benchmarks.perf_probe --arch dbrx-132b \
        --shape train_4k --set train_microbatches=16 --set attn_q_chunk=512

Prints the deployment-pass memory and the cost-pass roofline terms, so a
hypothesis → change → measure cycle is one command.  Overrides apply to the
model config (dataclasses.replace); ``--rules k=v`` overrides logical-axis
rules (e.g. --rules kv_seq=model).
"""

import argparse
import dataclasses
import json
import time


from repro.configs.registry import get_arch
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.launch.steps import build_step
from repro.utils import human_bytes


def _parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    if v == "None":
        return None
    return v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="model-config override k=v (repeatable)")
    ap.add_argument("--rules", action="append", default=[],
                    help="logical-axis rule override k=v; v may be a "
                         "+-separated axis tuple, e.g. kv_seq=data+model")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-cost", action="store_true",
                    help="memory pass only (fast)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = rules_for_mesh(mesh)
    for kv in args.rules:
        k, v = kv.split("=", 1)
        axes = tuple(v.split("+")) if v != "None" else None
        if axes is not None and len(axes) == 1:
            axes = axes[0]
        rules = rules.replace(**{k: axes})

    arch = get_arch(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = _parse_val(v)
    if overrides:
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(arch.model, **overrides))
    shape = arch.shape(args.shape)
    chips = mesh.devices.size

    out = {"arch": args.arch, "shape": args.shape, "overrides": overrides,
           "rules": args.rules}

    t0 = time.time()
    bundle = build_step(arch, shape, mesh, rules)
    with mesh:
        compiled = bundle.lower(mesh).compile()
    ma = compiled.memory_analysis()
    mem = int(ma.temp_size_in_bytes + ma.argument_size_in_bytes
              + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    out["mem_per_dev"] = mem
    out["mem_h"] = human_bytes(mem)
    out["compile_s"] = round(time.time() - t0, 1)

    if not args.skip_cost and shape.kind.startswith("lm"):
        t0 = time.time()
        cb = build_step(arch, shape, mesh, rules, unroll=True)
        with mesh:
            cost_compiled = cb.lower(mesh).compile()
        out["cost_compile_s"] = round(time.time() - t0, 1)
    else:
        cost_compiled = compiled

    mf = bundle.model_flops_fn() if bundle.model_flops_fn else None
    rep = roofline.analyze(f"{args.arch}:{args.shape}", "16x16", chips,
                           cost_compiled, mf)
    rep.hlo_gflops *= chips
    rep.hlo_gbytes *= chips
    rep.coll_gbytes *= chips
    rep.peak_memory_bytes = mem
    out.update({k: v for k, v in rep.to_dict().items()
                if k not in ("name", "mesh")})

    if args.json:
        print(json.dumps(out))
    else:
        print(f"\n=== {args.arch}:{args.shape} {overrides} {args.rules}")
        print(f"  mem/dev       {out['mem_h']}  (compile {out['compile_s']}s)")
        print(f"  t_compute     {rep.t_compute:.3e} s")
        print(f"  t_memory      {rep.t_memory:.3e} s")
        print(f"  t_collective  {rep.t_collective:.3e} s")
        print(f"  bottleneck    {rep.bottleneck}")
        print(f"  MODEL/HLO     {rep.flops_efficiency:.3f}")
        print(f"  roofline frac {rep.roofline_fraction:.4f}")
        print(f"  collectives   {rep.per_collective}")


if __name__ == "__main__":
    main()
