"""Paper Figure 5: PCA dimension × precision-reduction combinations."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import base_parser, default_kb, print_csv
from repro.core import (CenterNorm, CompressionPipeline, FloatCast,
                        Int8Quantizer, OneBitQuantizer, PCA)
from repro.retrieval import r_precision

PRECISIONS = {
    "fp32": None,
    "fp16": lambda: FloatCast(jnp.float16),
    "int8": Int8Quantizer,
    "1bit": lambda: OneBitQuantizer(0.5),
}
DIMS = (32, 64, 128, 245, 512, 768)


def main(argv=None) -> list[dict]:
    ap = base_parser("Paper Fig. 5: PCA × precision reduction")
    args = ap.parse_args(argv)
    kb = default_kb(args.dataset, args.n_docs, args.n_queries)
    dims = (64, 128, 245) if args.fast else DIMS

    rows = []
    for dim in dims:
        for prec_name, prec in PRECISIONS.items():
            stages = [CenterNorm()]
            if dim < kb.dim:
                stages.append(PCA(dim))
                stages.append(CenterNorm())
            if prec is not None:
                stages.append(prec())
            pipe = CompressionPipeline(stages)
            d, q = pipe.fit_transform(kb.docs, kb.queries,
                                      rng=jax.random.PRNGKey(0))
            row = {"dim": dim, "precision": prec_name,
                   "compression": round(pipe.compression_ratio(kb.dim), 1),
                   "rprec_ip": r_precision(q, d, kb.relevant, "ip")}
            rows.append(row)
            print(f"  d'={dim:4d} {prec_name:5s} "
                  f"{row['compression']:6.1f}x rprec={row['rprec_ip']:.3f}",
                  flush=True)
    print()
    print_csv(rows, ["dim", "precision", "compression", "rprec_ip"])
    return rows


if __name__ == "__main__":
    main()
