"""CI perf-regression gate: measure the quick benches, compare, fail loud.

    PYTHONPATH=src:. python benchmarks/ci_gate.py                 # gate
    PYTHONPATH=src:. python benchmarks/ci_gate.py --write-baseline

Measures serving-shaped workloads on a 100k-doc clustered synthetic KB
(the regime IVF exists for): per gated backend (int8 and 1-bit) an exact
quantized search and an IVF search over the same storage, plus a
mid-traffic live-update cycle.  Writes ``BENCH_<git-sha>.json`` with
throughput (qps), per-request latency percentiles (p50/p99 ms), and IVF
recall@10 against the backend's own exact ranking.  The measurement is
then checked two ways:

* **absolute invariants** — IVF must beat exact search in qps on every
  gated backend *while* holding ``recall@10 >= 0.80`` (the fused-IVF PR's
  acceptance bar; machine-independent, no baseline needed),
* **baseline comparison** against the committed
  ``benchmarks/BENCH_baseline.json`` — throughput/latency may not regress
  more than ``--tolerance`` (default 20%), recall not more than
  ``--recall-tolerance`` (absolute).

Any violation exits non-zero, which fails the CI job; the fresh JSON is
uploaded as a workflow artifact either way, so the perf trajectory is
recorded per commit.  ``--write-baseline`` records the current machine's
measurement with ``--slack`` headroom folded in (CI runners are noisy;
the committed floor should be conservative, the tolerance strict).
"""

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

import threading

from repro.data import make_dpr_like_kb
from repro.retrieval import (IndexSpec, build_index, load_index,
                             load_index_meta, recall_at_k, save_index)
from repro.serve import AdaptiveBatcher, MicroBatcher, QueryOptions, \
    RetrievalService, ServeEngine

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "BENCH_baseline.json")

#: backends the gate measures: name → IndexSpec method (the 1-bit lane
#: runs through the learned rotation, which is what buys its recall)
GATE_BACKENDS = {"int8": "pca_int8", "onebit": "pca_rot_onebit"}

#: absolute floor on IVF recall@10 vs the backend's own exact ranking —
#: IVF must stay a *good* index, not merely a fast one
RECALL_FLOOR = 0.80

#: the serving SLO row, machine-independent by construction: the threaded
#: front door (admission control + micro-batching + async handles) must
#: sustain at least this fraction of the bare exact engine's qps on the
#: same index, same machine — a ratio, so runner speed cancels out
SERVICE_RATIO_FLOOR = 0.40

#: tiered-storage row, also a ratio: serving the chunked artifact with a
#: 5% hot-tier budget (encoded lists 20× bigger than the budget) must
#: sustain at least this fraction of the fully-resident qps under
#: Zipf-skewed traffic — the cold tier may cost, not collapse
TIERED_RATIO_FLOOR = 0.25

#: metric name → direction ("higher" is better, or "lower")
METRICS = {
    "exact_qps_int8": "higher", "ivf_qps_int8": "higher",
    "ivf_p50_ms_int8": "lower", "ivf_p99_ms_int8": "lower",
    "ivf_recall_at_10_int8": "recall",
    "exact_qps_onebit": "higher", "ivf_qps_onebit": "higher",
    "ivf_p50_ms_onebit": "lower", "ivf_p99_ms_onebit": "lower",
    "ivf_recall_at_10_onebit": "recall",
    "update_qps": "higher",
    "service_qps": "higher",
    "service_exact_ratio": "higher",
    "service_p99_ms": "lower",
    "tiered_qps_full": "higher",
    "tiered_qps_cold": "higher",
    "tiered_cold_ratio": "higher",
    "sharded_qps": "higher",
    "sharded_parity": "recall",
    "sharded_lost_requests": "lower",
}


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             cwd=HERE, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "nogit"


def serve_rounds(engine, queries, n_requests, batch, warmup: int = 3):
    """Stream ``n_requests`` blocks through submit/drain; returns
    (qps, p50_ms, p99_ms).  ``warmup`` untimed rounds first, so jit
    compiles never land inside the measured window."""
    for _ in range(warmup):
        engine.submit(queries[:batch])
        engine.drain()
    lat = []
    n_rows = 0
    t0 = time.perf_counter()
    for r in range(n_requests):
        off = (r * batch) % (len(queries) - batch)
        engine.submit(queries[off: off + batch])
        n_rows += batch
        for res in engine.drain().values():
            lat.append(res.latency_s)
    wall = time.perf_counter() - t0
    ms = np.asarray(lat) * 1000.0
    return (n_rows / wall, float(np.percentile(ms, 50)),
            float(np.percentile(ms, 99)))


def serve_service(index, queries, n_requests, batch, k,
                  n_threads: int = 4):
    """Stream the same request load through the RetrievalService front
    door (threaded producers, background dispatcher, admission control).
    Returns (qps, request_p99_ms, lost, cache_identical).

    Throughput runs with the result cache OFF so every row really hits
    the engine; cache bit-identity is then checked separately on a
    cache-enabled service over the same index.
    """
    svc = RetrievalService(default_k=k,
                           batcher=AdaptiveBatcher(min_batch=8,
                                                   max_batch=64))
    svc.register("kb", index)
    for _ in range(3):                         # compile outside the window
        svc.query(queries[:batch], index="kb").result(timeout=300)
    per_thread = max(1, n_requests // n_threads)

    def producer(t):
        for r in range(per_thread):
            off = ((t * per_thread + r) * batch) % (len(queries) - batch)
            svc.query(queries[off: off + batch],
                      QueryOptions(index="kb")).result(timeout=300)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    stats = svc.stats()
    svc.close()
    lost = (stats["requests_submitted"] - stats["requests_served"]
            + stats["queue_depth"])
    qps = per_thread * n_threads * batch / wall

    cached = RetrievalService(default_k=k, cache_rows=4096,
                              batcher=AdaptiveBatcher(min_batch=8,
                                                      max_batch=64))
    cached.register("kb", index)
    probe = queries[:batch] + 0.125            # never seen above: a miss
    first = cached.query(probe, index="kb").result(timeout=300)
    again = cached.query(probe, index="kb")
    identical = (again.done()                  # hit resolves at submit
                 and np.array_equal(first.scores, again.result().scores)
                 and np.array_equal(first.ids, again.result().ids))
    cached.close()
    return qps, stats["request_p99_ms"], lost, identical


def measure(n_docs: int, n_requests: int, batch: int, k: int,
            repeats: int, nlist: int, nprobe: int) -> dict:
    """One full measurement pass; best-of-``repeats`` per metric to damp
    scheduler noise.

    The corpus is the *clustered* synthetic (topical low-rank structure,
    like real DPR embeddings) at serving scale — coarse routing has
    something to find, and the exact scan is expensive enough that IVF's
    candidate pruning shows up as throughput, not noise.
    """
    kb = make_dpr_like_kb(n_queries=max(256, 2 * batch), n_docs=n_docs,
                          d=256, r_eff=48)
    queries = np.asarray(kb.queries)

    out = {"update_qps": 0.0}
    pairs = {}
    for bname, method in GATE_BACKENDS.items():
        exact = build_index(
            IndexSpec(method=method, dim=128, backend="jnp", post=False),
            kb.docs, kb.queries[:256])
        ivf = build_index(
            IndexSpec(method=method, dim=128, backend="jnp", post=False,
                      ivf=(nlist, nprobe), kmeans_iters=8,
                      kmeans_init="++", balanced_lists=True),
            kb.docs, kb.queries[:256])
        pairs[bname] = (exact, ivf)
        # recall@k: IVF at the gate probe width vs the backend's own
        # exact ranking (IVF loss isolated from compression loss)
        _, want = exact.search(kb.queries[:128], 10)
        _, got = ivf.search(kb.queries[:128], 10)
        out[f"ivf_recall_at_10_{bname}"] = recall_at_k(
            np.asarray(got), np.asarray(want))
        out[f"exact_qps_{bname}"] = 0.0
        out[f"ivf_qps_{bname}"] = 0.0
        out[f"ivf_p50_ms_{bname}"] = np.inf
        out[f"ivf_p99_ms_{bname}"] = np.inf

    mutable = build_index(
        IndexSpec(method="pca_int8", dim=128, backend="jnp", post=False,
                  mutable=True), kb.docs, kb.queries[:256])

    extra = np.asarray(kb.docs[:256])
    for _ in range(repeats):
        for bname, (exact, ivf) in pairs.items():
            e = ServeEngine(exact, k=k, batcher=MicroBatcher(max_batch=64))
            qps, _, _ = serve_rounds(e, queries, n_requests, batch)
            out[f"exact_qps_{bname}"] = max(out[f"exact_qps_{bname}"], qps)

            e = ServeEngine(ivf, k=k, batcher=MicroBatcher(max_batch=64))
            qps, p50, p99 = serve_rounds(e, queries, n_requests, batch)
            out[f"ivf_qps_{bname}"] = max(out[f"ivf_qps_{bname}"], qps)
            out[f"ivf_p50_ms_{bname}"] = min(out[f"ivf_p50_ms_{bname}"], p50)
            out[f"ivf_p99_ms_{bname}"] = min(out[f"ivf_p99_ms_{bname}"], p99)

        # live-update cycle: search throughput with a live delta segment
        # and tombstones layered on.  compact() hands each repeat a fresh
        # fold of the same corpus, so every repeat measures the identical
        # workload (segments/tombstones never accumulate across repeats).
        m = mutable.compact()
        first = m.next_gid
        m.add(extra)
        m.delete(range(first, first + len(extra) // 2))
        e = ServeEngine(m, k=k, batcher=MicroBatcher(max_batch=64))
        qps, _, _ = serve_rounds(e, queries, n_requests, batch)
        out["update_qps"] = max(out["update_qps"], qps)

    # the SLO row: the threaded front door over the int8 exact index,
    # measured against that index's bare-engine qps from the loop above
    out["service_qps"] = 0.0
    out["service_p99_ms"] = np.inf
    out["service_lost_requests"] = 0.0
    out["service_cache_identical"] = 1.0
    for _ in range(repeats):
        qps, p99, lost, identical = serve_service(
            pairs["int8"][0], queries, n_requests, batch, k)
        out["service_qps"] = max(out["service_qps"], qps)
        out["service_p99_ms"] = min(out["service_p99_ms"], p99)
        out["service_lost_requests"] += float(lost)
        out["service_cache_identical"] = min(
            out["service_cache_identical"], 1.0 if identical else 0.0)
    out["service_exact_ratio"] = out["service_qps"] / \
        max(out["exact_qps_int8"], 1e-9)

    # the tiered-storage row: the int8 IVF index streamed to a chunked
    # artifact, served fully resident vs at a 5% hot-tier budget, under
    # Zipf-skewed traffic (what the LRU hot tier exists for).  A ratio,
    # so runner speed cancels out.
    from benchmarks.loadgen import zipf_weights
    out["tiered_qps_full"] = 0.0
    out["tiered_qps_cold"] = 0.0
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "kb.v3")
        save_index(pairs["int8"][1], path, chunked=True)
        enc = load_index_meta(path)["encoded_nbytes"]
        rng = np.random.default_rng(7)
        qz = queries[rng.choice(len(queries), size=len(queries),
                                p=zipf_weights(len(queries), 1.1))]
        for _ in range(repeats):
            for key, resident in (("tiered_qps_full", "all"),
                                  ("tiered_qps_cold", enc // 20)):
                e = ServeEngine(load_index(path, resident=resident), k=k,
                                batcher=MicroBatcher(max_batch=64))
                qps, _, _ = serve_rounds(e, qz, n_requests, batch)
                out[key] = max(out[key], qps)
    out["tiered_cold_ratio"] = out["tiered_qps_cold"] / \
        max(out["tiered_qps_full"], 1e-9)

    out.update(sharded_row())
    return out


def sharded_row() -> dict:
    """The sharded-serving row: run ``sharded_bench.py --quick`` in a
    subprocess with forced host devices (``XLA_FLAGS`` must land before
    jax initialises, which this process is long past) and collect its
    gate JSON — bit-parity vs single-host across all four scorer
    backends, sharded throughput, and the zero-lost-requests count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    with tempfile.TemporaryDirectory() as tmp:
        gate = os.path.join(tmp, "sharded.json")
        cmd = [sys.executable, os.path.join(HERE, "sharded_bench.py"),
               "--quick", "--gate-json", gate]
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=900)
        if r.returncode != 0:
            raise SystemExit(
                "sharded_bench subprocess failed "
                f"(rc={r.returncode}):\n{r.stdout}\n{r.stderr}")
        with open(gate) as f:
            return json.load(f)


def invariants(measured: dict) -> list[str]:
    """Machine-independent acceptance checks (no baseline involved):
    IVF must dominate exact search — faster *and* recall@10 ≥ the floor —
    on every gated backend."""
    failures = []
    for bname in GATE_BACKENDS:
        rec = measured[f"ivf_recall_at_10_{bname}"]
        if rec < RECALL_FLOOR:
            failures.append(
                f"ivf_recall_at_10_{bname}: {rec:.3f} < floor "
                f"{RECALL_FLOOR} (absolute)")
        iq, eq = measured[f"ivf_qps_{bname}"], measured[f"exact_qps_{bname}"]
        if iq <= eq:
            failures.append(
                f"ivf_qps_{bname}: {iq:.1f} <= exact_qps_{bname} {eq:.1f} "
                "(IVF must beat brute force)")
    ratio = measured["service_exact_ratio"]
    if ratio < SERVICE_RATIO_FLOOR:
        failures.append(
            f"service_exact_ratio: {ratio:.2f} < floor "
            f"{SERVICE_RATIO_FLOOR} (the front door may not cost more "
            "than this much of the bare engine's throughput)")
    if measured["service_lost_requests"]:
        failures.append(
            f"service_lost_requests: "
            f"{measured['service_lost_requests']:.0f} != 0 (every "
            "admitted request must resolve)")
    if measured["service_cache_identical"] != 1.0:
        failures.append(
            "service_cache_identical: cached result differed from the "
            "dispatch it replaced (must be bit-identical)")
    tiered = measured["tiered_cold_ratio"]
    if tiered < TIERED_RATIO_FLOOR:
        failures.append(
            f"tiered_cold_ratio: {tiered:.2f} < floor "
            f"{TIERED_RATIO_FLOOR} (a 5% hot-tier budget may not cost "
            "more than this much of fully-resident throughput)")
    if measured["sharded_parity"] != 1.0:
        failures.append(
            f"sharded_parity: {measured['sharded_parity']:.3f} != 1.0 "
            "(sharded serving must match single-host in ids AND score "
            "bytes on every backend, including mid-traffic "
            "update/compact)")
    if measured["sharded_lost_requests"]:
        failures.append(
            f"sharded_lost_requests: "
            f"{measured['sharded_lost_requests']:.0f} != 0 (every "
            "request admitted against a sharded version must resolve)")
    return failures


def compare(measured: dict, baseline: dict, tolerance: float,
            recall_tolerance: float) -> list[str]:
    failures = []
    base = baseline["metrics"]
    for name, direction in METRICS.items():
        if name not in base:
            continue
        have, want = measured[name], base[name]
        if direction == "higher" and have < want * (1.0 - tolerance):
            failures.append(f"{name}: {have:.1f} < floor "
                            f"{want * (1.0 - tolerance):.1f} "
                            f"(baseline {want:.1f}, -{tolerance:.0%})")
        elif direction == "lower" and have > want * (1.0 + tolerance):
            failures.append(f"{name}: {have:.2f} > ceiling "
                            f"{want * (1.0 + tolerance):.2f} "
                            f"(baseline {want:.2f}, +{tolerance:.0%})")
        elif direction == "recall" and have < want - recall_tolerance:
            failures.append(f"{name}: {have:.3f} < "
                            f"{want - recall_tolerance:.3f} "
                            f"(baseline {want:.3f}, "
                            f"-{recall_tolerance} abs)")
    return failures


def with_slack(metrics: dict, slack: float) -> dict:
    """Relax a measurement into a committable baseline (CI runners are
    slower and noisier than dev machines)."""
    out = {}
    for name, direction in METRICS.items():
        v = metrics[name]
        if direction == "higher":
            out[name] = round(v * (1.0 - slack), 2)
        elif direction == "lower":
            out[name] = round(v * (1.0 + slack), 3)
        else:
            out[name] = round(v - slack / 4.0, 3)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="accepted for lane uniformity (the gate is "
                    "always the quick configuration)")
    ap.add_argument("--n-docs", type=int, default=100_000)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--nlist", type=int, default=512)
    ap.add_argument("--nprobe", type=int, default=80)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--output", default=None,
                    help="result JSON path (default BENCH_<git-sha>.json)")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="max relative qps/latency regression (default "
                    "0.20 = fail on >20%%)")
    ap.add_argument("--recall-tolerance", type=float, default=0.05,
                    help="max absolute recall@k drop")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record this measurement (with --slack folded "
                    "in) as the committed baseline and exit")
    ap.add_argument("--slack", type=float, default=0.5,
                    help="headroom folded into --write-baseline")
    ap.add_argument("--no-compare", action="store_true",
                    help="measure + write JSON, skip the gate")
    args = ap.parse_args(argv)

    sha = git_sha()
    print(f"ci_gate: measuring quick benches at {sha} "
          f"({args.n_docs} docs, {args.requests} requests x {args.batch}, "
          f"best of {args.repeats}) ...")
    metrics = measure(args.n_docs, args.requests, args.batch, args.k,
                      args.repeats, args.nlist, args.nprobe)
    for name in METRICS:
        print(f"  {name:24s} {metrics[name]:10.2f}")

    hard_failures = invariants(metrics)
    if hard_failures:
        print("\nACCEPTANCE INVARIANT VIOLATED:", file=sys.stderr)
        for line in hard_failures:
            print(f"  FAIL {line}", file=sys.stderr)
        return 1

    if args.write_baseline:
        doc = {"sha": sha, "config": {"n_docs": args.n_docs,
                                      "requests": args.requests,
                                      "batch": args.batch, "k": args.k,
                                      "nlist": args.nlist,
                                      "nprobe": args.nprobe},
               "slack": args.slack,
               "metrics": with_slack(metrics, args.slack)}
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline written to {args.baseline} "
              f"(slack {args.slack:.0%})")
        return 0

    out_path = args.output or f"BENCH_{sha}.json"
    with open(out_path, "w") as f:
        json.dump({"sha": sha, "metrics": metrics}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")

    if args.no_compare:
        return 0
    if not os.path.exists(args.baseline):
        print(f"ERROR: no baseline at {args.baseline} — run "
              "--write-baseline once and commit it", file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(metrics, baseline, args.tolerance,
                       args.recall_tolerance)
    if failures:
        print(f"\nPERF REGRESSION vs baseline "
              f"(recorded at {baseline.get('sha', '?')}):",
              file=sys.stderr)
        for line in failures:
            print(f"  FAIL {line}", file=sys.stderr)
        return 1
    print(f"gate passed vs baseline {baseline.get('sha', '?')} "
          f"(tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
