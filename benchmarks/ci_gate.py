"""CI perf-regression gate: measure the quick benches, compare, fail loud.

    PYTHONPATH=src:. python benchmarks/ci_gate.py                 # gate
    PYTHONPATH=src:. python benchmarks/ci_gate.py --write-baseline

Measures the serving-shaped quick workloads (exact quantized search, IVF
search, and a mid-traffic live-update cycle) on a small synthetic KB and
writes ``BENCH_<git-sha>.json`` with throughput (qps), per-request
latency percentiles (p50/p99 ms), and IVF recall@k against exact search.
The measurement is then compared metric-by-metric against the committed
``benchmarks/BENCH_baseline.json``:

* throughput may not regress more than ``--tolerance`` (default 20%),
* latency percentiles may not regress more than ``--tolerance``,
* recall@k may not drop more than ``--recall-tolerance`` (absolute).

Any violation exits non-zero, which fails the CI job; the fresh JSON is
uploaded as a workflow artifact either way, so the perf trajectory is
recorded per commit.  ``--write-baseline`` records the current machine's
measurement with ``--slack`` headroom folded in (CI runners are noisy;
the committed floor should be conservative, the tolerance strict).
"""

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import IndexSpec, build_index, recall_at_k
from repro.serve import MicroBatcher, ServeEngine

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "BENCH_baseline.json")

#: metric name → direction ("higher" is better, or "lower")
METRICS = {
    "exact_qps": "higher", "exact_p50_ms": "lower", "exact_p99_ms": "lower",
    "ivf_qps": "higher", "ivf_p50_ms": "lower", "ivf_p99_ms": "lower",
    "update_qps": "higher",
    "ivf_recall_at_10": "recall",
}


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             cwd=HERE, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "nogit"


def serve_rounds(engine, queries, n_requests, batch, warmup: int = 3):
    """Stream ``n_requests`` blocks through submit/drain; returns
    (qps, p50_ms, p99_ms).  ``warmup`` untimed rounds first, so jit
    compiles never land inside the measured window."""
    for r in range(warmup):
        engine.submit(queries[:batch])
        engine.drain()
    lat = []
    n_rows = 0
    t0 = time.perf_counter()
    for r in range(n_requests):
        off = (r * batch) % (len(queries) - batch)
        engine.submit(queries[off: off + batch])
        n_rows += batch
        for res in engine.drain().values():
            lat.append(res.latency_s)
    wall = time.perf_counter() - t0
    ms = np.asarray(lat) * 1000.0
    return (n_rows / wall, float(np.percentile(ms, 50)),
            float(np.percentile(ms, 99)))


def measure(n_docs: int, n_requests: int, batch: int, k: int,
            repeats: int) -> dict:
    """One full measurement pass; best-of-``repeats`` per metric to damp
    scheduler noise."""
    kb = make_dpr_like_kb(n_queries=max(256, 2 * batch), n_docs=n_docs)
    queries = np.asarray(kb.queries)

    spec = IndexSpec(method="pca_int8", dim=128, backend="jnp", post=False)
    exact = build_index(spec, kb.docs, kb.queries[:256])
    ivf_spec = IndexSpec(method="pca_int8", dim=128, backend="jnp",
                         post=False, ivf=(64, 8), kmeans_iters=6)
    ivf = build_index(ivf_spec, kb.docs, kb.queries[:256])
    mutable = build_index(
        IndexSpec(method="pca_int8", dim=128, backend="jnp", post=False,
                  mutable=True), kb.docs, kb.queries[:256])

    # recall@k: IVF at the default probe width vs exact search
    _, want = exact.search(kb.queries[:128], 10)
    _, got = ivf.search(kb.queries[:128], 10)
    recall = recall_at_k(np.asarray(got), np.asarray(want))

    out = {"exact_qps": 0.0, "exact_p50_ms": np.inf, "exact_p99_ms": np.inf,
           "ivf_qps": 0.0, "ivf_p50_ms": np.inf, "ivf_p99_ms": np.inf,
           "update_qps": 0.0}
    extra = np.asarray(kb.docs[:256])
    for _ in range(repeats):
        e = ServeEngine(exact, k=k, batcher=MicroBatcher(max_batch=64))
        qps, p50, p99 = serve_rounds(e, queries, n_requests, batch)
        out["exact_qps"] = max(out["exact_qps"], qps)
        out["exact_p50_ms"] = min(out["exact_p50_ms"], p50)
        out["exact_p99_ms"] = min(out["exact_p99_ms"], p99)

        e = ServeEngine(ivf, k=k, batcher=MicroBatcher(max_batch=64))
        qps, p50, p99 = serve_rounds(e, queries, n_requests, batch)
        out["ivf_qps"] = max(out["ivf_qps"], qps)
        out["ivf_p50_ms"] = min(out["ivf_p50_ms"], p50)
        out["ivf_p99_ms"] = min(out["ivf_p99_ms"], p99)

        # live-update cycle: search throughput with a live delta segment
        # and tombstones layered on.  compact() hands each repeat a fresh
        # fold of the same corpus, so every repeat measures the identical
        # workload (segments/tombstones never accumulate across repeats).
        m = mutable.compact()
        first = m.next_gid
        m.add(extra)
        m.delete(range(first, first + len(extra) // 2))
        e = ServeEngine(m, k=k, batcher=MicroBatcher(max_batch=64))
        qps, _, _ = serve_rounds(e, queries, n_requests, batch)
        out["update_qps"] = max(out["update_qps"], qps)

    out["ivf_recall_at_10"] = recall
    return out


def compare(measured: dict, baseline: dict, tolerance: float,
            recall_tolerance: float) -> list[str]:
    failures = []
    base = baseline["metrics"]
    for name, direction in METRICS.items():
        if name not in base:
            continue
        have, want = measured[name], base[name]
        if direction == "higher" and have < want * (1.0 - tolerance):
            failures.append(f"{name}: {have:.1f} < floor "
                            f"{want * (1.0 - tolerance):.1f} "
                            f"(baseline {want:.1f}, -{tolerance:.0%})")
        elif direction == "lower" and have > want * (1.0 + tolerance):
            failures.append(f"{name}: {have:.2f} > ceiling "
                            f"{want * (1.0 + tolerance):.2f} "
                            f"(baseline {want:.2f}, +{tolerance:.0%})")
        elif direction == "recall" and have < want - recall_tolerance:
            failures.append(f"{name}: {have:.3f} < "
                            f"{want - recall_tolerance:.3f} "
                            f"(baseline {want:.3f}, "
                            f"-{recall_tolerance} abs)")
    return failures


def with_slack(metrics: dict, slack: float) -> dict:
    """Relax a measurement into a committable baseline (CI runners are
    slower and noisier than dev machines)."""
    out = {}
    for name, direction in METRICS.items():
        v = metrics[name]
        if direction == "higher":
            out[name] = round(v * (1.0 - slack), 2)
        elif direction == "lower":
            out[name] = round(v * (1.0 + slack), 3)
        else:
            out[name] = round(v - slack / 4.0, 3)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="accepted for lane uniformity (the gate is "
                    "always the quick configuration)")
    ap.add_argument("--n-docs", type=int, default=6000)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--output", default=None,
                    help="result JSON path (default BENCH_<git-sha>.json)")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="max relative qps/latency regression (default "
                    "0.20 = fail on >20%%)")
    ap.add_argument("--recall-tolerance", type=float, default=0.05,
                    help="max absolute recall@k drop")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record this measurement (with --slack folded "
                    "in) as the committed baseline and exit")
    ap.add_argument("--slack", type=float, default=0.5,
                    help="headroom folded into --write-baseline")
    ap.add_argument("--no-compare", action="store_true",
                    help="measure + write JSON, skip the gate")
    args = ap.parse_args(argv)

    sha = git_sha()
    print(f"ci_gate: measuring quick benches at {sha} "
          f"({args.n_docs} docs, {args.requests} requests x {args.batch}, "
          f"best of {args.repeats}) ...")
    metrics = measure(args.n_docs, args.requests, args.batch, args.k,
                      args.repeats)
    for name in METRICS:
        print(f"  {name:20s} {metrics[name]:10.2f}")

    if args.write_baseline:
        doc = {"sha": sha, "config": {"n_docs": args.n_docs,
                                      "requests": args.requests,
                                      "batch": args.batch, "k": args.k},
               "slack": args.slack,
               "metrics": with_slack(metrics, args.slack)}
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline written to {args.baseline} "
              f"(slack {args.slack:.0%})")
        return 0

    out_path = args.output or f"BENCH_{sha}.json"
    with open(out_path, "w") as f:
        json.dump({"sha": sha, "metrics": metrics}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")

    if args.no_compare:
        return 0
    if not os.path.exists(args.baseline):
        print(f"ERROR: no baseline at {args.baseline} — run "
              "--write-baseline once and commit it", file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(metrics, baseline, args.tolerance,
                       args.recall_tolerance)
    if failures:
        print(f"\nPERF REGRESSION vs baseline "
              f"(recorded at {baseline.get('sha', '?')}):",
              file=sys.stderr)
        for line in failures:
            print(f"  FAIL {line}", file=sys.stderr)
        return 1
    print(f"gate passed vs baseline {baseline.get('sha', '?')} "
          f"(tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
