"""Sharded serving through the front door: bit-parity vs single-host.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src:. python benchmarks/sharded_bench.py --quick

One :class:`RetrievalService` serves the same corpus twice — a
single-host IVF index and the same spec sharded over every forced host
device (``ShardSpec(shards=N)``) — and streams identical request waves
at both.  Parity is the strict serving contract: for every request the
sharded result must match single-host in ids AND raw score bytes
(``scores.tobytes()``), not approximately.  The stream then keeps going
through live ``update()`` (delta segments land on both sides) and
``compact()`` (the sharded fold re-shards onto the same mesh), and a
replicated lane (``ShardSpec(shards=N//2, replicas=2)``) checks that
read scaling preserves the same bytes.

Reported metrics (also written by ``--gate-json`` for the CI gate):

* ``sharded_parity``        — fraction of compared requests bit-identical
  (the gate requires exactly 1.0),
* ``sharded_qps``           — query rows/s through the sharded version,
* ``sharded_lost_requests`` — submitted − served + still-queued (must
  be 0: hot-swapping shards may never drop an admitted request).

All four scorer backends run through explicit stage pipelines (quantizer
tails select the real fp16/int8/1-bit scorers); device count is forced
via ``XLA_FLAGS`` so the lane is CPU-only and CI-stable.
"""

import argparse
import dataclasses
import json
import os
import sys
import time

# must land before jax initialises: the bench proves sharded serving on
# forced host devices when no real multi-device platform is attached
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np

from repro.retrieval.api import IndexSpec, ShardSpec, build_index
from repro.serve import MicroBatcher, QueryOptions, RetrievalService

#: explicit stage pipelines — the quantizer tail is what selects the
#: quantized scorer (a trailing post-transform would silently fall back
#: to the float decode path, which is *not* bit-stable across shard
#: shapes; see scorer_for_pipeline)
BASE = (("CenterNorm", {}), ("PCA", {"dim": 32}))
TAILS = {
    "float": (),
    "fp16": (("FloatCast", {}),),
    "int8": (("Int8Quantizer", {}),),
    "onebit": (("OneBitQuantizer", {"offset": 0.5}),),
}


def make_corpus(n_docs: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    docs = rng.standard_normal((n_docs, d)).astype(np.float32)
    queries = rng.standard_normal((max(256, 64), d)).astype(np.float32)
    extra = rng.standard_normal((24, d)).astype(np.float32)
    return docs, queries, extra


def wave(svc, names, queries, n_requests, batch, k):
    """Submit one request wave to every index in ``names``, wait for all
    results, and return {name: [(ids, score_bytes), ...]} in stream
    order.  Waves are joined before the caller mutates anything, so both
    sides always see the same index state for the same request."""
    handles = {name: [] for name in names}
    for r in range(n_requests):
        off = (r * batch) % (len(queries) - batch)
        block = queries[off: off + batch]
        for name in names:
            handles[name].append(
                svc.query(block, QueryOptions(index=name, k=k)))
    out = {}
    for name in names:
        rows = []
        for h in handles[name]:
            res = h.result(timeout=600)
            rows.append((np.asarray(res.ids), res.scores.tobytes()))
        out[name] = rows
    return out


def compare_waves(results, ref: str, other: str):
    """(n_compared, n_identical) between two indexes' wave results."""
    same = 0
    pairs = list(zip(results[ref], results[other]))
    for (ids_a, bytes_a), (ids_b, bytes_b) in pairs:
        if np.array_equal(ids_a, ids_b) and bytes_a == bytes_b:
            same += 1
    return len(pairs), same


def run_backend(backend: str, docs, queries, extra, *, shards, nlist,
                nprobe, n_requests, batch, k) -> dict:
    """Serve single-host vs sharded (vs replicated) mutable indexes
    through one service; stream → update → stream → compact → stream."""
    spec = IndexSpec(stages=BASE + TAILS[backend], ivf=(nlist, nprobe),
                     backend="jnp", mutable=True)
    sample = queries[:128]
    single = build_index(spec, docs, sample)
    sharded = build_index(
        dataclasses.replace(spec, shard=ShardSpec(shards=shards)),
        docs, sample)
    names = ["single", "sharded"]
    svc = RetrievalService(default_k=k,
                           batcher=MicroBatcher(max_batch=4 * batch))
    svc.register("single", index=single)
    svc.register("sharded", index=sharded)
    if shards >= 2 and shards % 2 == 0:
        replicated = build_index(
            dataclasses.replace(
                spec, shard=ShardSpec(shards=shards // 2, replicas=2)),
            docs, sample)
        svc.register("replicated", index=replicated)
        names.append("replicated")

    compared = identical = 0

    def score(results):
        nonlocal compared, identical
        for other in names[1:]:
            n, same = compare_waves(results, "single", other)
            compared += n
            identical += same

    # phase 1: clean stream
    score(wave(svc, names, queries, n_requests, batch, k))
    # phase 2: live update lands on every side, stream again
    for name in names:
        svc.update(name, add=extra)
    first_gid = len(docs)
    for name in names:
        svc.update(name, delete=range(first_gid, first_gid + len(extra) // 2))
    score(wave(svc, names, queries, n_requests, batch, k))
    # phase 3: compact (the sharded fold re-shards onto its mesh), stream
    for name in names:
        svc.compact(name)
    score(wave(svc, names, queries, n_requests, batch, k))

    # throughput: time a sharded-only burst (parity waves above already
    # paid every jit compile)
    t0 = time.perf_counter()
    rows = 0
    handles = []
    for r in range(n_requests):
        off = (r * batch) % (len(queries) - batch)
        handles.append(svc.query(queries[off: off + batch],
                                 QueryOptions(index="sharded", k=k)))
        rows += batch
    for h in handles:
        h.result(timeout=600)
    qps = rows / (time.perf_counter() - t0)

    stats = svc.stats()
    lost = (stats["requests_submitted"] - stats["requests_served"]
            - stats["cache_hits"] + stats["queue_depth"])
    shard_rows = None
    for row in stats["indexes"]["sharded"]["versions"].values():
        shard_rows = row.get("shards", shard_rows)
    svc.close()
    return {"compared": compared, "identical": identical, "qps": qps,
            "lost": int(lost), "shards": shard_rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="small corpus / few requests (the CI gate lane)")
    ap.add_argument("--n-docs", type=int, default=0)
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--shards", type=int, default=0,
                    help="doc shards (default: every forced device)")
    ap.add_argument("--nlist", type=int, default=0)
    ap.add_argument("--nprobe", type=int, default=0)
    ap.add_argument("--gate-json", default=None,
                    help="write {sharded_parity, sharded_qps, "
                    "sharded_lost_requests} here for the CI gate")
    args = ap.parse_args(argv)

    import jax
    n_dev = jax.device_count()
    shards = args.shards or n_dev
    n_docs = args.n_docs or (1003 if args.quick else 20_000)
    n_requests = args.requests or (6 if args.quick else 40)
    nlist = args.nlist or (12 if args.quick else 64)
    nprobe = args.nprobe or (6 if args.quick else 16)

    docs, queries, extra = make_corpus(n_docs, args.dim)
    print(f"sharded bench: {n_docs} docs x {args.dim} dims over "
          f"{shards} shards ({n_dev} devices), nlist={nlist} "
          f"nprobe={nprobe}, {n_requests} requests x {args.batch} "
          f"per phase\n")

    compared = identical = lost = 0
    qps_all = []
    for backend in TAILS:
        r = run_backend(backend, docs, queries, extra, shards=shards,
                        nlist=nlist, nprobe=nprobe,
                        n_requests=n_requests, batch=args.batch, k=args.k)
        compared += r["compared"]
        identical += r["identical"]
        lost += r["lost"]
        qps_all.append(r["qps"])
        verdict = "BIT-IDENTICAL" if r["identical"] == r["compared"] \
            else "DIVERGED"
        print(f"  {backend:7s} {r['identical']:3d}/{r['compared']:3d} "
              f"requests bit-identical  {r['qps']:8.0f} q/s  "
              f"lost={r['lost']}  {verdict}")
        if backend == "int8" and r["shards"]:
            docs_per = ", ".join(str(s["n_docs"]) for s in r["shards"])
            print(f"          shard rollup: n_docs per shard [{docs_per}]")

    parity = identical / compared if compared else 0.0
    qps = max(qps_all)
    print(f"\n  sharded_parity={parity:.3f}  sharded_qps={qps:.0f}  "
          f"sharded_lost_requests={lost}")
    if args.gate_json:
        with open(args.gate_json, "w") as f:
            json.dump({"sharded_parity": parity, "sharded_qps": qps,
                       "sharded_lost_requests": float(lost)}, f, indent=2)
            f.write("\n")
        print(f"  wrote {args.gate_json}")
    if parity != 1.0 or lost:
        print("FAIL: sharded serving must be bit-identical to "
              "single-host with zero lost requests", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
