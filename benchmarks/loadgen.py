"""Open-loop load generation: drive the RetrievalService to saturation.

    PYTHONPATH=src:. python benchmarks/loadgen.py            # fixed-rate trial
    PYTHONPATH=src:. python benchmarks/loadgen.py --sweep    # find qps @ SLO
    PYTHONPATH=src:. python benchmarks/loadgen.py --quick    # CI smoke

A closed-loop driver (submit, wait, submit …) can never see a queue: its
offered rate collapses to whatever the service sustains, and the latency
it reports silently omits every request the service *would* have delayed
— the classic coordinated-omission trap.  This generator is **open
loop**: an arrival schedule is drawn up front (Poisson, or an on/off
bursty process with the same mean rate), the submitter fires each request
at its scheduled instant whether or not earlier ones came back, and a
request's latency runs from its *scheduled* arrival to the moment its
last micro-batch completes (``ServeResult.latency_s`` plus any submitter
lag).  Queueing delay under overload is therefore measured, not hidden.

Realism knobs, all exercised by the default run:

* **Zipf-skewed popularity** — every query row is drawn from a fixed pool
  with P(rank r) ∝ r^-alpha, so a hot head repeats (what a result cache
  sees in production) while a long tail stays cold.
* **Mixed request menu** — weighted (rows, k, nprobe, lane) combinations:
  1-row interactive lookups next to multi-row bulk blocks, fast/full
  probe widths, distinct rate-limit lanes.
* **Interleaved update/delete traffic** — a mutator thread applies
  ``service.update(add=…, delete=…)`` against the live mutable index at a
  fixed cadence while queries fly, and the collector verifies that no
  query submitted after a delete returned ever surfaces the deleted id.

Verification is part of every trial: zero lost requests (every admitted
handle resolves; ``requests_submitted == requests_served`` and an empty
queue at quiesce), and — when the cache is on — a cached block is
bit-identical to the dispatch it skipped.

``--sweep`` ramps the offered rate geometrically until the p99 SLO
breaks, then bisects to the saturation point, reporting the largest
offered rate (rows/s) the service sustains within the SLO.
"""

import argparse
import dataclasses
import sys
import threading
import time
from typing import Optional

import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import IndexSpec, build_index
from repro.serve import AdaptiveBatcher, QueryOptions, RateLimited, \
    RetrievalService
from repro.serve.service import QueueFull


@dataclasses.dataclass(frozen=True)
class MenuItem:
    """One request shape: how many rows, search width, rate-limit lane."""

    weight: float
    rows: int
    k: int
    nprobe: Optional[int]
    lane: str


DEFAULT_MENU = (
    MenuItem(0.55, 1, 10, 4, "interactive"),    # hot path: 1-row, fast probe
    MenuItem(0.25, 4, 10, 8, "interactive"),    # small block, default probe
    MenuItem(0.15, 16, 20, 8, "bulk"),          # bulk scoring block
    MenuItem(0.05, 32, 20, 16, "bulk"),         # recall-heavy bulk block
)


@dataclasses.dataclass
class Workload:
    """A fully pre-drawn trial: no randomness left on the hot path."""

    arrivals: np.ndarray          # (n,) seconds from trial start, sorted
    menu_ids: np.ndarray          # (n,) index into menu
    row_ids: list                 # per request: pool indices, len = rows
    offered_rows_per_s: float


def zipf_weights(n: int, alpha: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** alpha
    return w / w.sum()


def build_workload(rng, *, duration_s: float, rows_per_s: float,
                   arrival: str, menu, pool_size: int,
                   zipf_alpha: float, burst_period_s: float = 0.25,
                   burst_duty: float = 0.25) -> Workload:
    """Draw the arrival schedule + per-request shapes for one trial.

    ``rows_per_s`` is the offered rate in query *rows*; the request rate
    follows from the menu's mean rows/request.  ``arrival="poisson"``
    gives exponential inter-arrivals; ``"bursty"`` keeps the same mean
    rate but concentrates arrivals in the first ``burst_duty`` fraction
    of every ``burst_period_s`` window — same load, far meaner queues.
    """
    weights = np.asarray([m.weight for m in menu], np.float64)
    weights = weights / weights.sum()
    mean_rows = float(sum(w * m.rows for w, m in zip(weights, menu)))
    req_rate = rows_per_s / mean_rows
    n = max(1, int(round(req_rate * duration_s)))

    if arrival == "poisson":
        gaps = rng.exponential(1.0 / req_rate, size=n)
        arrivals = np.cumsum(gaps)
    elif arrival == "bursty":
        # on/off modulated Poisson: arrivals land only inside the duty
        # window of each period, at rate/duty, so the mean matches
        on_rate = req_rate / burst_duty
        t, out = 0.0, []
        while len(out) < n:
            window_start = (t // burst_period_s) * burst_period_s
            window_end = window_start + burst_duty * burst_period_s
            if t < window_start:            # (never: t advances forward)
                t = window_start
            if t >= window_end:             # past this window's duty: hop
                t = window_start + burst_period_s
                continue
            t += rng.exponential(1.0 / on_rate)
            if t < window_end:
                out.append(t)
        arrivals = np.asarray(out[:n])
    else:
        raise ValueError(f"unknown arrival process {arrival!r} "
                         "(poisson | bursty)")

    menu_ids = rng.choice(len(menu), size=n, p=weights)
    pool_p = zipf_weights(pool_size, zipf_alpha)
    row_ids = [rng.choice(pool_size, size=menu[m].rows, p=pool_p)
               for m in menu_ids]
    return Workload(arrivals=arrivals, menu_ids=menu_ids, row_ids=row_ids,
                    offered_rows_per_s=rows_per_s)


class Mutator(threading.Thread):
    """Interleaved update/delete traffic against the live mutable index.

    Every ``interval_s``: add a small doc block, and delete a couple of
    ids from a block added earlier.  Keeps a timestamped delete log so
    the collector can assert no query submitted after a delete returned
    ever sees the deleted id.
    """

    def __init__(self, service, name: str, fresh_docs: np.ndarray,
                 interval_s: float, rng, block: int = 4):
        super().__init__(name="loadgen-mutator", daemon=True)
        self.service = service
        self.index_name = name
        self.fresh = fresh_docs
        self.interval_s = interval_s
        self.block = block
        self.rng = rng
        self.deleted_log: list = []     # [(wall time, gid)], append-only
        self.updates = 0
        self.added = 0
        self.deleted = 0
        self._deletable: list = []
        self._halt = threading.Event()   # NB: Thread itself owns `_stop`

    def stop(self) -> None:
        self._halt.set()

    def run(self) -> None:
        off = 0
        while not self._halt.wait(self.interval_s):
            add = None
            if off + self.block <= len(self.fresh):
                add = self.fresh[off: off + self.block]
                off += self.block
            delete = None
            if len(self._deletable) >= 2:
                picks = self.rng.choice(len(self._deletable), size=2,
                                        replace=False)
                delete = [self._deletable[i] for i in sorted(picks)]
                for gid in delete:
                    self._deletable.remove(gid)
            if add is None and delete is None:
                return                   # fresh docs exhausted, nothing left
            report = self.service.update(self.index_name, add=add,
                                         delete=delete)
            now = time.perf_counter()
            self.updates += 1
            self.added += report["added"]
            self.deleted += report["deleted"]
            if delete:
                self.deleted_log.extend((now, gid) for gid in delete)
            if report["gid_range"] is not None:
                self._deletable.extend(range(*report["gid_range"]))


def warmup(service, name: str, pool: np.ndarray, menu,
           max_batch: int, timeout_s: float) -> None:
    """Compile the search graphs the trial will hit before the clock
    starts: one small and one full-width block per menu shape.  A cold
    server pays these once at startup, not per request — measuring them
    inside the trial would charge steady-state latency for a one-time
    cost."""
    sizes, rows = set(), 1
    while rows <= max_batch:            # every pow2 bucket the batcher forms
        sizes.add(rows)
        rows *= 2
    for item in menu:
        for rows in sorted(sizes):
            q = pool[np.arange(rows) % len(pool)]
            service.query(q, QueryOptions(index=name, k=item.k,
                                          nprobe=item.nprobe,
                                          lane=item.lane)) \
                .result(timeout=timeout_s)


def run_trial(service, name: str, pool: np.ndarray, menu,
              workload: Workload, *, timeout_s: float = 120.0,
              mutator: Optional[Mutator] = None) -> dict:
    """Fire one open-loop trial; returns the measured report dict."""
    records = []          # (handle, scheduled_s, submitted_s)
    shed_limit = shed_queue = 0
    base = service.stats()      # don't bill warmup traffic to the trial
    t0 = time.perf_counter()
    if mutator is not None:
        mutator.start()
    for i in range(len(workload.arrivals)):
        sched = workload.arrivals[i]
        lag = sched - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        item = menu[workload.menu_ids[i]]
        q = pool[workload.row_ids[i]]
        submitted = time.perf_counter() - t0
        try:
            h = service.query(q, QueryOptions(index=name, k=item.k,
                                              nprobe=item.nprobe,
                                              lane=item.lane))
        except RateLimited:
            shed_limit += 1
            continue
        except QueueFull:
            shed_queue += 1
            continue
        records.append((h, sched, submitted))
    if mutator is not None:
        mutator.stop()
        mutator.join(timeout=10.0)

    # collect: latency runs from the *scheduled* arrival (anti-coordinated-
    # omission) to the request's last micro-batch completing
    lat, lost, deleted_seen = [], 0, 0
    log = tuple(mutator.deleted_log) if mutator is not None else ()
    for h, sched, submitted in records:
        try:
            res = h.result(timeout=timeout_s)
        except Exception:
            lost += 1
            continue
        lat.append((submitted - sched) + res.latency_s)
        if log:
            forbidden = {gid for (t, gid) in log if t <= t0 + submitted}
            if forbidden and np.isin(res.ids, sorted(forbidden)).any():
                deleted_seen += 1
    wall = time.perf_counter() - t0

    stats = service.stats()
    ms = np.asarray(lat) * 1000.0 if lat else np.asarray([np.nan])
    return {
        "offered_rows_per_s": workload.offered_rows_per_s,
        "wall_s": wall,
        "arrivals": len(workload.arrivals),
        "admitted": len(records),
        "shed_rate_limited": shed_limit,
        "shed_queue_full": shed_queue,
        "lost": lost,
        "deleted_ids_resurfaced": deleted_seen,
        # completed rows/s: engine-dispatched rows plus rows answered
        # straight from the result cache — both count as served traffic
        "served_rows_per_s":
            ((stats["queries_served"] - base["queries_served"])
             + (stats["cache"]["hits"] - base["cache"]["hits"]
                if "cache" in stats else 0)) / wall,
        "cache_hits": stats["cache_hits"] - base["cache_hits"],
        "queue_high_water": stats["queue_high_water"],
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
        "mean_ms": float(np.mean(ms)),
        "conserved": (stats["requests_submitted"] == stats["requests_served"]
                      and stats["queue_depth"] == 0),
        "updates": 0 if mutator is None else mutator.updates,
        "docs_added": 0 if mutator is None else mutator.added,
        "docs_deleted": 0 if mutator is None else mutator.deleted,
    }


def verify_cache_identity(service, name: str, pool: np.ndarray,
                          menu) -> int:
    """Submit head-of-pool blocks twice: the repeat must be a cache hit
    and bit-identical to the dispatched original.  Returns rows checked;
    raises on any mismatch."""
    checked = 0
    for item in menu:
        # offset the pool rows so these blocks were never part of trial
        # traffic: the first submission is then a guaranteed dispatch and
        # the repeat a guaranteed cache hit
        q = pool[np.arange(item.rows) % len(pool)] + 0.25
        h = service.query(q, QueryOptions(index=name, k=item.k,
                                          nprobe=item.nprobe))
        first = h.result(timeout=60.0)
        if first.request_id < 0:
            raise SystemExit("cache: probe block was unexpectedly cached")
        again = service.query(q, QueryOptions(index=name, k=item.k,
                                              nprobe=item.nprobe))
        if not again.done():
            raise SystemExit(f"cache: repeat of a {item.rows}-row block "
                             "was not served from cache")
        res = again.result()
        if not (np.array_equal(first.scores, res.scores)
                and np.array_equal(first.ids, res.ids)):
            raise SystemExit("cache hit is not bit-identical to the "
                             "dispatch it replaced")
        checked += item.rows
    return checked


def make_service(args) -> RetrievalService:
    batcher = None if args.fixed_batch else \
        AdaptiveBatcher(min_batch=8, max_batch=args.max_batch)
    svc = RetrievalService(default_k=10, max_batch=args.max_batch,
                           max_pending_queries=args.max_pending,
                           batcher=batcher, cache_rows=args.cache_rows)
    return svc


def trial_ok(r: dict, slo_ms: float) -> bool:
    return (r["lost"] == 0 and r["shed_queue_full"] == 0
            and r["conserved"] and r["p99_ms"] <= slo_ms)


def report(tag: str, r: dict) -> None:
    print(f"  {tag:24s} offered {r['offered_rows_per_s']:7.0f} rows/s "
          f"served {r['served_rows_per_s']:7.0f}  "
          f"p50={r['p50_ms']:6.1f}ms p99={r['p99_ms']:7.1f}ms  "
          f"shed={r['shed_rate_limited'] + r['shed_queue_full']} "
          f"lost={r['lost']} hiwater={r['queue_high_water']}"
          + (f"  cache_hits={r['cache_hits']}" if r["cache_hits"] else "")
          + (f"  updates={r['updates']}" if r["updates"] else ""))


def find_saturation(args, name, pool, menu, rng) -> dict:
    """Geometric ramp then bisection: the largest offered rows/s whose
    trial stays within the p99 SLO with zero lost/shed requests."""
    best, lo, hi = None, None, None
    rate = args.qps
    while rate <= args.sweep_max:
        r = sweep_trial(args, name, pool, menu, rng, rate)
        report(f"ramp @{rate:.0f}", r)
        if trial_ok(r, args.slo_ms):
            best, lo = r, rate
            rate *= 2.0
        else:
            hi = rate
            break
    if hi is not None and lo is not None:
        for _ in range(args.sweep_bisect):
            mid = (lo + hi) / 2.0
            r = sweep_trial(args, name, pool, menu, rng, mid)
            report(f"bisect @{mid:.0f}", r)
            if trial_ok(r, args.slo_ms):
                best, lo = r, mid
            else:
                hi = mid
    if best is None:
        raise SystemExit(f"no offered rate ≥ {args.qps} rows/s met the "
                         f"p99 ≤ {args.slo_ms}ms SLO — lower --qps")
    return best


def sweep_trial(args, name, pool, menu, rng, rate) -> dict:
    # fresh service per trial point: no queue or counter state bleeds
    # between rates, so each point is an independent measurement
    svc = make_service(args)
    svc.register(name, args.index_factory())
    try:
        warmup(svc, name, pool, menu, args.max_batch, args.timeout)
        wl = build_workload(rng, duration_s=args.duration,
                            rows_per_s=rate, arrival=args.arrival,
                            menu=menu, pool_size=len(pool),
                            zipf_alpha=args.zipf)
        return run_trial(svc, name, pool, menu, wl,
                         timeout_s=args.timeout)
    finally:
        svc.close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="open-loop load generator for RetrievalService")
    ap.add_argument("--quick", action="store_true",
                    help="tiny corpus / short trial (CI smoke)")
    ap.add_argument("--arrival", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--qps", type=float, default=0,
                    help="offered rate in query rows/s (sweep: start rate)")
    ap.add_argument("--duration", type=float, default=0,
                    help="seconds per trial")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="p99 latency SLO (scheduled arrival → done)")
    ap.add_argument("--sweep", action="store_true",
                    help="ramp + bisect to the saturation rate @ SLO")
    ap.add_argument("--sweep-max", type=float, default=200_000.0)
    ap.add_argument("--sweep-bisect", type=int, default=3)
    ap.add_argument("--n-docs", type=int, default=0)
    ap.add_argument("--pool", type=int, default=0,
                    help="distinct queries in the Zipf pool")
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="Zipf popularity exponent")
    ap.add_argument("--cache-rows", type=int, default=4096,
                    help="result-cache capacity (0 disables)")
    ap.add_argument("--rate-limit", type=float, default=0,
                    help="rows/s budget; bulk lane capped at 30%% of it")
    ap.add_argument("--update-every", type=float, default=0.2,
                    help="seconds between live update/delete ops "
                         "(0 disables the mutator)")
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--max-pending", type=int, default=8192)
    ap.add_argument("--fixed-batch", action="store_true",
                    help="fixed-cap MicroBatcher instead of adaptive")
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_docs = args.n_docs or (2000 if args.quick else 50_000)
    pool_size = args.pool or (64 if args.quick else 1024)
    duration = args.duration or (1.5 if args.quick else 10.0)
    qps = args.qps or (300.0 if args.quick else 2000.0)
    args.duration, args.qps = duration, qps

    rng = np.random.default_rng(args.seed)
    kb = make_dpr_like_kb(n_queries=pool_size, n_docs=n_docs,
                          seed=args.seed)
    fresh = make_dpr_like_kb(n_queries=8, n_docs=max(64, n_docs // 10),
                             seed=args.seed + 1)
    pool = np.asarray(kb.queries, np.float32)
    fresh_docs = np.asarray(fresh.docs, np.float32)
    nlist = max(8, int(np.sqrt(n_docs)))
    spec = IndexSpec(method="pca_int8", dim=64 if args.quick else 128,
                     ivf=(nlist, max(2, nlist // 8)), mutable=True,
                     backend="jnp", post=False)

    def index_factory():
        return build_index(spec, kb.docs, kb.queries[:min(256, pool_size)])

    args.index_factory = index_factory
    menu = DEFAULT_MENU
    name = "kb"

    print(f"loadgen: {n_docs} docs, mutable IVF(nlist={nlist}), "
          f"{args.arrival} arrivals, Zipf(a={args.zipf}) over "
          f"{pool_size} queries, menu of {len(menu)} shapes, "
          f"cache={args.cache_rows} rows\n")

    # --- fixed-rate trial with the full production shape ------------------
    svc = make_service(args)
    svc.register(name, index_factory())
    try:
        warmup(svc, name, pool, menu, args.max_batch, args.timeout)
        if args.rate_limit:                  # after warmup: don't shed it
            svc.set_rate_limit(name, qps=args.rate_limit,
                               lanes={"bulk": 0.3})
        mut = None
        if args.update_every:
            mut = Mutator(svc, name, fresh_docs,
                          interval_s=args.update_every,
                          rng=np.random.default_rng(args.seed + 2))
        wl = build_workload(rng, duration_s=duration, rows_per_s=qps,
                            arrival=args.arrival, menu=menu,
                            pool_size=pool_size, zipf_alpha=args.zipf)
        r = run_trial(svc, name, pool, menu, wl, timeout_s=args.timeout,
                      mutator=mut)
        report("fixed-rate", r)
        if not r["conserved"]:
            raise SystemExit("conservation violated: submitted != served "
                             "at quiesce")
        if r["lost"]:
            raise SystemExit(f"{r['lost']} requests lost")
        if r["deleted_ids_resurfaced"]:
            raise SystemExit(f"{r['deleted_ids_resurfaced']} results "
                             "contained tombstoned doc ids")
        if args.cache_rows:
            n = verify_cache_identity(svc, name, pool, menu)
            print(f"  cache identity verified on {n} rows "
                  "(hit == dispatch, bit for bit)")
        print("  zero lost requests, conservation holds, no deleted id "
              "resurfaced\n")
    finally:
        svc.close()

    # --- saturation sweep -------------------------------------------------
    if args.sweep:
        print(f"saturation sweep: p99 ≤ {args.slo_ms:.0f}ms, "
              f"{duration:.1f}s per point")
        best = find_saturation(args, name, pool, menu, rng)
        print(f"\nsaturation: {best['offered_rows_per_s']:.0f} rows/s "
              f"offered within SLO (p99={best['p99_ms']:.1f}ms ≤ "
              f"{args.slo_ms:.0f}ms, zero lost)")


if __name__ == "__main__":
    sys.exit(main())
