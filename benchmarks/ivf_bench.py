"""IVF benchmark: recall@k and queries/sec vs exact search, per backend.

For each scorer backend (float / fp16 / int8 / 1-bit) the corpus is encoded
once through a ``CompressedIndex`` and promoted to approximate search with
``to_ivf`` (routing fitted on the decode of the stored representation, so
the router sees exactly what the scorer scores).  The nprobe sweep then
traces the recall/latency trade-off against the backend's *own* exact
ranking — the IVF loss, isolated from the compression loss the paper
already quantifies.

Timing is serving-shaped: both exact and IVF paths are dispatched in small
query blocks (requests, not offline batch scans), which is the regime IVF
exists for.  The gather-based probe moves ``Q·C·d`` bytes per block against
the exact scan's ``D·d``, so the crossover sits near candidate fraction
``nprobe/nlist ≈ 1/Q`` — small blocks and small probe fractions win big,
full-recall probes lose to the plain GEMM on a corpus this size.

The default corpus is ``clustered`` (topical low-rank structure, like real
DPR embeddings — k-means routing works).  ``--dataset hotpot-like`` keeps
the paper's deliberately noise-dominated synthetic, where *no* coarse
router can do much better than random probing: recall there degrades
toward ``nprobe/nlist``, which is worth seeing once.

    PYTHONPATH=src:. python benchmarks/ivf_bench.py --quick
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import base_parser, default_kb, git_sha, print_csv
from repro.core import CenterNorm, CompressionPipeline
from repro.data import make_dpr_like_kb
from repro.retrieval import CompressedIndex, backend_tail_stages, recall_at_k
from repro.retrieval.ivf import PROBE_BLOCK, probe_and_score
from repro.retrieval.topk import merge_topk_block, similarity

SERVE_Q = 4          # rows per dispatched request block


def _bench_stream(search, queries, reps: int = 3) -> float:
    """Mean seconds to serve ``queries`` in SERVE_Q-row request blocks."""
    blocks = [queries[s: s + SERVE_Q]
              for s in range(0, queries.shape[0], SERVE_Q)]
    jax.block_until_ready(search(blocks[0]))       # compile
    if blocks[-1].shape != blocks[0].shape:        # ragged final block
        jax.block_until_ready(search(blocks[-1]))
    t0 = time.perf_counter()
    for _ in range(reps):
        for b in blocks:
            jax.block_until_ready(search(b))
    return (time.perf_counter() - t0) / reps


def _timeit(fn, reps: int = 5) -> float:
    jax.block_until_ready(fn())                    # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def stage_timings(ivf, queries, k: int, nprobe: int) -> dict:
    """Decomposed IVF hot-path timings in ms: route / gather+score / top-k.

    Stages are separated by nested jit graphs — ``route`` is coarse
    similarity + probe selection, ``gather_score`` is the list gather plus
    backend scoring *minus* the routing it re-runs, ``topk`` is the
    sort-free streaming merge on the candidate scores, scanned in the
    same ``PROBE_BLOCK``-list blocks as the search path.  The sum tracks
    (not equals) the fused end-to-end search, which overlaps these phases.
    """
    qf = jnp.asarray(ivf.encode_queries(queries), jnp.float32)
    params = ivf.scorer.params()
    max_len = int(ivf.lists.shape[1])

    f_route = jax.jit(lambda q: jax.lax.top_k(
        similarity(q, ivf.centroids, ivf.sim), nprobe))
    f_ps = jax.jit(lambda q: probe_and_score(
        q, ivf.centroids, ivf.lists, ivf.storage, ivf.scorer, params,
        ivf.sim, nprobe))

    @jax.jit
    def f_topk(s, c):
        n_q, width = s.shape
        g = min(PROBE_BLOCK, nprobe) * max_len
        pad = -width % g
        s_p = jnp.pad(s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        c_p = jnp.pad(c, ((0, 0), (0, pad)), constant_values=-1)
        steps = (jnp.moveaxis(s_p.reshape(n_q, -1, g), 1, 0),
                 jnp.moveaxis(c_p.reshape(n_q, -1, g), 1, 0))
        init = (jnp.full((n_q, k), -jnp.inf, jnp.float32),
                jnp.full((n_q, k), -1, jnp.int32))
        out, _ = jax.lax.scan(
            lambda run, blk: (merge_topk_block(*run, *blk, k), None),
            init, steps)
        return out

    t_route = _timeit(lambda: f_route(qf))
    t_ps = _timeit(lambda: f_ps(qf))
    s, cand, valid = f_ps(qf)
    cand = jnp.where(valid, cand, -1)
    t_topk = _timeit(lambda: f_topk(s, cand))
    return {"n_queries": int(qf.shape[0]), "nprobe": nprobe,
            "route_ms": t_route * 1e3,
            "gather_score_ms": max(t_ps - t_route, 0.0) * 1e3,
            "topk_ms": t_topk * 1e3}


def main(argv=None) -> list[dict]:
    ap = base_parser("IVF recall/throughput vs exact search",
                     datasets=("clustered", "hotpot-like", "nq-like"))
    ap.add_argument("--nlist", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)
    if args.fast:
        args.n_docs = min(args.n_docs, 10_000)
        args.n_queries = min(args.n_queries, 128)
    if args.dataset == "clustered":
        kb = make_dpr_like_kb(n_queries=args.n_queries, n_docs=args.n_docs,
                              d=256, r_eff=48)
    else:
        kb = default_kb(args.dataset, n_docs=args.n_docs,
                        n_queries=args.n_queries)
    queries = kb.queries
    nlist = args.nlist
    nprobes = sorted({max(1, nlist // 32), max(1, nlist // 16),
                      max(1, nlist // 8), max(1, nlist // 4), nlist // 2})

    rows = []
    stages: dict[str, dict] = {}
    for name, tail in backend_tail_stages().items():
        pipe = CompressionPipeline([CenterNorm(), *tail])
        idx = CompressedIndex.build(kb.docs, queries[:256], pipe)
        _, want = idx.search(queries, args.k)
        want = np.asarray(want)
        t_exact = _bench_stream(lambda b: idx.search(b, args.k), queries)
        qps_exact = queries.shape[0] / t_exact
        rows.append({"backend": name, "bytes_per_doc": idx.nbytes // len(idx),
                     "nlist": 0, "nprobe": 0, "recall_at_k": 1.0,
                     "us_per_query": t_exact / queries.shape[0] * 1e6,
                     "qps": qps_exact, "speedup_vs_exact": 1.0})
        ivf = idx.to_ivf(nlist=nlist, nprobe=nlist // 2,
                         kmeans_iters=8 if args.fast else 15)
        for nprobe in nprobes:
            _, got = ivf.search(queries, args.k, nprobe=nprobe)
            rec = recall_at_k(np.asarray(got), want)
            t = _bench_stream(
                lambda b, p=nprobe: ivf.search(b, args.k, nprobe=p), queries)
            rows.append({"backend": name,
                         "bytes_per_doc": idx.nbytes // len(idx),
                         "nlist": ivf.nlist, "nprobe": nprobe,
                         "recall_at_k": rec,
                         "us_per_query": t / queries.shape[0] * 1e6,
                         "qps": queries.shape[0] / t,
                         "speedup_vs_exact": t_exact / t})
        stages[name] = stage_timings(ivf, queries[:64], args.k,
                                     max(1, nlist // 8))

    for r in rows:
        tag = ("exact" if r["nprobe"] == 0
               else f"ivf nlist={r['nlist']} nprobe={r['nprobe']}")
        print(f"  {r['backend']:7s} {tag:24s} {r['bytes_per_doc']:5d} B/doc "
              f"recall@{args.k} {r['recall_at_k']:.3f}  "
              f"{r['qps']:9.0f} q/s  {r['speedup_vs_exact']:5.2f}x",
              flush=True)
    print()
    for name, st in stages.items():
        print(f"  stages[{name}] nprobe={st['nprobe']} "
              f"({st['n_queries']} queries): route {st['route_ms']:.2f} ms  "
              f"gather+score {st['gather_score_ms']:.2f} ms  "
              f"top-k {st['topk_ms']:.2f} ms", flush=True)
    print()
    print_csv(rows, ["backend", "bytes_per_doc", "nlist", "nprobe",
                     "recall_at_k", "us_per_query", "qps",
                     "speedup_vs_exact"])
    # per-sha artifact: the recall/qps sweep plus the per-stage breakdown,
    # uploadable next to ci_gate's BENCH_<sha>.json
    artifact = f"BENCH_{git_sha()}_ivf.json"
    with open(artifact, "w") as f:
        json.dump({"sha": git_sha(),
                   "config": {"dataset": args.dataset,
                              "n_docs": int(args.n_docs),
                              "n_queries": int(args.n_queries),
                              "nlist": int(nlist), "k": int(args.k)},
                   "rows": rows, "stages": stages},
                  f, indent=2, sort_keys=True, default=float)
        f.write("\n")
    print(f"wrote {artifact}")
    return rows


if __name__ == "__main__":
    main()
