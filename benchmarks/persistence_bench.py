"""Cold-start benchmark: re-fit + re-encode vs ``load_index`` from artifact.

    PYTHONPATH=src:. python benchmarks/persistence_bench.py
    PYTHONPATH=src:. python benchmarks/persistence_bench.py --quick

The cost the artifact format removes: without persistence, every serve
process pays the full pipeline fit (PCA eigendecomposition, quantizer
codebooks, optional k-means router) plus corpus re-encode at start-up.
``load_index`` restores the same index — bit-identical rankings, verified
per row — from one ``.npz`` without touching the raw corpus.
"""

import argparse
import os
import sys
import tempfile
import time

import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import (IndexSpec, build_index, load_index,
                             load_index_meta, save_index)
from repro.utils import human_bytes


def rows_for(quick: bool):
    ivf = (64, 32) if quick else (200, 100)
    kmeans = 8 if quick else 15
    # post=False keeps each quantizer as the trailing stage, so storage (and
    # the artifact) is genuinely fp16 / int8 / bit-packed — the paper's
    # storage-level ratios, scored through the quantized kernel paths
    return [
        ("fp16 (2x)", IndexSpec(method="fp16", backend="jnp", post=False)),
        ("int8 (4x)", IndexSpec(method="int8", backend="jnp", post=False)),
        ("pca_int8 (24x)", IndexSpec(method="pca_int8", dim=128,
                                     backend="jnp", post=False)),
        ("pca_onebit (100x)", IndexSpec(method="pca_onebit", dim=245,
                                        backend="jnp", post=False)),
        ("pca_int8 + ivf", IndexSpec(method="pca_int8", dim=128,
                                     backend="jnp", post=False, ivf=ivf,
                                     kmeans_iters=kmeans)),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny corpus (CI smoke)")
    ap.add_argument("--n-docs", type=int, default=0)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)
    n_docs = args.n_docs or (4000 if args.quick else 50_000)
    n_queries = 64 if args.quick else 512

    kb = make_dpr_like_kb(n_queries=n_queries, n_docs=n_docs)
    queries = kb.queries

    print(f"cold-start: fit+encode vs load_index  "
          f"({n_docs} docs x 768 dims)\n")
    print(f"  {'recipe':20s} {'build':>8s} {'load':>8s} {'speedup':>8s} "
          f"{'artifact':>10s}  parity")
    with tempfile.TemporaryDirectory() as tmp:
        for name, spec in rows_for(args.quick):
            t0 = time.perf_counter()
            idx = build_index(spec, kb.docs, queries)
            _, want = idx.search(queries, args.k)   # includes first compile
            t_build = time.perf_counter() - t0

            path = os.path.join(tmp, "idx.npz")
            idx.save(path)
            size = os.path.getsize(path)

            t0 = time.perf_counter()
            idx2 = load_index(path)
            _, got = idx2.search(queries, args.k)
            t_load = time.perf_counter() - t0

            parity = np.array_equal(np.asarray(want), np.asarray(got))
            print(f"  {name:20s} {t_build:7.2f}s {t_load:7.2f}s "
                  f"{t_build / t_load:7.1f}x {human_bytes(size):>10s}  "
                  f"{'identical' if parity else 'DRIFT'}")
            if not parity:
                raise SystemExit(f"{name}: reloaded rankings drifted")

            if spec.ivf is not None:
                # the tiered (v3 chunked) cold-start row: lazy mmap maps
                # the manifest + aux and pages lists on demand, so first
                # results arrive without materialising the encoded tail
                p3 = os.path.join(tmp, "idx.v3")
                save_index(idx, p3, chunked=True)
                enc = load_index_meta(p3)["encoded_nbytes"]
                t0 = time.perf_counter()
                idx3 = load_index(p3, resident="all")
                t_open_all = time.perf_counter() - t0
                _, got3 = idx3.search(queries, args.k)
                t_all = time.perf_counter() - t0
                t0 = time.perf_counter()
                idx3 = load_index(p3, resident=enc // 20)
                t_open_m = time.perf_counter() - t0
                _, got_m = idx3.search(queries, args.k)
                t_mmap = time.perf_counter() - t0
                parity = (np.array_equal(np.asarray(want),
                                         np.asarray(got3))
                          and np.array_equal(np.asarray(want),
                                             np.asarray(got_m)))
                print(f"  {'  v3 resident=all':20s} {'':>8s} "
                      f"{t_all:7.2f}s {t_build / t_all:7.1f}x "
                      f"open {t_open_all * 1e3:5.0f}ms  "
                      f"{'identical' if parity else 'DRIFT'}")
                print(f"  {'  v3 lazy mmap (5%)':20s} {'':>8s} "
                      f"{t_mmap:7.2f}s {t_build / t_mmap:7.1f}x "
                      f"open {t_open_m * 1e3:5.0f}ms")
                if not parity:
                    raise SystemExit(f"{name}: v3 reload drifted")
    print("\n(build = pipeline fit + corpus encode + first search; "
          "load = artifact read + first search; the v3 rows reload the "
          "ivf recipe from the chunked artifact — lazy mmap answers "
          "without materialising the encoded lists)")


if __name__ == "__main__":
    sys.exit(main())
