"""Paper Figure 3: random-projection methods across target dimensions."""

from __future__ import annotations

from benchmarks.common import (base_parser, default_kb, evaluate_method,
                               print_csv)

METHODS = ("gaussian_projection", "sparse_projection", "dim_drop",
           "greedy_dim_drop")
DIMS = (32, 64, 128, 256, 512)


def main(argv=None) -> list[dict]:
    ap = base_parser("Paper Fig. 3: random projections")
    ap.add_argument("--runs", type=int, default=3,
                    help="max over N runs (paper reports max of 3)")
    args = ap.parse_args(argv)
    kb = default_kb(args.dataset, args.n_docs, args.n_queries)
    dims = DIMS[:3] if args.fast else DIMS

    rows = []
    for method in METHODS:
        runs = 1 if method == "greedy_dim_drop" else args.runs
        for dim in dims:
            best = None
            for seed in range(runs):
                r = evaluate_method(kb, method, dim, sims=("ip",),
                                    seed=seed)["rprec_ip"]
                best = r if best is None else max(best, r)
            rows.append({"method": method, "dim": dim, "rprec_ip": best})
            print(f"  {method:22s} d'={dim:4d} rprec={best:.3f}", flush=True)
    print()
    print_csv(rows, ["method", "dim", "rprec_ip"])
    return rows


if __name__ == "__main__":
    main()
