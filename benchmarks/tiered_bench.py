"""Tiered-storage benchmark: serve an index whose lists don't fit in RAM.

    PYTHONPATH=src:. python benchmarks/tiered_bench.py           # full sweep
    PYTHONPATH=src:. python benchmarks/tiered_bench.py --quick   # CI smoke

The tentpole claim of the tiered store: the resident-set size of a
chunked (v3) artifact is a *memory* knob, not a quality knob.  This
driver sweeps the hot-tier byte budget from 100% of the encoded lists
down to 5% and, at every point, serves the same open-loop Zipf/Poisson
workload (PR 7's load generator) through the RetrievalService front
door, measuring:

* **recall@10** — identical at every fraction by construction (the
  store-backed search is bit-identical to fully resident; ``--quick``
  asserts the bits, every budget, before serving),
* **p50/p99 latency + served qps** — the real cost of the cold tier:
  misses page encoded chunks off disk mid-query, hits ride the LRU hot
  tier that Zipf-skewed traffic keeps warm,
* **tier hit rate** from ``stats()["...tier"]`` — how much of the
  budgeted hot tier the workload actually exploits,
* **zero lost requests** — tiering may slow a query, never drop it.

At the smallest fraction the encoded storage exceeds the budget ≥ 4×
(20× at 5%), which is the "serve an index bigger than RAM" regime the
subsystem exists for.  Results land in ``BENCH_<git-sha>_tiered.json``.
"""

import argparse
import json
import os
import sys
import tempfile

import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import (IndexSpec, build_index, load_index,
                             load_index_meta, recall_at_k, save_index)
from repro.serve import AdaptiveBatcher, RetrievalService
from repro.utils import human_bytes

from benchmarks.ci_gate import git_sha
from benchmarks.loadgen import (DEFAULT_MENU, build_workload, run_trial,
                                warmup)

#: hot-tier budget as a fraction of the artifact's encoded list bytes;
#: 0.25 and below is the ≥ 4× over-budget regime the ISSUE gates on
FRACTIONS = (1.0, 0.5, 0.25, 0.1, 0.05)


def build_artifact(args, tmp):
    """Fit the index once, stream it to a chunked v3 artifact, and
    return (path, encoded_nbytes, pool, ref_ids, recall)."""
    kb = make_dpr_like_kb(n_queries=args.pool, n_docs=args.n_docs,
                          seed=args.seed)
    pool = np.asarray(kb.queries, np.float32)
    nlist = max(8, int(np.sqrt(args.n_docs)))
    spec = IndexSpec(method=args.method, dim=args.dim, backend="jnp",
                     post=False, ivf=(nlist, max(2, nlist // 4)),
                     kmeans_iters=8, kmeans_init="++", balanced_lists=True)
    idx = build_index(spec, kb.docs, kb.queries[:min(256, args.pool)])
    path = os.path.join(tmp, "kb.v3")
    save_index(idx, path, chunked=True)
    meta = load_index_meta(path)
    enc = meta["encoded_nbytes"]

    # recall@10 at the serving probe width vs the index's own exact
    # ranking (full probe over the same storage): IVF loss isolated from
    # compression loss, and — by bit-identity — the same number at every
    # residency fraction below
    probe_q = pool[:min(128, len(pool))]
    _, want = idx.search(probe_q, 10, nprobe=nlist)
    _, got = idx.search(probe_q, 10)
    rec = recall_at_k(np.asarray(got), np.asarray(want))
    return path, enc, pool, rec


def assert_bit_identity(path, budgets, pool, k=10):
    """Every budget must reproduce the fully-resident search bit for bit
    (ids and float32 score bits) before we bother timing anything."""
    q = pool[:min(64, len(pool))]
    full = load_index(path, resident="all")
    want_v, want_i = full.search(q, k)
    want_bits = np.asarray(want_v, np.float32).view(np.uint32)
    for budget in budgets:
        tiered = load_index(path, resident=budget)
        got_v, got_i = tiered.search(q, k)
        if not np.array_equal(np.asarray(got_i), np.asarray(want_i)):
            raise SystemExit(f"budget {budget}: tiered ids diverged from "
                             "fully resident")
        got_bits = np.asarray(got_v, np.float32).view(np.uint32)
        if not np.array_equal(got_bits, want_bits):
            raise SystemExit(f"budget {budget}: tiered score bits diverged "
                             "from fully resident")
    print(f"  bit-identity: {len(budgets)} budgets x {len(q)} queries "
          "identical to fully resident (ids + score bits)")


def serve_point(args, path, resident, pool, rng):
    """One sweep point: fresh service, register at the budget, warm up,
    fire the open-loop trial, return (report, tier_stats_or_None)."""
    svc = RetrievalService(
        default_k=10, max_batch=args.max_batch,
        max_pending_queries=args.max_pending,
        batcher=AdaptiveBatcher(min_batch=8, max_batch=args.max_batch),
        cache_rows=0)                  # every row must hit the store
    try:
        svc.register("kb", artifact=path, resident_budget=resident)
        warmup(svc, "kb", pool, DEFAULT_MENU, args.max_batch, args.timeout)
        wl = build_workload(rng, duration_s=args.duration,
                            rows_per_s=args.qps, arrival="poisson",
                            menu=DEFAULT_MENU, pool_size=len(pool),
                            zipf_alpha=args.zipf)
        r = run_trial(svc, "kb", pool, DEFAULT_MENU, wl,
                      timeout_s=args.timeout)
        row = svc.stats()["indexes"]["kb"]["versions"][1]
        return r, row.get("tier")
    finally:
        svc.close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serve a chunked artifact across resident-set budgets")
    ap.add_argument("--quick", action="store_true",
                    help="tiny corpus / short trials + bit-identity "
                         "assertion at every budget (CI smoke)")
    ap.add_argument("--method", default="pca_int8")
    ap.add_argument("--dim", type=int, default=0)
    ap.add_argument("--n-docs", type=int, default=0)
    ap.add_argument("--pool", type=int, default=0,
                    help="distinct queries in the Zipf pool")
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--qps", type=float, default=0,
                    help="offered rate in query rows/s")
    ap.add_argument("--duration", type=float, default=0,
                    help="seconds per sweep point")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-pending", type=int, default=8192)
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--output", default=None,
                    help="result JSON (default BENCH_<sha>_tiered.json)")
    args = ap.parse_args(argv)

    args.n_docs = args.n_docs or (3000 if args.quick else 40_000)
    args.pool = args.pool or (48 if args.quick else 512)
    args.dim = args.dim or (64 if args.quick else 128)
    args.duration = args.duration or (1.2 if args.quick else 6.0)
    args.qps = args.qps or (250.0 if args.quick else 1500.0)

    rng = np.random.default_rng(args.seed)
    with tempfile.TemporaryDirectory() as tmp:
        print(f"tiered_bench: {args.n_docs} docs, method={args.method} "
              f"(dim {args.dim}), Zipf(a={args.zipf}) over {args.pool} "
              f"queries, {args.duration:.1f}s @ {args.qps:.0f} rows/s "
              "per point")
        path, enc, pool, rec = build_artifact(args, tmp)
        budgets = [int(f * enc) for f in FRACTIONS]
        print(f"  encoded lists: {human_bytes(enc)}  "
              f"(over-budget factor at 5%: {enc / budgets[-1]:.0f}x)")
        print(f"  recall@10 vs own exact ranking: {rec:.3f} "
              "(every fraction — tiering is bit-identical)\n")
        assert enc >= 4 * budgets[2], "sweep must cover the >=4x regime"
        if args.quick:
            assert_bit_identity(path, budgets, pool)

        print(f"  {'resident':>9s} {'budget':>10s} {'served':>8s} "
              f"{'p50':>8s} {'p99':>9s} {'hit rate':>9s} "
              f"{'resident bytes':>14s}  lost")
        rows = []
        for frac, budget in zip(FRACTIONS, budgets):
            resident = "all" if frac >= 1.0 else budget
            r, tier = serve_point(args, path, resident, pool, rng)
            if r["lost"] or not r["conserved"]:
                raise SystemExit(
                    f"fraction {frac}: {r['lost']} lost requests / "
                    f"conserved={r['conserved']} — tiering may never "
                    "drop traffic")
            hit = tier["hit_rate"] if tier else 1.0
            res_bytes = tier["bytes_resident"] if tier else enc
            print(f"  {frac:8.0%} {human_bytes(budget):>10s} "
                  f"{r['served_rows_per_s']:7.0f}/s "
                  f"{r['p50_ms']:7.1f}ms {r['p99_ms']:8.1f}ms "
                  f"{hit:8.1%} {human_bytes(res_bytes):>14s}  "
                  f"{r['lost']}")
            rows.append({
                "fraction": frac, "budget_bytes": budget,
                "resident": "all" if frac >= 1.0 else "mmap",
                "served_rows_per_s": r["served_rows_per_s"],
                "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
                "lost": r["lost"], "arrivals": r["arrivals"],
                "recall_at_10": rec,
                "tier": tier,
            })

        base = rows[0]["served_rows_per_s"]
        cold = rows[-1]["served_rows_per_s"]
        print(f"\n  cold-tier qps ratio (5% / fully resident): "
              f"{cold / max(base, 1e-9):.2f}")
        out_path = args.output or f"BENCH_{git_sha()}_tiered.json"
        with open(out_path, "w") as f:
            json.dump({"sha": git_sha(),
                       "config": {"n_docs": args.n_docs,
                                  "method": args.method, "dim": args.dim,
                                  "zipf": args.zipf, "qps": args.qps,
                                  "duration_s": args.duration,
                                  "encoded_nbytes": enc},
                       "rows": rows}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"  wrote {out_path}")


if __name__ == "__main__":
    sys.exit(main())
