"""Paper Appendix B: training + encoding speed of PCA vs autoencoder.

The paper compares PyTorch/Scikit CPU/GPU; we compare our JAX
implementations (jit-compiled) on the host platform, split into train and
encode phases, across target dimensionality.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import base_parser, default_kb, print_csv
from repro.core import (Autoencoder, AutoencoderConfig, PCA)


def _time(fn, *args, reps=3):
    fn(*args)                      # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            else out
    return (time.perf_counter() - t0) / reps


def main(argv=None) -> list[dict]:
    ap = base_parser("Paper Appendix B: PCA vs AE speed")
    ap.add_argument("--ae-epochs", type=int, default=2)
    args = ap.parse_args(argv)
    kb = default_kb(args.dataset, min(args.n_docs, 10_000), args.n_queries)
    dims = (32, 128) if args.fast else (32, 64, 128, 256)

    rows = []
    for dim in dims:
        t0 = time.perf_counter()
        pca = PCA(dim).fit(kb.docs)
        pca_train = time.perf_counter() - t0
        pca_encode = _time(lambda: jax.block_until_ready(pca(kb.docs)))

        t0 = time.perf_counter()
        ae = Autoencoder(AutoencoderConfig(variant="shallow_decoder",
                                           bottleneck=dim,
                                           epochs=args.ae_epochs))
        ae.fit(kb.docs)
        ae_train = time.perf_counter() - t0
        ae_encode = _time(lambda: jax.block_until_ready(ae(kb.docs)))

        for model, tr, enc in (("pca", pca_train, pca_encode),
                               ("autoencoder", ae_train, ae_encode)):
            rows.append({"model": model, "dim": dim, "train_s": tr,
                         "encode_s": enc})
            print(f"  {model:12s} d'={dim:4d} train={tr:7.2f}s "
                  f"encode={enc * 1e3:8.2f}ms", flush=True)
    print()
    print_csv(rows, ["model", "dim", "train_s", "encode_s"])
    return rows


if __name__ == "__main__":
    main()
