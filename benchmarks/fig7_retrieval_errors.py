"""Paper Figure 7 / Table 4: distribution of retrieved-relevant counts
before vs after compression + Pearson correlations between modes."""

from __future__ import annotations

import numpy as np

from benchmarks.common import base_parser, default_kb, print_csv
from repro.core import (CenterNorm, CompressionPipeline, OneBitQuantizer,
                        PCA)
from repro.retrieval.rprecision import retrieved_relevant_counts


def main(argv=None) -> dict:
    ap = base_parser("Paper Fig. 7: retrieval-error structure")
    ap.add_argument("--dim", type=int, default=128)
    args = ap.parse_args(argv)
    kb = default_kb(args.dataset, args.n_docs, args.n_queries)

    modes = {}
    pipe = CompressionPipeline([CenterNorm()])
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    modes["uncompressed"] = np.asarray(
        retrieved_relevant_counts(q, d, kb.relevant))
    pipe = CompressionPipeline([CenterNorm(), PCA(args.dim), CenterNorm()])
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    modes["pca"] = np.asarray(retrieved_relevant_counts(q, d, kb.relevant))
    pipe = CompressionPipeline([CenterNorm(), OneBitQuantizer(0.5),
                                CenterNorm()])
    d, q = pipe.fit_transform(kb.docs, kb.queries)
    modes["onebit"] = np.asarray(retrieved_relevant_counts(q, d, kb.relevant))

    names = list(modes)
    print("confusion (uncompressed rows × pca cols), counts of #relevant "
          "retrieved per query:")
    conf = np.zeros((3, 3), int)
    for a, b in zip(modes["uncompressed"], modes["pca"]):
        conf[int(a), int(b)] += 1
    print(conf)
    off_diag = (conf.sum() - np.trace(conf)) / conf.sum()
    print(f"off-diagonal mass: {off_diag:.3f} "
          "(paper: small → errors not method-specific)")

    print("\nPearson correlations (paper Table 4):")
    rows = []
    for i, a in enumerate(names):
        for b in names[i:]:
            r = float(np.corrcoef(modes[a], modes[b])[0, 1])
            rows.append({"a": a, "b": b, "pearson": r})
            print(f"  {a:13s} × {b:13s}: {r:.2f}")
    print()
    print_csv(rows, ["a", "b", "pearson"])
    return {"confusion": conf, "correlations": rows}


if __name__ == "__main__":
    main()
