"""Shared benchmark harness: KB setup, method evaluation, CSV output."""

from __future__ import annotations

import argparse
import os
import subprocess
import time

from repro.core import build_method
from repro.data import make_dpr_like_kb
from repro.data.synthetic import KBData
from repro.retrieval import r_precision
from repro.retrieval.rprecision import make_dim_drop_scorer


def default_kb(dataset: str = "hotpot-like", n_docs: int = 20_000,
               n_queries: int = 400) -> KBData:
    """HotpotQA-like (harder, 2-hop) or NQ-like (easier: less query noise,
    smaller pool — reproduces the paper's higher NQ numbers)."""
    if dataset == "nq-like":
        return make_dpr_like_kb(n_queries=n_queries,
                                n_docs=int(n_docs * 0.75),
                                query_noise=0.35, beta_sigma=0.55, seed=13)
    return make_dpr_like_kb(n_queries=n_queries, n_docs=n_docs)


def evaluate_method(kb: KBData, method: str, dim: int = 128, *,
                    pre: bool = True, post: bool = True,
                    sims=("ip",), ae_epochs: int = 5,
                    seed: int = 0) -> dict[str, float]:
    """Fit + transform + R-Precision for each similarity. Returns metrics."""
    import jax

    greedy_scorer = None
    if method == "greedy_dim_drop":
        greedy_scorer = make_dim_drop_scorer(kb.relevant, n_queries=256,
                                             n_docs=8192)
    t0 = time.time()
    pipe = build_method(method, dim, pre=pre, post=post,
                        greedy_scorer=greedy_scorer, ae_epochs=ae_epochs)
    docs, queries = pipe.fit_transform(kb.docs, kb.queries,
                                       rng=jax.random.PRNGKey(seed))
    fit_s = time.time() - t0
    out = {"fit_s": fit_s,
           "ratio": pipe.compression_ratio(kb.dim)}
    for sim in sims:
        out[f"rprec_{sim}"] = r_precision(queries, docs, kb.relevant,
                                          sim=sim)
    return out


def git_sha() -> str:
    """Short HEAD sha for per-commit artifact names ("nogit" off-repo)."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             cwd=os.path.dirname(os.path.abspath(__file__)),
                             timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "nogit"


def print_csv(rows: list[dict], columns: list[str]) -> None:
    print(",".join(columns))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in columns))


def _fmt(v) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)


def base_parser(desc: str, datasets: tuple[str, ...] = ("hotpot-like",
                                                        "nq-like")
                ) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=desc)
    ap.add_argument("--dataset", default=datasets[0], choices=datasets)
    ap.add_argument("--n-docs", type=int, default=20_000)
    ap.add_argument("--n-queries", type=int, default=400)
    ap.add_argument("--fast", "--quick", action="store_true", dest="fast",
                    help="smaller grids for CI")
    return ap
