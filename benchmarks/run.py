"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run             # fast CI subset
    PYTHONPATH=src python -m benchmarks.run --full      # the full grids

Per-table modules are independently runnable with finer flags, e.g.
``python -m benchmarks.table2_compression --dataset nq-like``.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table2,fig5")
    args = ap.parse_args()
    fast = [] if args.full else ["--fast"]

    from benchmarks import (fig3_random_projections, fig4_pca_autoencoder,
                            fig5_pca_precision, fig6_datasize,
                            fig7_retrieval_errors, kernel_bench,
                            speed_appendix_b, table2_compression,
                            table5_preprocessing)

    suites = {
        "table2": lambda: table2_compression.main(fast),
        "table2_nq": lambda: table2_compression.main(
            [*fast, "--dataset", "nq-like"]),
        "table5": lambda: table5_preprocessing.main([]),
        "fig3": lambda: fig3_random_projections.main(
            [*fast, "--runs", "1" if not args.full else "3"]),
        "fig4": lambda: fig4_pca_autoencoder.main(fast),
        "fig5": lambda: fig5_pca_precision.main(fast),
        "fig6": lambda: fig6_datasize.main(fast),
        "fig7": lambda: fig7_retrieval_errors.main([]),
        "speed": lambda: speed_appendix_b.main(fast),
        "kernels": lambda: kernel_bench.main(fast),
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    t_all = time.time()
    for name in chosen:
        print(f"\n=== {name} " + "=" * (70 - len(name)), flush=True)
        t0 = time.time()
        suites[name]()
        print(f"=== {name} done in {time.time() - t0:.0f}s", flush=True)
    print(f"\nall benchmarks done in {time.time() - t_all:.0f}s")


if __name__ == "__main__":
    main()
