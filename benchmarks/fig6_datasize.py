"""Paper Figure 6: fit-set size dependence (solid lines) and irrelevant-
document scaling (dashed lines) for PCA and the linear autoencoder."""

from __future__ import annotations

import jax

from benchmarks.common import base_parser, default_kb, print_csv
from repro.core import (Autoencoder, AutoencoderConfig, CenterNorm,
                        CompressionPipeline, PCA)
from repro.data.synthetic import add_distractors
from repro.retrieval import r_precision

FIT_SIZES = (128, 256, 1024, 4096, 16384)
DISTRACTORS = (0, 10_000, 40_000)


def _eval(kb, core) -> float:
    pipe = CompressionPipeline([CenterNorm(), core, CenterNorm()])
    d, q = pipe.fit_transform(kb.docs, kb.queries,
                              rng=jax.random.PRNGKey(0))
    return r_precision(q, d, kb.relevant, "ip")


def main(argv=None) -> list[dict]:
    ap = base_parser("Paper Fig. 6: data-size dependence")
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--ae-epochs", type=int, default=5)
    args = ap.parse_args(argv)
    kb = default_kb(args.dataset, args.n_docs, args.n_queries)
    sizes = FIT_SIZES[:3] if args.fast else FIT_SIZES

    rows = []
    for n_fit in sizes:
        if n_fit < args.dim:
            continue
        r_pca = _eval(kb, PCA(args.dim, max_fit_samples=n_fit))
        rows.append({"model": "pca", "axis": "fit_size", "x": n_fit,
                     "rprec_ip": r_pca})
        print(f"  pca fit_size={n_fit:6d} rprec={r_pca:.3f}", flush=True)
        if not args.fast:
            ae = Autoencoder(AutoencoderConfig(
                variant="linear", bottleneck=args.dim,
                epochs=args.ae_epochs))
            pipe = CompressionPipeline([CenterNorm()])
            pipe.fit(kb.docs, kb.queries)
            docs_n = pipe.transform(kb.docs, "docs")
            queries_n = pipe.transform(kb.queries, "queries")
            ae.fit(docs_n[:n_fit])
            post = CenterNorm().fit(ae(docs_n), ae(queries_n, "queries"))
            d = post(ae(docs_n), "docs")
            q = post(ae(queries_n, "queries"), "queries")
            r_ae = r_precision(q, d, kb.relevant, "ip")
            rows.append({"model": "ae_linear", "axis": "fit_size",
                         "x": n_fit, "rprec_ip": r_ae})
            print(f"  ae  fit_size={n_fit:6d} rprec={r_ae:.3f}", flush=True)

    # irrelevant-document scaling (fit set fixed at the original corpus)
    for extra in (DISTRACTORS[:2] if args.fast else DISTRACTORS):
        big = add_distractors(kb, extra) if extra else kb
        pipe = CompressionPipeline([CenterNorm(),
                                    PCA(args.dim,
                                        max_fit_samples=len(kb.docs)),
                                    CenterNorm()])
        pipe.fit(kb.docs, kb.queries)       # fit on ORIGINAL docs only
        d = pipe.transform(big.docs, "docs")
        q = pipe.transform(big.queries, "queries")
        r = r_precision(q, d, big.relevant, "ip")
        base = r_precision(
            CenterNorm().fit(big.docs, big.queries)(big.queries, "queries"),
            CenterNorm().fit(big.docs, big.queries)(big.docs, "docs"),
            big.relevant, "ip")
        rows.append({"model": "pca", "axis": "distractors", "x": extra,
                     "rprec_ip": r, "uncompressed": base})
        print(f"  pca distractors={extra:6d} rprec={r:.3f} "
              f"(uncompressed {base:.3f})", flush=True)
    print()
    print_csv(rows, ["model", "axis", "x", "rprec_ip", "uncompressed"])
    return rows


if __name__ == "__main__":
    main()
