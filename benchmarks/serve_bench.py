"""Serving throughput/latency: manual drain loop vs. the threaded
RetrievalService, and the cost of a mid-traffic hot-swap.

    PYTHONPATH=src:. python benchmarks/serve_bench.py
    PYTHONPATH=src:. python benchmarks/serve_bench.py --quick

Three topologies over the same compressed artifact:

* ``manual``   — the PR-3 shape: one caller is both producer and
  dispatcher, alternating ``submit`` / ``drain`` on a bare
  :class:`ServeEngine`.
* ``service``  — N producer threads submit async query blocks against the
  :class:`RetrievalService` front door; one background thread drains.
* ``hot-swap`` — ``service`` with a ``stage`` + ``promote`` to a second
  artifact landing mid-stream; verifies no request is lost and reports
  the same metrics, so the swap's latency cost is visible side by side.

qps counts query rows per wall second; p50/p99 are per-request
queue-entry → results-materialised latencies (:class:`ServeResult`).
"""

import argparse
import os
import sys
import tempfile
import threading
import time

import numpy as np

from repro.data import make_dpr_like_kb
from repro.retrieval import IndexSpec, build_index
from repro.serve import MicroBatcher, QueryOptions, RetrievalService, \
    load_engine


def run_manual(path, queries, n_requests, batch, max_batch, k):
    engine = load_engine(
        path, k=k, batcher=MicroBatcher(max_batch=max_batch))
    lat = []
    t0 = time.perf_counter()
    for r in range(n_requests):
        off = (r * batch) % (len(queries) - batch)
        engine.submit(queries[off: off + batch])
        for res in engine.drain().values():
            lat.append(res.latency_s)
    wall = time.perf_counter() - t0
    return wall, n_requests * batch, lat


def run_service(path, queries, n_requests, batch, max_batch, k,
                n_threads, swap_to=None, cache_rows=0, hot_fraction=0.0):
    """``cache_rows`` enables the result cache; ``hot_fraction`` of each
    thread's requests then re-submit one hot block (a Zipf-head stand-in)
    instead of walking the query stream, so the cache has something to
    hit."""
    service = RetrievalService(default_k=k, max_batch=max_batch,
                               cache_rows=cache_rows)
    service.register("kb", artifact=path)
    per_thread = n_requests // n_threads
    lat = [[] for _ in range(n_threads)]
    errors = []

    def producer(t):
        try:
            for r in range(per_thread):
                if hot_fraction and (r % max(1, int(1 / hot_fraction))) == 0:
                    off = 0                        # the hot head block
                else:
                    off = ((t * per_thread + r) * batch) \
                        % (len(queries) - batch)
                h = service.query(queries[off: off + batch],
                                  QueryOptions(index="kb"))
                lat[t].append(h.result(timeout=300).latency_s)
        except Exception as e:                     # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    swapped = None
    if swap_to is not None:
        service.stage("kb", artifact=swap_to)
        swapped = service.promote("kb")
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    stats = service.stats()
    service.close()
    if errors:
        raise SystemExit(f"producer failed: {errors[0]}")
    # cache hits resolve without touching the engine, so the no-lost
    # check is hits + engine-served == wanted (and nothing queued)
    done = stats["requests_served"] + stats["cache_hits"]
    want = per_thread * n_threads
    if done != want or stats["pending_queries"]:
        raise SystemExit(f"lost requests: served {done}/{want}, "
                         f"{stats['pending_queries']} still pending")
    if stats["requests_submitted"] != stats["requests_served"]:
        raise SystemExit("conservation violated: "
                         f"{stats['requests_submitted']} submitted vs "
                         f"{stats['requests_served']} served")
    flat = [x for per in lat for x in per]
    if swapped is not None:
        assert stats["indexes"]["kb"]["live"] == swapped
    return wall, want * batch, flat


def report(tag, wall, n_queries, lat):
    ms = np.asarray(lat) * 1000.0
    print(f"  {tag:26s} {n_queries / wall:9.0f} q/s "
          f"p50={np.percentile(ms, 50):7.1f}ms "
          f"p99={np.percentile(ms, 99):7.1f}ms  "
          f"({len(lat)} requests, {wall:.2f}s wall)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny corpus / few requests (CI smoke)")
    ap.add_argument("--n-docs", type=int, default=0)
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--threads", type=int, default=4)
    args = ap.parse_args(argv)
    n_docs = args.n_docs or (4000 if args.quick else 50_000)
    n_requests = args.requests or (24 if args.quick else 200)
    n_requests -= n_requests % args.threads

    kb = make_dpr_like_kb(n_queries=max(512, 2 * args.batch), n_docs=n_docs)
    fresh = make_dpr_like_kb(n_queries=8, n_docs=max(64, n_docs // 20),
                             seed=1)
    queries = np.asarray(kb.queries)
    spec = IndexSpec(method="pca_int8", dim=128, backend="jnp", post=False)

    print(f"serve bench: {n_docs} docs x 768 dims, pca_int8 storage, "
          f"{n_requests} requests x {args.batch} queries, "
          f"{args.threads} producers\n")
    with tempfile.TemporaryDirectory() as tmp:
        p1 = os.path.join(tmp, "v1.npz")
        p2 = os.path.join(tmp, "v2.npz")
        build_index(spec, kb.docs, kb.queries[:256]).save(p1)
        import jax.numpy as jnp
        build_index(spec, jnp.concatenate([kb.docs, fresh.docs]),
                    kb.queries[:256]).save(p2)

        report("manual submit/drain", *run_manual(
            p1, queries, n_requests, args.batch, args.max_batch, args.k))
        report(f"service ({args.threads} producers)", *run_service(
            p1, queries, n_requests, args.batch, args.max_batch, args.k,
            args.threads))
        report("service + mid-swap", *run_service(
            p1, queries, n_requests, args.batch, args.max_batch, args.k,
            args.threads, swap_to=p2))
        report("service + result cache", *run_service(
            p1, queries, n_requests, args.batch, args.max_batch, args.k,
            args.threads, cache_rows=4096, hot_fraction=0.5))
    print("\n(hot-swap run stages + promotes a refreshed artifact "
          "mid-stream; cache run re-submits a hot head block on half "
          "its requests; no requests lost — verified)")


if __name__ == "__main__":
    sys.exit(main())
