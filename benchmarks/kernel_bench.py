"""Kernel micro-benchmarks: compressed-index scoring throughput.

Wall-times on this host are CPU numbers (the Pallas TPU path is validated
for correctness in interpret mode; its performance story is the §Roofline
analysis).  What IS meaningful here: the *bytes-scanned* reduction of each
storage format, which is hardware-independent and determines the
memory-bound roofline on TPU.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import base_parser, print_csv
from repro.core.pipeline import CompressionPipeline
from repro.core.preprocess import CenterNorm
from repro.core.quantization import Int8Quantizer, pack_bits
from repro.kernels.binary_ip import ops as bops
from repro.kernels.int8_ip import ops as iops
from repro.kernels.ivf_fused import ops as fivf
from repro.retrieval.index import CompressedIndex
from repro.retrieval.scorers import backend_tail_stages
from repro.retrieval.topk import similarity


def _bench(fn, reps=5):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def main(argv=None) -> list[dict]:
    ap = base_parser("kernel micro-benchmarks")
    args = ap.parse_args(argv)
    n_docs = 20_000 if args.fast else 100_000
    n_q, d = 64, 768
    rng = np.random.default_rng(0)
    queries = jnp.asarray(rng.standard_normal((n_q, d)), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((n_docs, d)), jnp.float32)

    rows = []

    t = _bench(lambda: queries @ docs.T)
    rows.append({"kernel": "fp32_gemm", "bytes_per_doc": d * 4,
                 "us_per_call": t * 1e6,
                 "gdocs_per_s": n_q * n_docs / t / 1e9})

    quant = Int8Quantizer().fit(docs)
    codes = quant.encode(docs)
    t = _bench(lambda: iops.int8_scores(
        queries, codes, quant.state["scale"], quant.state["zero"]))
    rows.append({"kernel": "int8_scores(jnp)", "bytes_per_doc": d,
                 "us_per_call": t * 1e6,
                 "gdocs_per_s": n_q * n_docs / t / 1e9})

    packed = pack_bits(docs)
    t = _bench(lambda: bops.binary_ip_scores(queries, packed, d))
    rows.append({"kernel": "binary_ip(jnp)", "bytes_per_doc": d // 8,
                 "us_per_call": t * 1e6,
                 "gdocs_per_s": n_q * n_docs / t / 1e9})

    # end-to-end fused search per scorer backend (encode → kernel → top-k,
    # one jit graph; see repro.retrieval.scorers)
    for _name, tail in backend_tail_stages().items():
        idx = CompressedIndex.build(
            docs, queries, CompressionPipeline([CenterNorm(), *tail]))
        t = _bench(lambda: idx.search(queries, 10))
        rows.append({"kernel": f"search[{idx.scorer.name}]",
                     "bytes_per_doc": idx.nbytes // n_docs,
                     "us_per_call": t * 1e6,
                     "gdocs_per_s": n_q * n_docs / t / 1e9})
        # approximate path: same storage, coarse-routed to a few % of it.
        # Serving-shaped (small query batch): the per-query list gather is
        # tiny next to a full-index scan, which is where IVF pays off.
        nlist = 128 if args.fast else 256
        nprobe = max(1, nlist // 16)
        n_q_serve = 4
        ivf = idx.to_ivf(nlist=nlist, nprobe=nprobe, kmeans_iters=5)
        q_serve = queries[:n_q_serve]
        t = _bench(lambda: ivf.search(q_serve, 10))
        # effective throughput: docs *ranked over* (the whole corpus) per
        # second — comparable with the exact rows above
        rows.append({"kernel": f"ivf[{idx.scorer.name},{nprobe}/{nlist}]",
                     "bytes_per_doc": ivf.nbytes // n_docs,
                     "us_per_call": t * 1e6,
                     "gdocs_per_s": n_q_serve * n_docs / t / 1e9})
        # fused IVF hot-path op (gather+score+top-k in one kernel) over the
        # same probed lists.  On TPU this is the Pallas kernel; on CPU the
        # jnp reference mirror is timed instead (interpret mode executes
        # the kernel body in Python — correct, but not a perf number).
        on_tpu = jax.default_backend() == "tpu"
        lst_s, lst_i = ivf._list_major_layout()
        qf = jnp.asarray(ivf.encode_queries(q_serve), jnp.float32)
        probe = jax.lax.top_k(
            similarity(qf, ivf.centroids, ivf.sim), nprobe)[1]
        params = ivf.scorer.params()
        t = _bench(lambda: fivf.fused_ivf_topk(
            probe, qf, lst_s, lst_i, 10, ivf.scorer.name, params=params,
            use_pallas=on_tpu))
        impl = "pallas" if on_tpu else "ref"
        rows.append({"kernel": f"fused_ivf[{idx.scorer.name},{impl}]",
                     "bytes_per_doc": ivf.nbytes // n_docs,
                     "us_per_call": t * 1e6,
                     "gdocs_per_s": n_q_serve * n_docs / t / 1e9})

    for r in rows:
        print(f"  {r['kernel']:26s} {r['bytes_per_doc']:5d} B/doc "
              f"{r['us_per_call']:12.0f} us "
              f"{r['gdocs_per_s']:.3f} Gdoc-score/s", flush=True)
    print()
    print_csv(rows, ["kernel", "bytes_per_doc", "us_per_call",
                     "gdocs_per_s"])
    return rows


if __name__ == "__main__":
    main()
