"""Paper Table 2 (and Table 7 with --dataset nq-like): the full method grid.

Columns mirror the paper: method, compression ratio, R-Precision with raw
IP / raw L2 (no pre/post-processing), and with center+norm pre+post.
"""

from __future__ import annotations

from benchmarks.common import (base_parser, default_kb, evaluate_method,
                               print_csv)

ROWS = [
    # (label, method, dim)
    ("Original", "original", 768),
    ("Gaussian Projection (128)", "gaussian_projection", 128),
    ("Sparse Projection (128)", "sparse_projection", 128),
    ("Dimension Dropping (128)", "dim_drop", 128),
    ("Greedy Dimension Dropping (128)", "greedy_dim_drop", 128),
    ("PCA (128)", "pca", 128),
    ("PCA (128, scaled top 5)", "pca_scaled", 128),
    ("Autoencoder (128, single layer)", "ae_linear", 128),
    ("Autoencoder (128, full)", "ae_full", 128),
    ("Autoencoder (128, shallow decoder)", "ae_shallow", 128),
    ("Autoencoder (128, single layer) + L1", "ae_linear_l1", 128),
    ("Autoencoder (128, full) + L1", "ae_full_l1", 128),
    ("Autoencoder (128, shallow decoder) + L1", "ae_shallow_l1", 128),
    ("Precision 16-bit", "fp16", 768),
    ("Precision 8-bit", "int8", 768),
    ("Precision 1-bit (offset 0.5)", "onebit", 768),
    ("Precision 1-bit (offset 0)", "onebit_offset0", 768),
    ("PCA (245) + Precision 1-bit", "pca_onebit", 245),
    ("PCA (128) + Precision 8-bit", "pca_int8", 128),
]

EXTRAS = [
    ("Distance learning (128)", "distance_learning", 128),
    ("Contrastive (128)", "contrastive", 128),
]


def main(argv=None) -> list[dict]:
    ap = base_parser("Paper Table 2: compression method grid")
    ap.add_argument("--extras", action="store_true",
                    help="include the §5.4 distance-learning baselines")
    ap.add_argument("--ae-epochs", type=int, default=5)
    args = ap.parse_args(argv)

    kb = default_kb(args.dataset, args.n_docs, args.n_queries)
    rows = []
    grid = list(ROWS) + (list(EXTRAS) if args.extras else [])
    if args.fast:
        grid = [g for g in grid if not g[1].startswith(("ae_", "greedy"))]
    baseline = None
    for label, method, dim in grid:
        raw = evaluate_method(kb, method, dim, pre=False, post=False,
                              sims=("ip", "l2"), ae_epochs=args.ae_epochs)
        cn = evaluate_method(kb, method, dim, pre=True, post=True,
                             sims=("ip",), ae_epochs=args.ae_epochs)
        row = {"method": label, "compression": round(raw["ratio"], 1),
               "raw_ip": raw["rprec_ip"], "raw_l2": raw["rprec_l2"],
               "center_norm": cn["rprec_ip"]}
        if method == "original":
            baseline = cn["rprec_ip"]
        row["pct_of_original"] = (100.0 * row["center_norm"] / baseline
                                  if baseline else None)
        rows.append(row)
        print(f"  {label:44s} {row['compression']:6.1f}x "
              f"raw_ip={row['raw_ip']:.3f} raw_l2={row['raw_l2']:.3f} "
              f"c+n={row['center_norm']:.3f} "
              f"({row['pct_of_original'] or 0:.0f}%)", flush=True)
    print()
    print_csv(rows, ["method", "compression", "raw_ip", "raw_l2",
                     "center_norm", "pct_of_original"])
    return rows


if __name__ == "__main__":
    main()
