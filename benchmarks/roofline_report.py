"""Render the dry-run results (results/dryrun/results.jsonl) as the
EXPERIMENTS.md §Roofline table."""

from __future__ import annotations

import argparse
import json
import os
from collections import OrderedDict

from repro.utils import human_bytes

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun",
                       "results.jsonl")


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    # last row per (arch, shape, multi_pod) wins
    dedup: "OrderedDict[tuple, dict]" = OrderedDict()
    for r in rows:
        dedup[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return list(dedup.values())


def fmt_row(r: dict) -> str:
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | "
                f"{'2x16x16' if r.get('multi_pod') else '16x16'} "
                f"| FAILED: {r.get('status')} |||||||")
    mem = human_bytes(r.get("peak_memory_bytes") or 0)
    return ("| {arch} | {shape} | {mesh} | {tc:.2e} | {tm:.2e} | {tl:.2e} "
            "| {bn} | {mf} | {eff} | {rf} | {mem} {fits} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        tc=r["t_compute_s"], tm=r["t_memory_s"], tl=r["t_collective_s"],
        bn=r["bottleneck"],
        mf=(f"{r['model_gflops']:.0f}" if r.get("model_gflops") else "—"),
        eff=(f"{r['flops_efficiency']:.2f}"
             if r.get("flops_efficiency") else "—"),
        rf=(f"{r['roofline_fraction']:.3f}"
            if r.get("roofline_fraction") is not None else "—"),
        mem=mem, fits="✓" if r.get("fits_hbm") else "✗")


HEADER = ("| arch | shape | mesh | t_compute (s) | t_memory (s) | "
          "t_collective (s) | bottleneck | MODEL_GFLOPs | MODEL/HLO | "
          "roofline frac | mem/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DEFAULT)
    ap.add_argument("--multi-pod", action="store_true",
                    help="show multi-pod rows instead of single-pod")
    args = ap.parse_args(argv)
    rows = load(args.path)
    print(HEADER)
    for r in rows:
        if bool(r.get("multi_pod", False)) == args.multi_pod:
            print(fmt_row(r))


if __name__ == "__main__":
    main()
