"""Paper Figure 4: PCA / autoencoder × fit-set (docs/queries/both) ×
pre-processing (4 combinations of centering and normalizing)."""

from __future__ import annotations

import jax

from benchmarks.common import base_parser, default_kb, print_csv
from repro.core import (Autoencoder, AutoencoderConfig, Center,
                        CompressionPipeline, Normalize, PCA)
from repro.retrieval import r_precision

PREPROC = {
    "raw": [],
    "center": [Center()],
    "norm": [Normalize()],
    "center_norm": [Center(), Normalize()],
}


def main(argv=None) -> list[dict]:
    ap = base_parser("Paper Fig. 4: PCA/AE fit-set × preprocessing")
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--ae-epochs", type=int, default=5)
    args = ap.parse_args(argv)
    kb = default_kb(args.dataset, args.n_docs, args.n_queries)

    rows = []
    models = ["pca"] if args.fast else ["pca", "ae_linear"]
    for model in models:
        for prep_name, prep in PREPROC.items():
            for fit_on in ("docs", "queries", "both"):
                stages = [type(t)() for t in prep]
                if model == "pca":
                    core = PCA(args.dim, fit_on=fit_on)
                else:
                    core = Autoencoder(AutoencoderConfig(
                        variant="linear", bottleneck=args.dim,
                        fit_on=fit_on, epochs=args.ae_epochs))
                pipe = CompressionPipeline([*stages, core])
                d, q = pipe.fit_transform(kb.docs, kb.queries,
                                          rng=jax.random.PRNGKey(0))
                row = {"model": model, "preproc": prep_name,
                       "fit_on": fit_on,
                       "rprec_ip": r_precision(q, d, kb.relevant, "ip")}
                rows.append(row)
                print(f"  {model:10s} prep={prep_name:12s} "
                      f"fit={fit_on:8s} rprec={row['rprec_ip']:.3f}",
                      flush=True)
    print()
    print_csv(rows, ["model", "preproc", "fit_on", "rprec_ip"])
    return rows


if __name__ == "__main__":
    main()
