"""JIT-retrace hazard pass.

Rules:

* ``retrace-in-loop`` — ``jax.jit(...)`` / ``pl.pallas_call(...)`` constructed
  inside a ``for``/``while`` body: every iteration builds a fresh callable and
  forfeits the compile cache.
* ``retrace-in-serve`` — ``jax.jit``/``pallas_call`` construction anywhere in
  ``src/repro/serve/``: per-request paths must call pre-built functions, never
  build them.
* ``retrace-self-capture`` — a function handed to ``jax.jit``/``lax.scan``/
  ``lax.map`` (or decorated with ``@jax.jit``/``@partial(jax.jit, ...)``) reads
  ``self.<attr>`` data.  Jitted closures must snapshot object state into locals
  first (the ``ivf.py`` idiom) — otherwise mutating the object silently serves
  stale constants or retraces.
* ``retrace-host-sync`` — ``float()``/``int()``/``.item()``/``np.asarray()``
  applied to a traced value inside a jit/scan body forces a host sync and
  breaks tracing.

Method calls (``self.method(...)``) and ``@property``-free module access are
not flagged; only data reads of ``self`` attributes are.
"""

from __future__ import annotations

import ast

from .findings import Finding

JIT_BUILDERS = {"jit", "pallas_call"}
SCAN_CONSUMERS = {"scan", "map", "fori_loop", "while_loop"}
HOST_SYNC_CALLS = {"float", "int"}
HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
HOST_SYNC_NP = {"asarray", "array"}


def _call_name(fn: ast.expr) -> str:
    """Dotted tail of a call target: ``jax.jit`` -> ``jit``."""
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _np_aliases(tree: ast.Module) -> set[str]:
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    out.add(a.asname or "numpy")
    return out


def _is_jit_builder(call: ast.Call) -> str | None:
    name = _call_name(call.func)
    if name in JIT_BUILDERS:
        return name
    # functools.partial(jax.jit, ...)
    if name == "partial" and call.args:
        inner = _call_name(call.args[0]) if isinstance(call.args[0], ast.Call) else (
            call.args[0].attr if isinstance(call.args[0], ast.Attribute) else
            call.args[0].id if isinstance(call.args[0], ast.Name) else "")
        if inner in JIT_BUILDERS:
            return inner
    return None


def _jitted_function_names(tree: ast.Module) -> dict[str, ast.AST]:
    """Map function name -> def node for functions that are jit targets.

    A function is a jit target if it is decorated with ``jit``/``partial(jit)``
    or passed (by name or inline) to ``jax.jit``/``lax.scan``/``lax.map``.
    """
    defs: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node

    targets: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                dec_name = _call_name(dec.func) if isinstance(dec, ast.Call) else _call_name(dec)
                if dec_name in JIT_BUILDERS:
                    targets[node.name] = node
                elif isinstance(dec, ast.Call) and _is_jit_builder(dec):
                    targets[node.name] = node
        if isinstance(node, ast.Call):
            name = _call_name(node.func)
            if name in JIT_BUILDERS or name in SCAN_CONSUMERS:
                args = node.args if name in SCAN_CONSUMERS else node.args[:1]
                for arg in args:
                    if isinstance(arg, ast.Name) and arg.id in defs:
                        targets[arg.id] = defs[arg.id]
                    elif isinstance(arg, ast.Lambda):
                        targets[f"<lambda:{arg.lineno}>"] = arg
    return targets


def _qualname_of(tree: ast.Module, target: ast.AST) -> str:
    """Best-effort qualname: enclosing class/function chain."""
    chain: list[str] = []

    def visit(node: ast.AST, stack: list[str]) -> bool:
        for child in ast.iter_child_nodes(node):
            new_stack = stack
            if isinstance(child, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef)):
                new_stack = [*stack, child.name]
                if child is target:
                    chain.extend(new_stack)
                    return True
            if child is target:
                chain.extend([*stack, getattr(child, "name", "<lambda>")])
                return True
            if visit(child, new_stack):
                return True
        return False

    visit(tree, [])
    return ".".join(chain) if chain else getattr(target, "name", "<lambda>")


def check_retrace(tree: ast.Module, relpath: str) -> list[Finding]:
    findings: list[Finding] = []
    np_aliases = _np_aliases(tree)
    in_serve = "/serve/" in relpath or relpath.startswith("serve/")

    # --- construction-site rules -------------------------------------------
    class LoopVisitor(ast.NodeVisitor):
        def __init__(self) -> None:
            self.loop_depth = 0
            self.qual: list[str] = []

        def _enter(self, node, is_loop=False):
            if is_loop:
                self.loop_depth += 1
            self.generic_visit(node)
            if is_loop:
                self.loop_depth -= 1

        def visit_For(self, node):
            self._enter(node, is_loop=True)

        def visit_While(self, node):
            self._enter(node, is_loop=True)

        def visit_ClassDef(self, node):
            self.qual.append(node.name)
            self.generic_visit(node)
            self.qual.pop()

        def visit_FunctionDef(self, node):
            self.qual.append(node.name)
            saved = self.loop_depth
            self.loop_depth = 0   # a def inside a loop runs later, not per-iter
            self.generic_visit(node)
            self.loop_depth = saved
            self.qual.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, node):
            builder = _is_jit_builder(node)
            if builder is not None:
                qual = ".".join(self.qual)
                if self.loop_depth > 0:
                    findings.append(Finding(
                        rule="retrace-in-loop", path=relpath, line=node.lineno,
                        qualname=qual, detail=builder,
                        message=(f"`{builder}` constructed inside a loop — hoist "
                                 f"it out so the compile cache is reused"),
                    ))
                if in_serve:
                    findings.append(Finding(
                        rule="retrace-in-serve", path=relpath, line=node.lineno,
                        qualname=qual, detail=builder,
                        message=(f"`{builder}` constructed in serve/ — per-request "
                                 f"paths must call pre-built functions"),
                    ))
            self.generic_visit(node)

    LoopVisitor().visit(tree)

    # --- jit-body rules -----------------------------------------------------
    for _name, fn_node in _jitted_function_names(tree).items():
        qual = _qualname_of(tree, fn_node)
        body = fn_node.body if isinstance(fn_node.body, list) else [fn_node.body]
        params = set()
        if isinstance(fn_node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            a = fn_node.args
            params = {p.arg for p in a.args + a.kwonlyargs + a.posonlyargs}
        for stmt in body:
            for node in ast.walk(stmt if isinstance(stmt, ast.AST) else stmt):
                if (isinstance(node, ast.Attribute) and isinstance(node.ctx, ast.Load)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self" and "self" not in params):
                    parent_call = getattr(node, "_rl_in_call_func", False)
                    if not parent_call:
                        findings.append(Finding(
                            rule="retrace-self-capture", path=relpath,
                            line=node.lineno, qualname=qual, detail=node.attr,
                            message=(f"jitted function reads `self.{node.attr}` — "
                                     f"snapshot it into a local before closing "
                                     f"over it (see ivf.py search-fn builders)"),
                        ))
                if isinstance(node, ast.Call):
                    # mark method-call funcs so self.method(...) is not flagged
                    if isinstance(node.func, ast.Attribute):
                        node.func._rl_in_call_func = True  # type: ignore[attr-defined]
                    cname = _call_name(node.func)
                    if (isinstance(node.func, ast.Name)
                            and cname in HOST_SYNC_CALLS and node.args
                            and not isinstance(node.args[0], ast.Constant)):
                        findings.append(Finding(
                            rule="retrace-host-sync", path=relpath,
                            line=node.lineno, qualname=qual, detail=cname,
                            message=(f"`{cname}()` inside a jit/scan body forces "
                                     f"a host sync — keep values traced"),
                        ))
                    elif (isinstance(node.func, ast.Attribute)
                          and node.func.attr in HOST_SYNC_METHODS):
                        findings.append(Finding(
                            rule="retrace-host-sync", path=relpath,
                            line=node.lineno, qualname=qual,
                            detail=node.func.attr,
                            message=(f"`.{node.func.attr}()` inside a jit/scan "
                                     f"body forces a host sync"),
                        ))
                    elif (isinstance(node.func, ast.Attribute)
                          and isinstance(node.func.value, ast.Name)
                          and node.func.value.id in np_aliases
                          and node.func.attr in HOST_SYNC_NP):
                        findings.append(Finding(
                            rule="retrace-host-sync", path=relpath,
                            line=node.lineno, qualname=qual,
                            detail=f"np.{node.func.attr}",
                            message=(f"`np.{node.func.attr}()` inside a jit/scan "
                                     f"body materializes on host — use jnp"),
                        ))
    return findings
