"""Lock-discipline pass.

For every class that owns at least one ``threading.Lock``/``RLock``/
``Condition`` attribute, infer which ``self._*`` attributes are guarded by
which lock and flag:

* ``lock-bare-read`` / ``lock-bare-write`` — access to a guarded attribute
  outside any ``with self.<lock>`` block (outside ``__init__``);
* ``lock-blocking-call`` — a blocking call (``time.sleep``, ``.wait()``,
  ``.get()``/``.put()`` without ``block=False``/``timeout=0``, ``.result()``,
  ``.join()``) made while a lock is lexically held;
* ``lock-helper-unlocked`` — calling a ``self.*_locked()`` helper without
  holding any lock;
* ``lock-order`` — two locks acquired in both nesting orders anywhere in the
  analyzed set.

Inference rule: an attribute is *guarded* when at least one mutation of it
happens under a lock; the guard set is the union of locks held at its locked
mutation sites.  Bare mutations of a guarded attribute are violations (they do
not un-guard the attribute).  Exempt from inference and checking:

* all accesses inside ``__init__`` (single-threaded construction);
* attributes assigned a synchronization primitive (locks, events, queues,
  conditions) — these objects are internally synchronized;
* attributes only ever assigned in ``__init__`` (immutable after init);
* methods named ``*_locked`` — by convention the caller holds the lock, and
  calling one without a lock held is its own finding.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from .findings import Finding

MUTATOR_METHODS = {
    "append", "extend", "insert", "remove", "clear", "pop", "popitem",
    "update", "setdefault", "add", "discard", "move_to_end", "appendleft",
    "popleft", "rotate",
}

# Constructors whose product is internally synchronized — attributes holding
# one of these are exempt from guard inference.
SYNC_CONSTRUCTORS = {
    "Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore",
    "Event", "Barrier", "Queue", "SimpleQueue", "LifoQueue", "PriorityQueue",
    "local",
}

BLOCKING_METHODS = {"wait", "result", "join", "acquire"}
QUEUE_METHODS = {"get", "put"}


def _self_attr(node: ast.expr) -> str | None:
    """Return attr name if node is ``self.<attr>``."""
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _is_sync_ctor(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    name = None
    if isinstance(fn, ast.Attribute):
        name = fn.attr
    elif isinstance(fn, ast.Name):
        name = fn.id
    return name in SYNC_CONSTRUCTORS


@dataclass
class _Access:
    attr: str
    kind: str                 # "read" | "write"
    held: frozenset[str]      # lock attrs lexically held
    method: str               # method qualname suffix
    line: int


@dataclass
class _ClassInfo:
    name: str
    locks: set[str] = field(default_factory=set)
    sync_attrs: set[str] = field(default_factory=set)
    init_assigned: set[str] = field(default_factory=set)
    accesses: list[_Access] = field(default_factory=list)
    blocking: list[tuple[str, str, frozenset, str, int]] = field(default_factory=list)
    # (call-desc, detail, held, method, line)
    helper_calls: list[tuple[str, frozenset, str, int]] = field(default_factory=list)
    order_edges: list[tuple[str, str, str, int]] = field(default_factory=list)
    # (outer, inner, method, line)


class _MethodWalker:
    """Walk one method body tracking lexically-held locks."""

    def __init__(self, cls: _ClassInfo, method: str, in_init: bool,
                 time_aliases: set[str]):
        self.cls = cls
        self.method = method
        self.in_init = in_init
        self.locked_helper = method.endswith("_locked")
        self.time_aliases = time_aliases

    def walk(self, body: list[ast.stmt], held: frozenset[str]) -> None:
        for stmt in body:
            self._stmt(stmt, held)

    def _stmt(self, stmt: ast.stmt, held: frozenset[str]) -> None:
        if isinstance(stmt, ast.With) or isinstance(stmt, ast.AsyncWith):
            new_held = set(held)
            for item in stmt.items:
                lock = self._lock_name(item.context_expr)
                if lock is not None:
                    for outer in held:
                        if outer != lock:
                            self.cls.order_edges.append(
                                (outer, lock, self.method, stmt.lineno))
                    new_held.add(lock)
                else:
                    self._expr(item.context_expr, held)
            self.walk(stmt.body, frozenset(new_held))
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Nested function: runs later, no lock lexically held.
            sub = _MethodWalker(self.cls, f"{self.method}.{stmt.name}",
                                self.in_init, self.time_aliases)
            sub.walk(stmt.body, frozenset())
            return
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            value = stmt.value
            for t in targets:
                self._target(t, held, value)
            if value is not None:
                self._expr(value, held)
            return
        if isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                self._target(t, held, None)
            return
        # Generic: visit child statements/expressions.
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt):
                self._stmt(child, held)
            elif isinstance(child, ast.expr):
                self._expr(child, held)
            elif isinstance(child, (ast.excepthandler,)):
                for s in child.body:
                    self._stmt(s, held)
            elif hasattr(child, "body"):
                pass

    def _lock_name(self, expr: ast.expr) -> str | None:
        attr = _self_attr(expr)
        if attr is not None and attr in self.cls.locks:
            return attr
        # ``with other.lock`` / ``with other._lock``: name the lock attr so the
        # order check sees cross-object nesting too.
        if isinstance(expr, ast.Attribute) and ("lock" in expr.attr or expr.attr == "_mu"):
            return expr.attr
        return None

    def _target(self, t: ast.expr, held: frozenset[str], value: ast.expr | None) -> None:
        attr = _self_attr(t)
        if attr is not None:
            if self.in_init:
                self.cls.init_assigned.add(attr)
                if value is not None and _is_sync_ctor(value):
                    self.cls.sync_attrs.add(attr)
                return
            self._record(attr, "write", held, t.lineno)
            return
        if isinstance(t, ast.Subscript):
            base = _self_attr(t.value)
            if base is not None:
                self._record(base, "write", held, t.lineno)
            else:
                self._expr(t.value, held)
            self._expr(t.slice, held)
            return
        if isinstance(t, ast.Attribute):
            self._expr(t.value, held)
            return
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                self._target(el, held, None)
            return

    def _record(self, attr: str, kind: str, held: frozenset[str], line: int) -> None:
        if self.in_init or self.locked_helper:
            return
        self.cls.accesses.append(_Access(attr, kind, held, self.method, line))

    def _expr(self, expr: ast.expr, held: frozenset[str]) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._call(node, held)
            elif isinstance(node, ast.Attribute) and isinstance(node.ctx, ast.Load):
                attr = _self_attr(node)
                if attr is not None:
                    self._record(attr, "read", held, node.lineno)
            elif isinstance(node, (ast.Lambda, ast.FunctionDef)):
                pass

    def _call(self, call: ast.Call, held: frozenset[str]) -> None:
        fn = call.func
        # self.<attr>.<mutator>(...) counts as a write to <attr>.
        if isinstance(fn, ast.Attribute):
            base_attr = _self_attr(fn.value)
            if base_attr is not None and fn.attr in MUTATOR_METHODS:
                self._record(base_attr, "write", held, call.lineno)
            # self.<helper>_locked() without a lock held
            helper = _self_attr(fn)
            if (helper is not None and helper.endswith("_locked")
                    and not held and not self.locked_helper and not self.in_init):
                self.cls.helper_calls.append((helper, held, self.method, call.lineno))
            if held:
                self._blocking(call, fn, held)

    def _blocking(self, call: ast.Call, fn: ast.Attribute, held: frozenset[str]) -> None:
        name = fn.attr
        base = fn.value
        desc = None
        if name == "sleep" and isinstance(base, ast.Name) and base.id in self.time_aliases:
            desc = f"{base.id}.sleep"
        elif name in BLOCKING_METHODS:
            base_attr = _self_attr(base)
            # Waiting on the lock/condition you hold is normal Condition usage;
            # acquiring a *different* lock is covered by the order check.
            if base_attr in self.cls.locks or base_attr in self.cls.sync_attrs and name == "acquire":
                return
            if name == "acquire":
                return  # nested acquire handled by lock-order pass
            desc = f".{name}"
        elif name in QUEUE_METHODS:
            # dict/OrderedDict .get(key[, default]) take positional args;
            # queue.Queue.get()/put(item) block via keywords only — treat
            # .get with positional args as a mapping lookup, not a block.
            if name == "get" and call.args:
                return
            for kw in call.keywords:
                if kw.arg == "block" and isinstance(kw.value, ast.Constant) and kw.value.value is False:
                    return
                if kw.arg == "timeout" and isinstance(kw.value, ast.Constant) and kw.value.value == 0:
                    return
            desc = f".{name}"
        if desc is not None:
            self.cls.blocking.append((desc, desc, held, self.method, call.lineno))


def _collect_class(cls_node: ast.ClassDef, time_aliases: set[str]) -> _ClassInfo:
    info = _ClassInfo(name=cls_node.name)
    # First sweep: find lock attributes (assigned a Lock/RLock/Condition in any
    # method, typically __init__).
    for node in ast.walk(cls_node):
        if isinstance(node, ast.Assign) and _is_sync_ctor(node.value):
            ctor = node.value.func
            ctor_name = ctor.attr if isinstance(ctor, ast.Attribute) else getattr(ctor, "id", "")
            for t in node.targets:
                attr = _self_attr(t)
                if attr is not None:
                    info.sync_attrs.add(attr)
                    if ctor_name in {"Lock", "RLock", "Condition"}:
                        info.locks.add(attr)
    for item in cls_node.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walker = _MethodWalker(info, item.name, item.name == "__init__",
                                   time_aliases)
            walker.walk(item.body, frozenset())
    return info


def _module_time_aliases(tree: ast.Module) -> set[str]:
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    aliases.add(a.asname or "time")
    return aliases


def check_locks(tree: ast.Module, relpath: str) -> list[Finding]:
    findings: list[Finding] = []
    time_aliases = _module_time_aliases(tree)
    order_edges: list[tuple[str, str, str, str, int]] = []

    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        info = _collect_class(node, time_aliases)
        if not info.locks:
            continue

        # Guard inference: union of locks held at locked mutation sites.
        guards: dict[str, set[str]] = {}
        for acc in info.accesses:
            if acc.kind == "write" and acc.held:
                guards.setdefault(acc.attr, set()).update(acc.held)
        # Drop exempt attrs.
        for attr in list(guards):
            if attr in info.sync_attrs:
                del guards[attr]

        for acc in info.accesses:
            guard = guards.get(acc.attr)
            if not guard:
                continue
            if acc.held & guard:
                continue
            rule = "lock-bare-read" if acc.kind == "read" else "lock-bare-write"
            lock_desc = "/".join(sorted(guard))
            findings.append(Finding(
                rule=rule, path=relpath, line=acc.line,
                qualname=f"{info.name}.{acc.method}",
                detail=acc.attr,
                message=(f"attribute `self.{acc.attr}` is guarded by "
                         f"`self.{lock_desc}` (mutated under it elsewhere) but "
                         f"accessed here without holding it"),
            ))

        for desc, detail, held, method, line in info.blocking:
            held_desc = "/".join(sorted(held))
            findings.append(Finding(
                rule="lock-blocking-call", path=relpath, line=line,
                qualname=f"{info.name}.{method}", detail=detail,
                message=(f"blocking call `{desc}` while holding "
                         f"`self.{held_desc}` — move it outside the lock"),
            ))

        for helper, _held, method, line in info.helper_calls:
            findings.append(Finding(
                rule="lock-helper-unlocked", path=relpath, line=line,
                qualname=f"{info.name}.{method}", detail=helper,
                message=(f"`self.{helper}()` follows the *_locked convention "
                         f"(caller must hold the lock) but no lock is held here"),
            ))

        for outer, inner, method, line in info.order_edges:
            order_edges.append((outer, inner, info.name, method, line))

    # Lock-order consistency across the whole module.
    seen: dict[tuple[str, str], tuple[str, str, int]] = {}
    for outer, inner, cls, method, line in order_edges:
        seen.setdefault((outer, inner), (cls, method, line))
    reported: set[frozenset[str]] = set()
    for (outer, inner), (cls, method, line) in seen.items():
        if (inner, outer) in seen:
            pair = frozenset((outer, inner))
            if pair in reported:
                continue
            reported.add(pair)
            other = seen[(inner, outer)]
            findings.append(Finding(
                rule="lock-order", path=relpath, line=line,
                qualname=f"{cls}.{method}",
                detail=f"{outer}<->{inner}",
                message=(f"locks `{outer}` and `{inner}` are acquired in both "
                         f"orders (also at {other[0]}.{other[1]} line {other[2]}) "
                         f"— pick one global order to avoid deadlock"),
            ))
    return findings
