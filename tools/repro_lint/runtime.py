"""Runtime sanitizers complementing the static replint passes.

``retrace_guard`` — asserts an *exact* XLA compile count around a code block
by counting ``/jax/core/compile/backend_compile_duration`` monitoring events.
The canonical use is "warm up, then assert zero": run the hot path once, then
prove steady-state requests never retrace::

    search(qs)                          # warm-up compile
    with retrace_guard(expected=0):
        for _ in range(32):
            search(qs)                  # must all hit the jit cache

``LockSanitizer`` — wraps a set of ``threading.Lock``/``RLock`` attributes
with counting proxies and (while active) patches the blocking primitives
(``time.sleep``, ``threading.Event.wait``, ``threading.Thread.join``,
``queue.Queue.get/put``) to record a violation whenever one is entered while
the calling thread holds a sanitized lock.  It also records lock acquisition
order and flags pairs taken in both orders.  Used by the service stress tests
to catch held-across-blocking at runtime — the dynamic complement of the
static ``lock-blocking-call`` rule.

Only this module touches jax, and only lazily — the static passes and the CLI
stay pure stdlib.
"""

from __future__ import annotations

import contextlib
import queue
import threading
import time
from dataclasses import dataclass, field

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_compile_count = 0
_listener_installed = False
_listener_mu = threading.Lock()


def _ensure_listener() -> None:
    """Install the (permanent) compile-event listener once.

    jax.monitoring has no per-listener unregister — ``clear_event_listeners``
    would nuke listeners we don't own — so one module-level counter is
    installed on first use and guards diff it.
    """
    global _listener_installed
    with _listener_mu:
        if _listener_installed:
            return
        import jax.monitoring as mon

        def _on_duration(event: str, duration: float, **kw) -> None:
            global _compile_count
            if event == COMPILE_EVENT:
                _compile_count += 1

        mon.register_event_duration_secs_listener(_on_duration)
        _listener_installed = True


def compile_count() -> int:
    """Monotonic count of backend compiles observed so far."""
    _ensure_listener()
    return _compile_count


class RetraceError(AssertionError):
    """The guarded block compiled a different number of programs than
    declared."""


@dataclass
class CompileTally:
    """Mutable view handed out by :func:`retrace_guard`."""
    start: int
    end: int | None = None

    @property
    def compiles(self) -> int:
        current = _compile_count if self.end is None else self.end
        return current - self.start


@contextlib.contextmanager
def retrace_guard(expected: int = 0, what: str = "guarded block"):
    """Assert the block performs exactly ``expected`` backend compiles.

    Note the count is process-global: incidental first-use compiles (e.g. a
    ``jnp.ones`` fill) are charged to the block, which is exactly the
    property the serving hot path must have — *nothing* compiles once warm.
    """
    _ensure_listener()
    tally = CompileTally(start=_compile_count)
    try:
        yield tally
    finally:
        tally.end = _compile_count
    if tally.compiles != expected:
        raise RetraceError(
            f"{what}: expected exactly {expected} compile(s), "
            f"observed {tally.compiles} — a retrace hazard (shape/dtype "
            f"churn, un-hoisted jit, or mutable capture)")


# --- lock sanitizer ---------------------------------------------------------

@dataclass
class Violation:
    kind: str            # "blocking-call" | "lock-order"
    detail: str
    thread: str
    held: tuple[str, ...]

    def __str__(self) -> str:
        return (f"{self.kind}: {self.detail} while holding "
                f"{list(self.held)} on thread {self.thread}")


class _SanitizedLock:
    """Counting proxy preserving Lock/RLock semantics."""

    def __init__(self, name: str, inner, sanitizer: "LockSanitizer"):
        self._name = name
        self._inner = inner
        self._san = sanitizer

    def acquire(self, *a, **kw):
        self._san._note_acquire(self._name)
        got = self._inner.acquire(*a, **kw)
        if got:
            self._san._push(self._name)
        return got

    def release(self):
        self._san._pop(self._name)
        return self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._inner.locked()

    def __repr__(self):
        return f"SanitizedLock({self._name}, {self._inner!r})"


class LockSanitizer:
    """Runtime lock-discipline monitor (see module docstring).

    ``wrap(obj, "attr", ...)`` replaces lock attributes with sanitized
    proxies (in place — pass every object sharing the contract).  Entering
    the context installs the blocking-call detectors; exiting restores them
    and leaves ``violations`` for the test to assert on.
    """

    def __init__(self) -> None:
        self._tls = threading.local()
        self.violations: list[Violation] = []
        self._mu = threading.Lock()
        self._order_edges: dict[tuple[str, str], str] = {}
        self._patches: list[tuple[object, str, object]] = []

    # -- wiring ------------------------------------------------------------
    def wrap(self, obj: object, *attrs: str) -> "LockSanitizer":
        for attr in attrs:
            inner = getattr(obj, attr)
            if isinstance(inner, _SanitizedLock):
                continue
            label = f"{type(obj).__name__}.{attr}"
            setattr(obj, attr, _SanitizedLock(label, inner, self))
        return self

    # -- per-thread held stack ---------------------------------------------
    def _held(self) -> list[str]:
        if not hasattr(self._tls, "stack"):
            self._tls.stack = []
        return self._tls.stack

    def held_locks(self) -> tuple[str, ...]:
        # outermost-first, reentrant acquisitions deduplicated
        out: list[str] = []
        for name in self._held():
            if name not in out:
                out.append(name)
        return tuple(out)

    def _note_acquire(self, name: str) -> None:
        held = self.held_locks()
        for outer in held:
            if outer == name:        # reentrant RLock acquire
                continue
            edge = (outer, name)
            with self._mu:
                self._order_edges.setdefault(edge, threading.current_thread().name)
                conflict = (name, outer) in self._order_edges
            if conflict:   # record outside _mu (it takes _mu itself)
                self._record("lock-order",
                             f"`{outer}` -> `{name}` conflicts with the "
                             f"observed `{name}` -> `{outer}`", held)

    def _push(self, name: str) -> None:
        self._held().append(name)

    def _pop(self, name: str) -> None:
        held = self._held()
        if held and held[-1] == name:
            held.pop()
        elif name in held:           # out-of-order release (legal, rare)
            held.remove(name)

    def _record(self, kind: str, detail: str, held: tuple[str, ...]) -> None:
        v = Violation(kind, detail, threading.current_thread().name, held)
        with self._mu:
            self.violations.append(v)

    def _check_blocking(self, desc: str) -> None:
        held = self.held_locks()
        if held:
            self._record("blocking-call", desc, held)

    # -- blocking-call detectors -------------------------------------------
    def _patch(self, owner, attr: str, wrapper_factory) -> None:
        original = getattr(owner, attr)
        setattr(owner, attr, wrapper_factory(original))
        self._patches.append((owner, attr, original))

    def __enter__(self) -> "LockSanitizer":
        san = self

        def wrap_fn(desc):
            def factory(original):
                def wrapper(*a, **kw):
                    san._check_blocking(desc)
                    return original(*a, **kw)
                return wrapper
            return factory

        def wrap_queue(desc):
            # Queue.get/put(self, item?, block=True, timeout=None):
            # block=False / timeout=0 never block — don't flag them.
            def factory(original):
                def wrapper(*a, **kw):
                    blocking = kw.get("block", True) and kw.get("timeout") != 0
                    if blocking:
                        san._check_blocking(desc)
                    return original(*a, **kw)
                return wrapper
            return factory

        self._patch(time, "sleep", wrap_fn("time.sleep"))
        self._patch(threading.Event, "wait", wrap_fn("Event.wait"))
        self._patch(threading.Thread, "join", wrap_fn("Thread.join"))
        self._patch(queue.Queue, "get", wrap_queue("Queue.get"))
        self._patch(queue.Queue, "put", wrap_queue("Queue.put"))
        return self

    def __exit__(self, *exc) -> bool:
        for owner, attr, original in reversed(self._patches):
            setattr(owner, attr, original)
        self._patches.clear()
        return False

    def assert_clean(self) -> None:
        if self.violations:
            lines = "\n  ".join(str(v) for v in self.violations)
            raise AssertionError(
                f"LockSanitizer caught {len(self.violations)} violation(s):"
                f"\n  {lines}")
