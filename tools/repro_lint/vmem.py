"""Pallas VMEM budget + tiling alignment pass.

For each ``pl.pallas_call`` in a kernel module, statically evaluate the
BlockSpec block shapes (straight-line abstract interpretation of the enclosing
function, seeded by a per-package *profile* of representative dimensions) and
estimate per-grid-step VMEM residency:

    bytes(spec) = prod(padded block dims) × dtype size × buffering
    buffering   = 2 if the index map varies with the grid (double-buffered DMA)
                  1 if the map is constant (block stays resident)

Padding models the physical VMEM tile: the last dim is padded to a multiple of
128 (lane), the second-to-last to the dtype sublane requirement (4-byte: 8,
2-byte: 16, 1-byte: 32).

Rules:

* ``vmem-budget`` — the per-step total exceeds the 16 MiB VMEM budget;
* ``vmem-misaligned`` — a block dim is neither a multiple of its lane/sublane
  requirement, nor full-span (block dim == array dim — the compiler pads the
  whole array once), nor 1;
* ``vmem-uneval`` — a block shape could not be evaluated (the profile is
  missing a symbol).  Unevaluated specs would silently undercount residency,
  so they are findings, not skips.

``--vmem-report`` renders the per-kernel table from the same machinery.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

from .findings import Finding

VMEM_LIMIT = 16 * 1024 * 1024

DTYPE_INFO = {  # name -> (bytes, sublane requirement)
    "float32": (4, 8), "int32": (4, 8), "uint32": (4, 8),
    "bfloat16": (2, 16), "float16": (2, 16),
    "int8": (1, 32), "uint8": (1, 32),
}


@dataclasses.dataclass
class KernelProfile:
    """Representative dims + operand dtypes/shapes for one kernel variant."""
    variant: str
    env: dict[str, int]
    dtypes: list[str]               # per BlockSpec, in_specs then out_specs
    arrays: list[tuple[int, ...]]   # full array shapes, same order


# One profile list per kernels/<package>.  Dims mirror the shipped defaults
# (d=768 embeddings, 100k-doc corpus, k=10 retrieval) — the shapes every
# benchmark and ci_gate run actually compiles.
DEFAULT_PROFILES: dict[str, list[KernelProfile]] = {
    "binary_ip": [KernelProfile(
        "default", {"d": 768, "n_words": 24},
        ["int8", "uint32", "int32"],
        [(256, 768), (4096, 24), (256, 4096)],
    )],
    "int8_ip": [KernelProfile(
        "default", {"d": 768},
        ["bfloat16", "uint8", "float32"],
        [(256, 768), (4096, 768), (256, 4096)],
    )],
    "fused_quantize": [KernelProfile(
        "default", {"d": 768, "d_out": 128},
        ["float32", "float32", "float32", "float32", "float32", "float32",
         "uint8"],
        [(4096, 768), (768,), (768, 128), (128,), (128,), (128,),
         (4096, 128)],
    )],
    "topk_blocks": [KernelProfile(
        "default", {"k": 10, "n_d": 102400, "n_blocks": 100},
        ["float32", "float32", "int32"],
        [(256, 102400), (256, 12800), (256, 12800)],
    )],
    "ivf_fused": [
        KernelProfile(
            "float", {"dq": 768, "w": 768, "max_len": 2048, "k": 10,
                      "nprobe": 8, "n_q": 64},
            ["float32", "float32", "int32", "float32", "float32", "int32"],
            [(64, 768), (1024, 2048, 768), (1024, 2048), (64, 8),
             (64, 128), (64, 128)],
        ),
        KernelProfile(
            "onebit", {"dq": 768, "w": 24, "max_len": 2048, "k": 10,
                       "nprobe": 8, "n_q": 64},
            ["int8", "uint32", "int32", "float32", "float32", "int32"],
            [(64, 768), (1024, 2048, 24), (1024, 2048), (64, 8),
             (64, 128), (64, 128)],
        ),
    ],
}


# --- tiny straight-line evaluator ------------------------------------------

def _eval(node: ast.expr, env: dict[str, int]) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _eval(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        a, b = _eval(node.left, env), _eval(node.right, env)
        if a is None or b is None:
            return None
        if isinstance(node.op, ast.Add):
            return a + b
        if isinstance(node.op, ast.Sub):
            return a - b
        if isinstance(node.op, ast.Mult):
            return a * b
        if isinstance(node.op, (ast.FloorDiv, ast.Div)):
            return a // b if b else None
        if isinstance(node.op, ast.Mod):
            return a % b if b else None
        return None
    if isinstance(node, ast.Call):
        fname = (node.func.attr if isinstance(node.func, ast.Attribute)
                 else node.func.id if isinstance(node.func, ast.Name) else "")
        vals = [_eval(a, env) for a in node.args]
        if any(v is None for v in vals):
            return None
        if fname == "cdiv" and len(vals) == 2 and vals[1]:
            return -(-vals[0] // vals[1])
        if fname == "min":
            return min(vals)
        if fname == "max":
            return max(vals)
    return None


def _iter_stmts(body: list[ast.stmt]):
    for stmt in body:
        yield stmt
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr, None)
            if isinstance(sub, list) and sub and isinstance(sub[0], ast.stmt):
                yield from _iter_stmts(sub)


def _build_env(fn: ast.FunctionDef, profile_env: dict[str, int]) -> dict[str, int]:
    env: dict[str, int] = {}
    # signature defaults (block_q=128, ...)
    args = fn.args
    pos = args.posonlyargs + args.args
    for arg, default in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        if isinstance(default, ast.Constant) and isinstance(default.value, int):
            env[arg.arg] = default.value
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if (default is not None and isinstance(default, ast.Constant)
                and isinstance(default.value, int)):
            env[arg.arg] = default.value
    env.update(profile_env)
    # straight-line assignments
    for stmt in _iter_stmts(fn.body):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            t = stmt.targets[0]
            if isinstance(t, ast.Name):
                v = _eval(stmt.value, env)
                if v is not None:
                    env[t.id] = v
    return env


# --- BlockSpec extraction ---------------------------------------------------

@dataclasses.dataclass
class SpecEstimate:
    label: str                      # "in[0]" / "out[1]"
    shape: tuple[int, ...] | None
    dtype: str
    varies: bool
    bytes: int                      # 0 if shape is None
    align_errors: list[str]


def _call_named(node: ast.expr, name: str) -> bool:
    return (isinstance(node, ast.Call)
            and (node.func.attr if isinstance(node.func, ast.Attribute)
                 else getattr(node.func, "id", "")) == name)


def _index_map_varies(spec_call: ast.Call) -> bool:
    lam = None
    if len(spec_call.args) > 1 and isinstance(spec_call.args[1], ast.Lambda):
        lam = spec_call.args[1]
    for kw in spec_call.keywords:
        if kw.arg == "index_map" and isinstance(kw.value, ast.Lambda):
            lam = kw.value
    if lam is None:
        return True  # identity map: block index == grid index → varies
    body = lam.body
    elts = body.elts if isinstance(body, ast.Tuple) else [body]
    return any(not isinstance(e, ast.Constant) for e in elts)


def _spec_shape(spec_call: ast.Call, env: dict[str, int]) -> tuple[int, ...] | None:
    if not spec_call.args:
        return None
    shp = spec_call.args[0]
    if not isinstance(shp, ast.Tuple):
        return None
    dims = [_eval(e, env) for e in shp.elts]
    if any(d is None for d in dims):
        return None
    return tuple(dims)


def _collect_specs(call: ast.Call, fn: ast.FunctionDef) -> tuple[list[ast.Call], list[ast.Call]]:
    """Return (in_spec calls, out_spec calls) for a pallas_call."""
    kwargs = {kw.arg: kw.value for kw in call.keywords}
    src = kwargs
    gs = kwargs.get("grid_spec")
    if isinstance(gs, ast.Name):
        for stmt in _iter_stmts(fn.body):
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and stmt.targets[0].id == gs.id
                    and isinstance(stmt.value, ast.Call)):
                src = {kw.arg: kw.value for kw in stmt.value.keywords}
                break
    elif isinstance(gs, ast.Call):
        src = {kw.arg: kw.value for kw in gs.keywords}

    def specs_of(node: ast.expr | None) -> list[ast.Call]:
        if node is None:
            return []
        if isinstance(node, (ast.List, ast.Tuple)):
            return [e for e in node.elts if _call_named(e, "BlockSpec")]
        if _call_named(node, "BlockSpec"):
            return [node]
        return []

    return specs_of(src.get("in_specs")), specs_of(src.get("out_specs"))


def _padded_bytes(shape: tuple[int, ...], dtype: str) -> int:
    size, sublane = DTYPE_INFO.get(dtype, (4, 8))
    dims = list(shape)
    if len(dims) >= 1:
        dims[-1] = -(-dims[-1] // 128) * 128
    if len(dims) >= 2:
        dims[-2] = -(-dims[-2] // sublane) * sublane
    total = size
    for d in dims:
        total *= max(d, 1)
    return total


def _alignment_errors(shape: tuple[int, ...], dtype: str,
                      array: tuple[int, ...] | None) -> list[str]:
    size, sublane = DTYPE_INFO.get(dtype, (4, 8))
    errs = []

    def full_span(axis_from_end: int) -> bool:
        if array is None or len(array) != len(shape):
            return False
        return shape[-axis_from_end] == array[-axis_from_end]

    if len(shape) >= 1:
        last = shape[-1]
        if last % 128 != 0 and last != 1 and not full_span(1):
            errs.append(f"lane:{last}: last dim {last} not a multiple of "
                        f"128 (lane)")
    if len(shape) >= 2:
        sub = shape[-2]
        if sub % sublane != 0 and sub != 1 and not full_span(2):
            errs.append(f"sublane:{sub}: dim {sub} not a multiple of "
                        f"{sublane} ({dtype} sublane)")
    return errs


@dataclasses.dataclass
class KernelEstimate:
    package: str
    variant: str
    path: str
    line: int
    specs: list[SpecEstimate]
    uneval: int

    @property
    def total_bytes(self) -> int:
        return sum(s.bytes for s in self.specs)

    @property
    def ok(self) -> bool:
        return (self.total_bytes <= VMEM_LIMIT and self.uneval == 0
                and not any(s.align_errors for s in self.specs))


def estimate_file(tree: ast.Module, relpath: str,
                  profiles: list[KernelProfile]) -> list[KernelEstimate]:
    package = _package_of(relpath) or Path(relpath).stem
    out: list[KernelEstimate] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        calls = [n for n in ast.walk(fn) if _call_named(n, "pallas_call")]
        for call in calls:
            in_specs, out_specs = _collect_specs(call, fn)
            all_specs = ([("in", i, s) for i, s in enumerate(in_specs)]
                         + [("out", i, s) for i, s in enumerate(out_specs)])
            for prof in profiles:
                env = _build_env(fn, prof.env)
                ests: list[SpecEstimate] = []
                uneval = 0
                for idx, (side, i, spec) in enumerate(all_specs):
                    dtype = prof.dtypes[idx] if idx < len(prof.dtypes) else "float32"
                    array = prof.arrays[idx] if idx < len(prof.arrays) else None
                    shape = _spec_shape(spec, env)
                    varies = _index_map_varies(spec)
                    if shape is None:
                        uneval += 1
                        ests.append(SpecEstimate(f"{side}[{i}]", None, dtype,
                                                 varies, 0, []))
                        continue
                    nbytes = _padded_bytes(shape, dtype) * (2 if varies else 1)
                    ests.append(SpecEstimate(
                        f"{side}[{i}]", shape, dtype, varies, nbytes,
                        _alignment_errors(shape, dtype, array)))
                out.append(KernelEstimate(package, prof.variant, relpath,
                                          call.lineno, ests, uneval))
    return out


def _package_of(relpath: str) -> str | None:
    parts = Path(relpath).parts
    if "kernels" in parts:
        i = parts.index("kernels")
        if i + 1 < len(parts) - 1:
            return parts[i + 1]
    return None


def profiles_for(relpath: str) -> list[KernelProfile] | None:
    pkg = _package_of(relpath)
    if pkg is None or not relpath.endswith("kernel.py"):
        return None
    return DEFAULT_PROFILES.get(
        pkg, [KernelProfile("default", {}, [], [])])


def check_vmem(tree: ast.Module, relpath: str,
               profiles: list[KernelProfile] | None = None) -> list[Finding]:
    profs = profiles if profiles is not None else profiles_for(relpath)
    if profs is None:
        return []
    findings: list[Finding] = []
    for est in estimate_file(tree, relpath, profs):
        name = f"{est.package}[{est.variant}]"
        if est.uneval:
            findings.append(Finding(
                rule="vmem-uneval", path=est.path, line=est.line,
                qualname=name, detail=f"{est.uneval} specs",
                message=(f"{est.uneval} BlockSpec shape(s) could not be "
                         f"evaluated — extend the {est.package} profile so the "
                         f"estimate covers every operand"),
            ))
        if est.total_bytes > VMEM_LIMIT:
            findings.append(Finding(
                rule="vmem-budget", path=est.path, line=est.line,
                qualname=name, detail=str(est.total_bytes // (1024 * 1024)),
                message=(f"estimated per-step VMEM {est.total_bytes / 2**20:.1f} "
                         f"MiB exceeds the {VMEM_LIMIT // 2**20} MiB budget"),
            ))
        for s in est.specs:
            for err in s.align_errors:
                tag, _, msg = err.partition(": ")
                findings.append(Finding(
                    rule="vmem-misaligned", path=est.path, line=est.line,
                    qualname=name, detail=f"{s.label}:{tag}",
                    message=f"{s.label} block {s.shape} {s.dtype}: {msg}",
                ))
    return findings


def render_report(estimates: list[KernelEstimate]) -> str:
    lines = [
        f"{'kernel':<24} {'blocks':>6} {'est VMEM':>10} {'limit':>8} status",
        "-" * 60,
    ]
    for est in estimates:
        name = f"{est.package}[{est.variant}]"
        status = "OK" if est.ok else "FAIL"
        if est.uneval:
            status += f" ({est.uneval} uneval)"
        align = sum(len(s.align_errors) for s in est.specs)
        if align:
            status += f" ({align} misaligned)"
        lines.append(
            f"{name:<24} {len(est.specs):>6} "
            f"{est.total_bytes / 2**20:>8.2f}MB {VMEM_LIMIT // 2**20:>6}MB "
            f"{status}")
    return "\n".join(lines)
