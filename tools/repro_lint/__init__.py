"""replint: project-invariant static analysis for the repro codebase.

Four AST passes (lock discipline, JIT-retrace hazards, tie-order invariant,
Pallas VMEM budgets) plus runtime sanitizer hooks (``retrace_guard``,
``LockSanitizer``).  See README "Static analysis" for the contract each pass
enforces.  The static passes are pure stdlib; ``runtime`` imports jax lazily.
"""

from .findings import Finding, apply_baseline, load_baseline, write_baseline
from .cli import main, run_passes
from .locks import check_locks
from .retrace import check_retrace
from .tieorder import check_tieorder
from .vmem import (DEFAULT_PROFILES, KernelProfile, VMEM_LIMIT, check_vmem,
                   estimate_file, profiles_for, render_report)

__all__ = [
    "Finding", "apply_baseline", "load_baseline", "write_baseline",
    "main", "run_passes",
    "check_locks", "check_retrace", "check_tieorder", "check_vmem",
    "DEFAULT_PROFILES", "KernelProfile", "VMEM_LIMIT",
    "estimate_file", "profiles_for", "render_report",
]
