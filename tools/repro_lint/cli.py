"""replint command line.

Usage:

    python -m tools.repro_lint src/ --baseline tools/repro_lint/baseline.json
    python -m tools.repro_lint --vmem-report
    python -m tools.repro_lint src/ --write-baseline

Pure stdlib: the static passes never import jax, so the CI lane needs no heavy
dependencies.  Exit codes: 0 clean, 1 active findings (or stale baseline
entries), 2 usage/parse error.
"""

from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path

from .findings import Finding, apply_baseline, load_baseline, write_baseline
from .locks import check_locks
from .retrace import check_retrace
from .tieorder import check_tieorder
from .vmem import check_vmem, estimate_file, profiles_for, render_report

DEFAULT_PATHS = ["src", "benchmarks", "examples"]
SKIP_PARTS = {"__pycache__", ".git", "replint_fixtures"}


def iter_py_files(paths: list[str], root: Path) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        path = (root / p) if not Path(p).is_absolute() else Path(p)
        if path.is_file() and path.suffix == ".py":
            files.append(path)
        elif path.is_dir():
            files.extend(sorted(
                f for f in path.rglob("*.py")
                if not (SKIP_PARTS & set(f.parts))))
    return files


def run_passes(files: list[Path], root: Path,
               strict_tieorder: bool = False) -> list[Finding]:
    findings: list[Finding] = []
    for f in files:
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            tree = ast.parse(f.read_text())
        except SyntaxError as e:
            findings.append(Finding(
                rule="parse-error", path=rel, line=e.lineno or 0,
                qualname="", detail="syntax",
                message=f"could not parse: {e.msg}"))
            continue
        # Lock discipline is scoped to the serving stack (the ISSUE contract):
        # retrieval-side classes like SegmentedIndex intentionally publish
        # state via atomic reference swaps and are checked by their own
        # bit-identity tests instead.
        if "serve/" in rel:
            findings.extend(check_locks(tree, rel))
        findings.extend(check_retrace(tree, rel))
        findings.extend(check_tieorder(tree, rel, strict=strict_tieorder))
        findings.extend(check_vmem(tree, rel))
    return findings


def vmem_report(root: Path) -> tuple[str, bool]:
    kernel_files = sorted((root / "src" / "repro" / "kernels").rglob("kernel.py"))
    estimates = []
    for f in kernel_files:
        rel = f.relative_to(root).as_posix()
        profs = profiles_for(rel)
        if profs is None:
            continue
        tree = ast.parse(f.read_text())
        estimates.extend(estimate_file(tree, rel, profs))
    ok = all(e.ok for e in estimates) and bool(estimates)
    return render_report(estimates), ok


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="replint: project-invariant static analysis "
                    "(locks, retrace, tie-order, VMEM)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--baseline", default=None,
                    help="baseline.json of suppressed findings (shrink-only)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to --baseline and exit 0")
    ap.add_argument("--vmem-report", action="store_true",
                    help="print per-kernel VMEM estimates and exit")
    ap.add_argument("--strict-tieorder", action="store_true",
                    help="also report non-score-like raw rank primitives")
    ap.add_argument("--root", default=".", help="repo root for relative paths")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve()

    if args.vmem_report:
        report, ok = vmem_report(root)
        print(report)
        if not ok:
            print("\nvmem-report: FAIL", file=sys.stderr)
            return 1
        return 0

    paths = args.paths or DEFAULT_PATHS
    files = iter_py_files(paths, root)
    if not files:
        print(f"replint: no python files under {paths}", file=sys.stderr)
        return 2

    findings = run_passes(files, root, strict_tieorder=args.strict_tieorder)

    if args.write_baseline:
        if not args.baseline:
            print("--write-baseline requires --baseline", file=sys.stderr)
            return 2
        write_baseline(args.baseline, findings)
        print(f"replint: wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    result = apply_baseline(findings, baseline)

    for f in sorted(result.active, key=lambda f: (f.path, f.line)):
        print(f.render())
    for key in result.stale_keys:
        print(f"stale baseline entry (fixed? delete it): {key}")

    n_files = len(files)
    print(f"replint: {n_files} files, {len(result.active)} finding(s), "
          f"{len(result.suppressed)} baselined, "
          f"{len(result.stale_keys)} stale baseline entr"
          f"{'y' if len(result.stale_keys) == 1 else 'ies'}")
    return 1 if (result.active or result.stale_keys) else 0


if __name__ == "__main__":
    sys.exit(main())
