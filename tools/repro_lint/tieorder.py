"""Tie-order invariant pass.

The repo's ranking contract is strict ``(score desc, id asc)``; every layer
(PRs 2-8) preserves it bit-for-bit.  The only module allowed to implement raw
ranking primitives is ``retrieval/topk.py`` — everything else must go through
``topk_score_then_id`` / ``masked_topk_by_id`` / ``merge_topk_block`` /
``streaming_masked_topk``, and k-handling through ``resolve_k``.

Rules:

* ``tieorder-raw-rank`` — ``argsort``/``lexsort``/``top_k``/``sort`` call on
  an expression that *looks score-like* (name contains score/sim/dist/logit)
  outside the whitelist.  This is the high-confidence error case.
* ``tieorder-raw-rank-audit`` — the same primitives on other arrays outside
  the whitelist.  These are only reported with ``--strict-tieorder`` (the CLI
  default keeps them off because argsort has legitimate non-ranking uses:
  label bucketing, routing, permutation building).

The whitelist is explicit: ``(path suffix, qualname or None, reason)``.  A
``None`` qualname whitelists the whole file.
"""

from __future__ import annotations

import ast

from .findings import Finding

RANK_CALLS = {"argsort", "lexsort", "top_k", "approx_max_k", "sort_key_val"}
SCORE_HINTS = ("score", "sim", "dist", "logit", "prob", "qd", "inner")

# (path-suffix, qualname-prefix or None, reason). Keep this list justified:
# every entry names a site whose raw primitive is NOT a document ranking, or
# whose tie order is provably (score desc, id asc) by construction.
WHITELIST: list[tuple[str, str | None, str]] = [
    ("retrieval/topk.py", None,
     "canonical tie-order module: implements the (score desc, id asc) contract"),
    ("retrieval/ivf.py", None,
     "centroid routing top_k (probe selection, not doc ranking) and "
     "np.argsort label bucketing that keeps ids ascending per list"),
    ("retrieval/segments.py", None,
     "delta-probe routing top_k and fold bucketing argsort — not doc ranking"),
    ("retrieval/sharded.py", None,
     "per-shard lax.top_k over id-ascending scan order (first occurrence wins "
     "= lowest id) and partition_lists size argsort"),
    ("retrieval/index.py", "CompressedIndex",
     "exact-search lax.top_k over id-ascending scan order"),
    ("retrieval/kmeans.py", None,
     "kmeans++ second-nearest distances — clustering, not doc ranking"),
    ("retrieval/rprecision.py", None,
     "r-precision set membership — order-insensitive metric"),
    ("kernels/topk_blocks/ref.py", None,
     "interpret-mode parity oracle for the kernel, checked against topk.py"),
    ("kernels/topk_blocks/ops.py", None,
     "stage-2 merge over stage-1 candidates already in (score desc, id asc) "
     "block order; padded -inf candidates never surface"),
    ("kernels/ivf_fused/", None,
     "in-kernel k-round merge implements the contract directly (parity-tested)"),
    ("models/moe.py", None,
     "MoE expert-routing top_k — gating, not document ranking"),
    ("benchmarks/ivf_bench.py", None,
     "centroid routing top_k for the jnp IVF baseline — probe selection"),
    ("benchmarks/kernel_bench.py", None,
     "centroid routing top_k feeding the fused kernel harness"),
]


def _whitelisted(relpath: str, qualname: str) -> str | None:
    for suffix, qual, reason in WHITELIST:
        if relpath.endswith(suffix) or (suffix.endswith("/") and suffix.rstrip("/") in relpath):
            if qual is None or qualname.startswith(qual):
                return reason
    return None


def _expr_names(node: ast.expr) -> list[str]:
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            out.append(n.attr)
    return out


def _score_like(call: ast.Call) -> bool:
    hay = []
    for arg in call.args:
        hay.extend(_expr_names(arg))
    for kw in call.keywords:
        if kw.value is not None:
            hay.extend(_expr_names(kw.value))
    joined = " ".join(hay).lower()
    return any(h in joined for h in SCORE_HINTS)


def check_tieorder(tree: ast.Module, relpath: str,
                   strict: bool = False) -> list[Finding]:
    findings: list[Finding] = []

    class V(ast.NodeVisitor):
        def __init__(self) -> None:
            self.qual: list[str] = []

        def _scoped(self, node):
            self.qual.append(node.name)
            self.generic_visit(node)
            self.qual.pop()

        visit_ClassDef = _scoped
        visit_FunctionDef = _scoped
        visit_AsyncFunctionDef = _scoped

        def visit_Call(self, node):
            name = node.func.attr if isinstance(node.func, ast.Attribute) else (
                node.func.id if isinstance(node.func, ast.Name) else "")
            if name in RANK_CALLS:
                qual = ".".join(self.qual)
                reason = _whitelisted(relpath, qual)
                if reason is None:
                    if _score_like(node):
                        findings.append(Finding(
                            rule="tieorder-raw-rank", path=relpath,
                            line=node.lineno, qualname=qual, detail=name,
                            message=(f"raw `{name}` on a score-like array — "
                                     f"route ranking through "
                                     f"topk_score_then_id/masked_topk_by_id/"
                                     f"merge_topk_block (retrieval/topk.py) to "
                                     f"preserve (score desc, id asc)"),
                        ))
                    elif strict:
                        findings.append(Finding(
                            rule="tieorder-raw-rank-audit", path=relpath,
                            line=node.lineno, qualname=qual, detail=name,
                            message=(f"raw `{name}` outside retrieval/topk.py — "
                                     f"verify this is not a document ranking, "
                                     f"then whitelist it with a reason"),
                        ))
            self.generic_visit(node)

    V().visit(tree)
    return findings
