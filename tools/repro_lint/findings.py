"""Finding model and baseline suppression for replint.

A finding is identified by a *stable key* that deliberately excludes line
numbers, so that unrelated edits do not churn the baseline:

    rule:relpath:qualname:detail

The baseline (``tools/repro_lint/baseline.json``) maps stable keys to a short
justification string.  Baseline semantics are shrink-only:

* a finding whose key appears in the baseline is *suppressed* (reported in the
  summary count but does not fail the run);
* a baseline entry that matches no current finding is **stale** and is itself
  an error — entries must be deleted as the underlying violations are fixed,
  so the baseline can only shrink over time.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # e.g. "lock-bare-read"
    path: str          # repo-relative posix path
    line: int          # 1-based line for human output (not part of the key)
    qualname: str      # Class.method or function qualname ("" for module level)
    detail: str        # stable machine detail, e.g. attribute / call name
    message: str       # human-readable explanation

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.qualname}:{self.detail}"

    def render(self) -> str:
        where = f"{self.path}:{self.line}"
        ctx = f" [{self.qualname}]" if self.qualname else ""
        return f"{where}: {self.rule}{ctx}: {self.message}"


def load_baseline(path: str | Path | None) -> dict[str, str]:
    if path is None:
        return {}
    p = Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    if not isinstance(data, dict):
        raise ValueError(f"baseline {p} must be a JSON object of key -> justification")
    return {str(k): str(v) for k, v in data.items()}


def write_baseline(path: str | Path, findings: list[Finding]) -> None:
    entries = {f.key: f.message for f in sorted(findings, key=lambda f: f.key)}
    Path(path).write_text(json.dumps(entries, indent=2, sort_keys=True) + "\n")


@dataclasses.dataclass
class BaselineResult:
    active: list[Finding]          # findings not covered by the baseline
    suppressed: list[Finding]      # findings matched by a baseline entry
    stale_keys: list[str]          # baseline entries that matched nothing


def apply_baseline(findings: list[Finding], baseline: dict[str, str]) -> BaselineResult:
    active: list[Finding] = []
    suppressed: list[Finding] = []
    used: set[str] = set()
    for f in findings:
        if f.key in baseline:
            suppressed.append(f)
            used.add(f.key)
        else:
            active.append(f)
    stale = sorted(k for k in baseline if k not in used)
    return BaselineResult(active=active, suppressed=suppressed, stale_keys=stale)
