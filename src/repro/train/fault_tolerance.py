"""Fault-tolerance machinery: preemption capture, retries, straggler watch.

On a real multi-pod deployment each host runs this; here everything is
exercised single-host (tests simulate signals/stragglers).  The pieces:

- :class:`PreemptionHandler` — catches SIGTERM/SIGINT, flips a flag the train
  loop polls; the loop saves an emergency checkpoint and exits cleanly
  (maps to Borg/GCE preemption notice or k8s SIGTERM grace period).
- :func:`with_retries` — deterministic-backoff retry wrapper for transient
  infra faults (checkpoint I/O, RPC); *compute* errors are not retried.
- :class:`StragglerMonitor` — per-step wall-time EWMA; a step slower than
  ``threshold ×`` the EWMA flags its host as a straggler.  At fleet scale the
  controller reacts by excluding the host and re-meshing
  (:mod:`repro.train.elastic`); here we log + count.
"""

from __future__ import annotations

import signal
import threading
import time
from typing import Callable, Optional, TypeVar

T = TypeVar("T")


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._installed = []
        for sig in signals:
            prev = signal.signal(sig, self._handle)
            self._installed.append((sig, prev))

    def _handle(self, signum, frame):
        self._stop.set()

    def should_stop(self) -> bool:
        return self._stop.is_set()

    def trigger(self) -> None:          # for tests
        self._stop.set()

    def uninstall(self) -> None:
        for sig, prev in self._installed:
            signal.signal(sig, prev)
        self._installed.clear()


def with_retries(fn: Callable[..., T], *args, retries: int = 3,
                 backoff: float = 0.5,
                 retry_on: tuple = (IOError, OSError),
                 log_fn=print, **kwargs) -> T:
    last: Optional[BaseException] = None
    for attempt in range(retries + 1):
        try:
            return fn(*args, **kwargs)
        except retry_on as e:           # transient infra faults only
            last = e
            if attempt < retries:
                delay = backoff * (2 ** attempt)
                log_fn(f"[retry] {fn.__name__} failed ({e}); "
                       f"attempt {attempt+1}/{retries} in {delay:.1f}s")
                time.sleep(delay)
    raise last  # type: ignore[misc]


class StragglerMonitor:
    """EWMA step-time monitor; flags steps slower than threshold × EWMA."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.2,
                 warmup_steps: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup_steps = warmup_steps
        self.ewma: Optional[float] = None
        self.n = 0
        self.flagged: list[tuple[int, float]] = []
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self.n += 1
        is_straggler = False
        if self.ewma is None:
            self.ewma = dt
        else:
            if (self.n > self.warmup_steps
                    and dt > self.threshold * self.ewma):
                self.flagged.append((self.n, dt))
                is_straggler = True
            else:
                self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler

    def observe(self, dt: float) -> bool:
        """Feed an externally-measured step time (tests)."""
        self._t0 = time.monotonic() - dt
        return self.end_step()
