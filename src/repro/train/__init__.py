"""Training substrate: optimizers, trainer loop, checkpointing, fault tolerance."""
