"""Elastic re-meshing: resume a checkpoint onto a different device count.

The recovery path after node loss (or fleet growth):

    1. controller detects failure → picks the new healthy device set,
    2. builds a new mesh (data axis shrinks/grows; model axis preserved so
       TP-sharded weights keep their layout),
    3. restores the latest checkpoint with shardings derived from the *new*
       mesh (Checkpointer.restore is mesh-agnostic),
    4. training resumes at the saved step; the data pipeline is stateless in
       step index so no samples are lost or duplicated.

Batch handling on shrink: global batch is preserved by raising the gradient-
accumulation factor (microbatches ×= old_data/new_data) — the optimizer sees
identical statistics, so loss curves continue smoothly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.utils import first_divisor_leq


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: dict[str, int]
    new_shape: dict[str, int]
    microbatch_scale: int        # multiply grad-accum by this on shrink

    @property
    def data_scale(self) -> float:
        return self.old_shape.get("data", 1) / self.new_shape.get("data", 1)


def plan_remesh(old_mesh_shape: dict[str, int], n_devices: int,
                model_axis: str = "model") -> RemeshPlan:
    """Choose a new mesh shape for ``n_devices``, preserving the model axis."""
    model = old_mesh_shape.get(model_axis, 1)
    if n_devices % model != 0:
        model = first_divisor_leq(n_devices, model)
    data = n_devices // model
    new_shape = {"data": data, model_axis: model}
    old_data = old_mesh_shape.get("data", 1) * old_mesh_shape.get("pod", 1)
    scale = max(1, int(np.ceil(old_data / data)))
    return RemeshPlan(old_shape=dict(old_mesh_shape), new_shape=new_shape,
                      microbatch_scale=scale)


def build_mesh(shape: dict[str, int],
               devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices) if devices is not None else jax.devices()
    n = int(np.prod(list(shape.values())))
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    arr = np.array(devices[:n]).reshape(*shape.values())
    return Mesh(arr, tuple(shape.keys()))


def reshard_state(state: Any, specs: Any, new_mesh: Mesh) -> Any:
    """Move a state pytree onto a new mesh (device_put per leaf)."""
    def place(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))
    return jax.tree_util.tree_map(place, state, specs)
