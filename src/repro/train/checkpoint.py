"""Sharded checkpointing with atomic publish, retention, and async save.

Layout::

    <dir>/step_000042/          # staged as .tmp-step_000042, renamed when done
        manifest.json           # step, tree structure, array index, fingerprint
        arrays.npz              # flat {path: array} (host-gathered)
    <dir>/LATEST                # text file: last complete step

Design points for the 1000-node regime (documented; single-host here):
- *atomic publish*: writers stage into a tmp dir and ``os.rename`` —
  a reader never sees a partial checkpoint; LATEST is written after.
- *restore to any mesh*: arrays are stored unsharded-logical; restore
  ``device_put``s against the *target* sharding, so a checkpoint written on
  512 chips restores onto 256 or 1024 (elastic re-mesh, fault recovery).
- *async*: save() snapshots to host then writes on a worker thread —
  training continues; ``wait()`` joins before the next save.
- *retention*: keep the newest K complete checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------
    def save(self, state: Any, step: int, blocking: bool = False) -> None:
        self.wait()
        flat = _flatten(state)
        # snapshot to host memory synchronously (cheap vs device compute)
        host = {k: np.asarray(v) for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(state)

        def write():
            name = f"step_{step:08d}"
            tmp = os.path.join(self.directory, f".tmp-{name}")
            final = os.path.join(self.directory, name)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "keys": sorted(host.keys()),
                "treedef": str(treedef),
                "time": time.time(),
                "nbytes": int(sum(a.nbytes for a in host.values())),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=2)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(self.directory, "LATEST"), "w") as f:
                f.write(str(step))
            self._retain()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if os.path.exists(path):
            step = int(open(path).read().strip())
            if step in self.all_steps():
                return step
        steps = self.all_steps()          # LATEST missing/stale: recover
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (arrays or structs).

        ``shardings``: optional matching pytree of Sharding objects — arrays
        are placed directly to their target devices (elastic re-mesh path).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        flat_like = _flatten(like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, _leaf in flat_like.items():
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if flat_shard.get(key) is not None:
                out[key] = jax.device_put(arr, flat_shard[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # rebuild tree in like's structure
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        keys_in_order = [SEP.join(_path_str(p) for p in path_)
                         for path_, _ in leaves_like]
        return jax.tree_util.tree_unflatten(
            treedef, [out[k] for k in keys_in_order])
