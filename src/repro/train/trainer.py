"""Train-step factory: loss → grads → optimizer, with microbatch gradient
accumulation, global-norm metrics, and optional compressed data-parallel
gradient exchange (see :mod:`repro.parallel.compression_comm`).

State layout (plain pytree — shards like params):
    {"params": …, "opt": tx_state, "step": int32}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib


def init_state(rng: jax.Array, init_params_fn: Callable,
               tx: opt_lib.GradientTransformation) -> dict:
    params = init_params_fn(rng)
    return {"params": params, "opt": tx.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(abstract_params: Any,
                   tx: opt_lib.GradientTransformation) -> dict:
    """ShapeDtypeStruct state tree (dry-run path, no allocation)."""
    opt = jax.eval_shape(tx.init, abstract_params)
    return {"params": abstract_params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _split_microbatches(batch: Any, n: int) -> Any:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree_util.tree_map(f, batch)


def make_train_step(loss_fn: Callable[[Any, Any], tuple[jax.Array, dict]],
                    tx: opt_lib.GradientTransformation,
                    microbatches: int = 1,
                    grad_transform: Optional[Callable] = None,
                    unroll_microbatches: bool = False) -> Callable:
    """Build ``train_step(state, batch) → (state, metrics)``.

    ``loss_fn(params, batch) → (loss, metrics_dict)``.
    ``grad_transform`` optionally post-processes grads (e.g. compressed DP
    exchange).  ``unroll_microbatches`` replaces the accumulation scan with
    a Python loop (dry-run cost pass: loop bodies count once).
    """

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: dict, batch: Any) -> tuple[dict, dict]:
        params = state["params"]
        if microbatches > 1 and unroll_microbatches:
            mb = _split_microbatches(batch, microbatches)
            loss = jnp.zeros(())
            grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            for i in range(microbatches):
                micro = jax.tree_util.tree_map(lambda x, i=i: x[i], mb)
                li, metrics, gi = compute_grads(params, micro)
                loss = loss + li
                grads = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grads, gi)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        elif microbatches > 1:
            mb = _split_microbatches(batch, microbatches)

            def body(carry, micro):
                loss_acc, grads_acc = carry
                loss, metrics, grads = compute_grads(params, micro)
                grads_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32),
                    grads_acc, grads)
                return (loss_acc + loss, grads_acc), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), metrics = jax.lax.scan(
                body, (jnp.zeros(()), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = compute_grads(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)

        updates, opt = tx.update(grads, state["opt"], params)
        params = opt_lib.apply_updates(params, updates)
        new_state = {"params": params, "opt": opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = opt_lib.global_norm(grads)
        return new_state, metrics

    return train_step


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 0           # 0 = disabled
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3


def run_train_loop(train_step, state, batch_iter, cfg: TrainLoopConfig,
                   checkpointer=None, preemption=None,
                   log_fn=print) -> tuple[dict, list[dict]]:
    """Host training loop with checkpointing + preemption handling.

    ``batch_iter`` yields batches; ``checkpointer`` is a
    :class:`repro.train.checkpoint.Checkpointer`; ``preemption`` a
    :class:`repro.train.fault_tolerance.PreemptionHandler`.
    """
    history = []
    start = int(state["step"])
    step_jit = jax.jit(train_step, donate_argnums=(0,))
    for step in range(start, cfg.total_steps):
        batch = next(batch_iter)
        state, metrics = step_jit(state, batch)
        if preemption is not None and preemption.should_stop():
            if checkpointer is not None:
                checkpointer.save(state, step + 1, blocking=True)
            log_fn(f"[preempt] saved emergency checkpoint at step {step+1}")
            break
        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step + 1, **m})
            log_fn(f"step {step+1}: " +
                   " ".join(f"{k}={v:.4f}" for k, v in m.items()))
        if (cfg.checkpoint_every and checkpointer is not None
                and (step + 1) % cfg.checkpoint_every == 0):
            checkpointer.save(state, step + 1)
    return state, history
