"""Minimal optax-style optimizer library (self-contained; no optax dependency).

Gradient transformations compose with :func:`chain`; every transformation is a
pair of pure functions (``init``, ``update``) over pytrees, so optimizer state
shards exactly like the parameters (see ``repro.parallel.sharding`` for the
ZeRO rules applied on top).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any
OptState = Any
Schedule = Callable[[jax.Array], jax.Array]


class GradientTransformation(NamedTuple):
    init: Callable[[Params], OptState]
    update: Callable[[Params, OptState, Params], tuple[Params, OptState]]
    # update(grads, state, params) -> (updates, new_state)


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def constant_schedule(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    end_fraction: float = 0.1) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak * (end_fraction + (1 - end_fraction)
                      * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def linear_warmup_schedule(peak: float, warmup_steps: int) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        return peak * jnp.minimum(1.0, step / jnp.maximum(1.0, warmup_steps))
    return sched


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# transformations
# ---------------------------------------------------------------------------


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)


class ScaleByAdamState(NamedTuple):
    count: jax.Array
    mu: Params
    nu: Params


def scale_by_adam(b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-8) -> GradientTransformation:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return ScaleByAdamState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params))

    def update(grads, state, params=None):
        count = state.count + 1
        f32 = lambda g: g.astype(jnp.float32)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * f32(g), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(f32(g)),
            state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree_util.tree_map(
            lambda m, v: (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu)
        return updates, ScaleByAdamState(count, mu, nu)

    return GradientTransformation(init, update)


class ScaleByAdamQ8State(NamedTuple):
    count: jax.Array
    mu_q: Params            # int8 codes
    mu_scale: Params        # per-tensor absmax scales
    nu_q: Params
    nu_scale: Params


def scale_by_adam_q8(b1: float = 0.9, b2: float = 0.999,
                     eps: float = 1e-8) -> GradientTransformation:
    """Adam with int8-quantized moments (per-tensor absmax scaling).

    The paper's precision-reduction insight applied to optimizer state:
    m and v are stored as int8 + one fp32 scale per tensor — 2 bytes/param
    of optimizer state instead of 8 (the dominant memory of large-model
    training; see EXPERIMENTS.md §Perf).  Dequant → update → requant per
    step; the requant error is O(absmax/127) per step and empirically
    indistinguishable on convergence (tests/test_train.py).
    """

    def _q(x):
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-20
        return (jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8),
                scale)

    def _dq(q, scale):
        return q.astype(jnp.float32) * scale

    def init(params):
        z8 = lambda p: jnp.zeros(p.shape, jnp.int8)
        zs = lambda p: jnp.zeros((), jnp.float32)
        return ScaleByAdamQ8State(
            count=jnp.zeros((), jnp.int32),
            mu_q=jax.tree_util.tree_map(z8, params),
            mu_scale=jax.tree_util.tree_map(zs, params),
            nu_q=jax.tree_util.tree_map(z8, params),
            nu_scale=jax.tree_util.tree_map(zs, params))

    def update(grads, state, params=None):
        count = state.count + 1
        f32 = lambda g: g.astype(jnp.float32)

        def upd_mu(q, s, g):
            m = b1 * _dq(q, s) + (1 - b1) * f32(g)
            return _q(m) + (m,)

        def upd_nu(q, s, g):
            v = b2 * _dq(q, s) + (1 - b2) * jnp.square(f32(g))
            return _q(v) + (v,)

        mu_t = jax.tree_util.tree_map(upd_mu, state.mu_q, state.mu_scale,
                                      grads)
        nu_t = jax.tree_util.tree_map(upd_nu, state.nu_q, state.nu_scale,
                                      grads)
        unzip = lambda t, i: jax.tree_util.tree_map(
            lambda x: x[i], t, is_leaf=lambda x: isinstance(x, tuple))
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree_util.tree_map(
            lambda mt, vt: (mt[2] / c1) / (jnp.sqrt(vt[2] / c2) + eps),
            mu_t, nu_t, is_leaf=lambda x: isinstance(x, tuple))
        return updates, ScaleByAdamQ8State(
            count, unzip(mu_t, 0), unzip(mu_t, 1),
            unzip(nu_t, 0), unzip(nu_t, 1))

    return GradientTransformation(init, update)


def add_decayed_weights(weight_decay: float,
                        mask_fn: Optional[Callable] = None,
                        ) -> GradientTransformation:
    """Adds wd·param to the (normalized-gradient) update. mask_fn(path, p)
    returns True for params to decay; default: decay only ndim >= 2."""

    def init(params):
        return ()

    def update(updates, state, params):
        if params is None:
            raise ValueError("add_decayed_weights needs params")

        def f(u, p):
            decay = weight_decay if (mask_fn is None and p.ndim >= 2) else (
                weight_decay if (mask_fn is not None and mask_fn(p)) else 0.0)
            return u + decay * p.astype(jnp.float32)

        return jax.tree_util.tree_map(f, updates, params), state

    return GradientTransformation(init, update)


def scale_by_schedule(lr) -> GradientTransformation:
    sched = _as_schedule(lr)

    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(updates, count, params=None):
        step_lr = sched(count)
        return (jax.tree_util.tree_map(lambda u: -step_lr * u, updates),
                count + 1)

    return GradientTransformation(init, update)


def add_l1_penalty(l1: float) -> GradientTransformation:
    """Subgradient of λ·|w|₁ added to grads (paper autoencoder Table 3)."""

    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree_util.tree_map(
            lambda g, p: g + l1 * jnp.sign(p.astype(jnp.float32)),
            grads, params), state

    return GradientTransformation(init, update)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# user-facing factories
# ---------------------------------------------------------------------------


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, l1: float = 0.0,
          max_grad_norm: Optional[float] = None,
          quantized_state: bool = False) -> GradientTransformation:
    parts: list[GradientTransformation] = []
    if l1 > 0:
        parts.append(add_l1_penalty(l1))
    if max_grad_norm is not None:
        parts.append(clip_by_global_norm(max_grad_norm))
    parts.append(scale_by_adam_q8(b1, b2, eps) if quantized_state
                 else scale_by_adam(b1, b2, eps))
    if weight_decay > 0:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(scale_by_schedule(lr))
    return chain(*parts)


def sgd(lr, momentum: float = 0.0) -> GradientTransformation:
    if momentum == 0.0:
        return chain(scale_by_schedule(lr))

    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None):
        state = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        return state, state

    return chain(GradientTransformation(init, update), scale_by_schedule(lr))


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Config-file friendly optimizer spec."""

    name: str = "adamw"
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # constant|cosine|warmup_linear
    quantized_state: bool = False  # int8 Adam moments (see scale_by_adam_q8)

    def build(self) -> GradientTransformation:
        if self.schedule == "cosine":
            lr = cosine_schedule(self.lr, self.warmup_steps, self.total_steps)
        elif self.schedule == "warmup_linear":
            lr = linear_warmup_schedule(self.lr, self.warmup_steps)
        else:
            lr = constant_schedule(self.lr)
        if self.name == "adamw":
            return adamw(lr, self.b1, self.b2, self.eps, self.weight_decay,
                         max_grad_norm=self.max_grad_norm,
                         quantized_state=self.quantized_state)
        if self.name == "sgd":
            return sgd(lr)
        raise ValueError(f"unknown optimizer {self.name!r}")
