"""repro — production-grade JAX framework reproducing and extending

    "Knowledge Base Index Compression via Dimensionality and Precision
     Reduction" (Zouhar, Mosbach, Zhang, Klakow; 2022, cs.IR).

Layers
------
- ``repro.core``      : the paper's contribution — post-hoc unsupervised index
                        compression (PCA, random projections, autoencoders,
                        precision reduction) with composable pipelines.
- ``repro.retrieval`` : dense retrieval substrate — exact/IVF top-k search,
                        sharded multi-pod search, R-Precision evaluation.
- ``repro.kernels``   : Pallas TPU kernels for the compressed-index hot paths.
- ``repro.models``    : transformer LM (dense + MoE), SchNet GNN, recsys archs.
- ``repro.train``     : optimizer, trainer, checkpointing, fault tolerance.
- ``repro.data``      : deterministic synthetic corpora + sharded loaders.
- ``repro.launch``    : production mesh, multi-pod dry-run, roofline, CLIs.
"""

__version__ = "1.0.0"
