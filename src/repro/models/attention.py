"""Grouped-query attention with RoPE: training, prefill, and KV-cache decode.

Blockwise (query-chunked) attention keeps the (q_chunk × S) score tile
bounded regardless of sequence length — at 32k prefill this is the difference
between a 12.9 GiB and a 0.4 GiB per-device transient (DESIGN.md §4).  The
chunk loop is a ``lax.scan`` (compile size O(1) in sequence length).

Sharding (logical axes): activations (batch, seq, heads/kv_heads, None);
decode KV caches optionally (batch|kv_seq) — for ``long_500k`` (batch=1) the
cache shards over the *sequence* axis and XLA's SPMD partitioner produces the
flash-decoding split-K schedule (partial softmax + cross-device merge).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import layers as L
from repro.parallel.sharding import shard

NEG_INF = -1e30


def attention_spec(cfg: LMConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    spec = {
        "wq": L.ParamSpec((d, cfg.n_heads, hd), ("fsdp", "heads", None)),
        "wk": L.ParamSpec((d, cfg.n_kv_heads, hd), ("fsdp", "kv_heads", None)),
        "wv": L.ParamSpec((d, cfg.n_kv_heads, hd), ("fsdp", "kv_heads", None)),
        "wo": L.ParamSpec((cfg.n_heads, hd, d), ("heads", None, "fsdp")),
    }
    if cfg.qkv_bias:
        spec["bq"] = L.ParamSpec((cfg.n_heads, hd), ("heads", None), "zeros")
        spec["bk"] = L.ParamSpec((cfg.n_kv_heads, hd), ("kv_heads", None),
                                 "zeros")
        spec["bv"] = L.ParamSpec((cfg.n_kv_heads, hd), ("kv_heads", None),
                                 "zeros")
    return spec


def _project_qkv(p: dict, x: jax.Array, cfg: LMConfig, dt):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array, n_kv: int) -> jax.Array:
    """q (B,Sq,H,hd), k (B,Sk,KV,hd) → scores (B,KV,G,Sq,Sk) float32."""
    b, sq, h, hd = q.shape
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    return scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))


def _gqa_out(weights: jax.Array, v: jax.Array) -> jax.Array:
    """weights (B,KV,G,Sq,Sk) × v (B,Sk,KV,hd) → (B,Sq,H,hd)."""
    b, kv, g, sq, sk = weights.shape
    o = jnp.einsum("bkgst,btkh->bskgh", weights.astype(v.dtype), v)
    return o.reshape(b, sq, kv * g, o.shape[-1])


def _chunked_causal_attend(q, k, v, p, cfg: LMConfig) -> jax.Array:
    """Query-chunked causal attention: scans chunks of cfg.attn_q_chunk
    queries against the full K/V, masking causally by absolute position.
    The (chunk × S) score tile bounds transient memory at any S."""
    dt = q.dtype
    b, s = q.shape[0], q.shape[1]
    n_kv = cfg.n_kv_heads
    chunk = min(cfg.attn_q_chunk or s, s)
    if s % chunk != 0:
        chunk = s  # irregular sizes: single chunk

    kv_pos = jnp.arange(s)

    def chunk_attn(q_chunk: jax.Array, q_start) -> jax.Array:
        sq = q_chunk.shape[1]
        scores = _gqa_scores(q_chunk, k, n_kv)       # (B,KV,G,sq,S)
        q_pos = q_start + jnp.arange(sq)
        causal = kv_pos[None, :] <= q_pos[:, None]   # (sq, S)
        scores = jnp.where(causal[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(w, v)                        # (B,sq,H,hd)

    if chunk == s:
        o = chunk_attn(q, 0)
    elif not cfg.scan_layers:
        # cost/unrolled mode: Python loop so every tile is counted
        outs = [chunk_attn(q[:, i * chunk:(i + 1) * chunk], i * chunk)
                for i in range(s // chunk)]
        o = jnp.concatenate(outs, axis=1)
    else:
        n_chunks = s // chunk
        q_chunks = q.reshape(b, n_chunks, chunk, *q.shape[2:])
        q_chunks = jnp.moveaxis(q_chunks, 1, 0)      # (n, B, chunk, H, hd)

        def body(_, args):
            i, qc = args
            return None, chunk_attn(qc, i * chunk)

        _, o_chunks = jax.lax.scan(
            body, None, (jnp.arange(n_chunks), q_chunks))
        o = jnp.moveaxis(o_chunks, 0, 1).reshape(b, s, cfg.n_heads, -1)

    o = shard(o, "batch", None, "heads", None)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(dt))


def _online_causal_attend(q, k, v, p, cfg: LMConfig) -> jax.Array:
    """Flash-style attention: q-chunk × kv-chunk tiles with ONLINE softmax
    (running max/sum carried across kv chunks).

    The (S × S) score matrix never exists — per (q,kv) tile the chain
    QKᵀ → mask → exp → partial-PV is one fusion cluster whose HBM traffic
    is O(tile edges), not O(tile area).  This is the jnp expression of the
    FlashAttention schedule; on TPU, XLA fuses the tile chain (and the
    Pallas splash kernel is the logical next step).  Numerics: max/sum
    statistics in f32, weights applied in bf16.
    """
    dt = q.dtype
    b, s = q.shape[0], q.shape[1]
    n_kv = cfg.n_kv_heads
    h = cfg.n_heads
    g = h // n_kv
    hd = q.shape[-1]
    cq = min(cfg.attn_q_chunk or s, s)
    if s % cq != 0:
        cq = s
    ck = cq  # kv chunk size = q chunk size
    n_q, n_k = s // cq, s // ck

    qg = q.reshape(b, n_q, cq, n_kv, g, hd)
    kg = k.reshape(b, n_k, ck, n_kv, hd)
    vg = v.reshape(b, n_k, ck, n_kv, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    def q_block(qi, q_tile):
        # carries: running (max, sum, out) over kv chunks
        m0 = jnp.full((b, n_kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, cq), jnp.float32)
        o0 = jnp.zeros((b, cq, n_kv, g, hd), jnp.float32)

        def kv_step(carry, kj):
            m, l, o = carry
            k_tile, v_tile = kg[:, kj], vg[:, kj]
            scores = jnp.einsum("bskgh,btkh->bkgst", q_tile, k_tile,
                                preferred_element_type=jnp.float32) * scale
            q_pos = qi * cq + jnp.arange(cq)
            kv_pos = kj * ck + jnp.arange(ck)
            causal = kv_pos[None, :] <= q_pos[:, None]
            scores = jnp.where(causal[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
            p_tile = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p_tile, axis=-1)
            o_new = (o * jnp.moveaxis(corr, -1, 1)[..., None]
                     + jnp.einsum("bkgst,btkh->bskgh",
                                  p_tile.astype(dt), v_tile
                                  ).astype(jnp.float32))
            return (m_new, l_new, o_new), None

        if cfg.scan_layers:
            # scan ALL kv chunks (static length); fully-future chunks are
            # -inf-masked → p=0, carries unchanged (numerically safe since
            # chunk 0 always contains valid positions)
            (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0),
                                        jnp.arange(n_k))
        else:
            # cost/unrolled mode: only the causally-needed tiles (this is
            # also what a production flash kernel schedules)
            carry = (m0, l0, o0)
            kmax = (int(qi) + 1) if isinstance(qi, int) else n_k
            for kj in range(kmax):
                carry, _ = kv_step(carry, kj)
            m, l, o = carry
        o = o / jnp.moveaxis(l, -1, 1)[..., None]
        return o.reshape(b, cq, h, hd).astype(dt)

    if n_q == 1:
        o = q_block(0, qg[:, 0])
    elif not cfg.scan_layers:
        outs = [q_block(i, qg[:, i]) for i in range(n_q)]
        o = jnp.concatenate(outs, axis=1)
    else:
        _, o_chunks = jax.lax.scan(
            lambda _, args: (None, q_block(args[0], args[1])),
            None, (jnp.arange(n_q), jnp.moveaxis(qg, 1, 0)))
        o = jnp.moveaxis(o_chunks, 0, 1).reshape(b, s, h, hd)
    o = shard(o, "batch", None, "heads", None)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(dt))


def self_attention(p: dict, x: jax.Array, cos: jax.Array, sin: jax.Array,
                   cfg: LMConfig) -> jax.Array:
    """Causal self-attention over the full sequence (training)."""
    dt = x.dtype
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, dt)
    q = L.apply_rope(q, cos[:s], sin[:s])
    k = L.apply_rope(k, cos[:s], sin[:s])
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    if cfg.attn_impl == "online":
        return _online_causal_attend(q, k, v, p, cfg)
    return _chunked_causal_attend(q, k, v, p, cfg)


def prefill_attention(p: dict, x: jax.Array, cos: jax.Array, sin: jax.Array,
                      cfg: LMConfig) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Like self_attention, but also returns (k, v) for the decode cache."""
    dt = x.dtype
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, dt)
    q = L.apply_rope(q, cos[:s], sin[:s])
    k = L.apply_rope(k, cos[:s], sin[:s])
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    if cfg.attn_impl == "online":
        out = _online_causal_attend(q, k, v, p, cfg)
    else:
        out = _chunked_causal_attend(q, k, v, p, cfg)
    return out, k, v


def decode_attention(p: dict, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, cos: jax.Array,
                     sin: jax.Array, cfg: LMConfig,
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a (B, S, KV, hd) cache.

    ``pos`` is the scalar index of the new token (same for every sequence in
    the batch — the serving benchmark regime).  Returns (out, new_k_cache,
    new_v_cache).  With the cache sequence-sharded ("kv_seq" → mesh axis),
    XLA emits the split-K flash-decoding schedule automatically.
    """
    dt = x.dtype
    b, one, _ = x.shape
    s_max = cache_k.shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg, dt)        # (B,1,·,hd)
    positions = jnp.full((b,), pos, jnp.int32)
    q = L.apply_rope_at(q, cos, sin, positions)
    k_new = L.apply_rope_at(k_new, cos, sin, positions)

    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))

    scores = _gqa_scores(q, cache_k.astype(dt), cfg.n_kv_heads)
    # mask future slots (cache positions > pos)
    valid = jnp.arange(s_max)[None, :] <= pos
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w, cache_v.astype(dt))
    out = jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(dt))
    return out, cache_k, cache_v
