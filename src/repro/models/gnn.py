"""SchNet (Schütt et al., arXiv:1706.08566) — continuous-filter conv GNN.

Message passing is built on ``jax.ops.segment_sum`` over an edge index (the
JAX-native scatter formulation — there is no SpMM primitive to lean on):

    cfconv:  m_ij = (W₁ x_src(j))  ⊙  filter(rbf(‖r_i − r_j‖))
             x_i ← x_i + W₂ · ssp( segment_sum_i(m_ij) )

Supports three input regimes (the assigned shapes):
- full-graph  (Cora-scale & ogb-products-scale): node features projected into
  the hidden space, positions synthesized per node, per-node classification;
- sampled minibatch (GraphSAGE-style fanout sampling, see
  ``repro.data.graphs.NeighborSampler``) with padded subgraphs + masks;
- batched small molecules: atom-type embeddings, per-graph energy readout.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SchNetConfig
from repro.models import layers as L
from repro.parallel.sharding import shard


def ssp(x: jax.Array) -> jax.Array:
    """Shifted softplus (SchNet's activation)."""
    return jax.nn.softplus(x) - jnp.log(2.0)


def rbf_expand(dist: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """(E,) distances → (E, n_rbf) Gaussian radial basis."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 1.0 / (centers[1] - centers[0]) ** 2
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def interaction_spec(cfg: SchNetConfig) -> dict:
    h, r = cfg.d_hidden, cfg.n_rbf
    return {
        "w_pre": L.dense_spec(h, h, None, None, bias=False),
        "filter1": L.dense_spec(r, h, None, "ff"),
        "filter2": L.dense_spec(h, h, "ff", None),
        "w_post1": L.dense_spec(h, h, None, "ff"),
        "w_post2": L.dense_spec(h, h, "ff", None),
    }


def schnet_spec(cfg: SchNetConfig) -> dict:
    h = cfg.d_hidden
    spec = {
        "interactions": [interaction_spec(cfg)
                         for _ in range(cfg.n_interactions)],
        "readout1": L.dense_spec(h, max(h // 2, 8), None, "ff"),
    }
    if cfg.d_feat_in:
        spec["feat_proj"] = L.dense_spec(cfg.d_feat_in, h, None, None)
    else:
        spec["atom_embed"] = L.ParamSpec((cfg.n_atom_types, h),
                                         ("vocab", None), "embed", 1.0)
    out_dim = cfg.n_classes if cfg.task == "node" else 1
    spec["readout2"] = L.dense_spec(max(h // 2, 8), out_dim, "ff", None)
    return spec


def init(rng: jax.Array, cfg: SchNetConfig) -> dict:
    return L.init_params(rng, schnet_spec(cfg))


def _interaction(p: dict, x: jax.Array, edge_src: jax.Array,
                 edge_dst: jax.Array, rbf: jax.Array, edge_mask,
                 n_nodes: int, dt) -> jax.Array:
    """One cfconv + atom-wise update block."""
    w = L.dense(p["filter1"], rbf.astype(dt), dt)
    w = ssp(w)
    w = L.dense(p["filter2"], w, dt)                      # (E, h) filters
    if edge_mask is not None:
        w = w * edge_mask[:, None].astype(dt)
    m = L.dense(p["w_pre"], x, dt)[edge_src] * w          # (E, h) messages
    agg = jax.ops.segment_sum(m, edge_dst, num_segments=n_nodes)
    agg = ssp(L.dense(p["w_post1"], agg, dt))
    agg = L.dense(p["w_post2"], agg, dt)
    return x + agg


def forward(params: dict, batch: dict, cfg: SchNetConfig,
            n_graphs: Optional[int] = None) -> jax.Array:
    """batch: positions (N,3), edge_index (2,E), and either
    ``features`` (N, d_feat) or ``atom_types`` (N,); optional edge_mask (E,),
    node_mask (N,), graph_ids (N,) for molecule batching.  ``n_graphs`` must
    be static for graph tasks (defaults to targets' batch dim).

    Returns per-node outputs (N, n_classes) for node tasks, or per-graph
    energies (G,) for graph tasks.
    """
    dt = jnp.bfloat16
    pos = batch["positions"].astype(jnp.float32)
    edge_src, edge_dst = batch["edge_index"][0], batch["edge_index"][1]
    n_nodes = pos.shape[0]

    if "features" in batch:
        x = L.dense(params["feat_proj"], batch["features"].astype(dt), dt)
    else:
        x = params["atom_embed"][batch["atom_types"]].astype(dt)
    x = shard(x, "batch", None)

    diff = pos[edge_src] - pos[edge_dst]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    edge_mask = batch.get("edge_mask")

    for p_int in params["interactions"]:
        x = _interaction(p_int, x, edge_src, edge_dst, rbf, edge_mask,
                         n_nodes, dt)

    h = ssp(L.dense(params["readout1"], x, dt))
    out = L.dense(params["readout2"], h, dt).astype(jnp.float32)

    if cfg.task == "graph":
        graph_ids = batch["graph_ids"]
        if n_graphs is None:
            n_graphs = int(batch["targets"].shape[0])
        node_mask = batch.get("node_mask")
        e = out[:, 0]
        if node_mask is not None:
            e = e * node_mask
        return jax.ops.segment_sum(e, graph_ids, num_segments=n_graphs)
    return out


def node_embeddings(params: dict, batch: dict, cfg: SchNetConfig) -> jax.Array:
    """Hidden-state embeddings (N, d_hidden) — the KB index for the paper's
    compression technique (molecule/node retrieval)."""
    dt = jnp.bfloat16
    pos = batch["positions"].astype(jnp.float32)
    edge_src, edge_dst = batch["edge_index"][0], batch["edge_index"][1]
    if "features" in batch:
        x = L.dense(params["feat_proj"], batch["features"].astype(dt), dt)
    else:
        x = params["atom_embed"][batch["atom_types"]].astype(dt)
    diff = pos[edge_src] - pos[edge_dst]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    for p_int in params["interactions"]:
        x = _interaction(p_int, x, edge_src, edge_dst, rbf,
                         batch.get("edge_mask"), pos.shape[0], dt)
    return x.astype(jnp.float32)


def loss_fn(params: dict, batch: dict, cfg: SchNetConfig):
    n_graphs = (int(batch["targets"].shape[0])
                if cfg.task == "graph" else None)
    out = forward(params, batch, cfg, n_graphs=n_graphs)
    if cfg.task == "graph":
        err = out - batch["targets"]
        loss = jnp.mean(jnp.square(err))
        return loss, {"mse": loss}
    logp = jax.nn.log_softmax(out, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch.get("label_mask")
    if mask is not None:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss, {"ce": loss}
