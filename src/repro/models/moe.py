"""Mixture-of-Experts FFN with grouped, sort-based token dispatch.

Tokens are processed in **groups** aligned with the data-parallel shards
(MaxText-style): each group argsorts *its own* tokens by assigned expert,
computes positions-within-expert via a searchsorted prefix, and drops tokens
beyond each expert's per-group capacity (written to a sacrificial slot).
All sorting/scatter/gather indexing is then local to a data shard; the only
cross-device movement is the (groups × experts × capacity × d) dispatch
buffer resharding for the expert GEMMs — the all-to-all that defines
expert parallelism.  This avoids both the O(T·E·C) GShard one-hot tensors
and any global (cross-shard) sort.

Load-balancing auxiliary loss follows Switch (f·P, scaled by E).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import layers as L
from repro.parallel.sharding import ShardingContext, shard


def moe_spec(cfg: LMConfig) -> dict:
    moe = cfg.moe
    d, ff, e = cfg.d_model, cfg.d_ff, moe.n_experts
    spec = {
        "router": L.ParamSpec((d, e), (None, "experts"), "normal"),
        "w_out": L.ParamSpec((e, ff, d), ("experts", "ff", "fsdp")),
        "w_in": L.ParamSpec((e, d, ff), ("experts", "fsdp", "ff")),
    }
    if cfg.ffn == "swiglu":
        spec["w_gate"] = L.ParamSpec((e, d, ff), ("experts", "fsdp", "ff"))
    return spec


def _activation(cfg: LMConfig, h: jax.Array,
                g: Optional[jax.Array]) -> jax.Array:
    if cfg.ffn == "swiglu":
        return jax.nn.silu(g) * h
    if cfg.ffn == "squared_relu":
        return L.squared_relu(h)
    return jax.nn.gelu(h)


def load_balance_loss(probs: jax.Array, expert_ids: jax.Array,
                      n_experts: int) -> jax.Array:
    """Switch-style aux loss: E · Σ_e f_e · P_e (over all tokens)."""
    f = jnp.mean(
        jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32),
        axis=tuple(range(expert_ids.ndim)))
    p = jnp.mean(probs.astype(jnp.float32),
                 axis=tuple(range(probs.ndim - 1)))
    return n_experts * jnp.sum(f * p)


def _n_groups(t: int) -> int:
    """Dispatch groups = data-parallel shards (1 without a mesh)."""
    ctx = ShardingContext.current()
    if ctx is None or ctx.mesh is None or ctx.rules is None:
        return 1
    ax = ctx.rules.get("batch")
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    g = 1
    for a in axes:
        g *= ctx.mesh.shape.get(a, 1)
    while g > 1 and t % g != 0:
        g //= 2
    return max(g, 1)


def _dispatch_group(x: jax.Array, expert_ids: jax.Array, gate: jax.Array,
                    capacity: int, e: int, dt):
    """One group's sort-based dispatch.  x (Tg, d); ids/gate (Tg, k).
    Returns (buf (E, C, d), combine metadata)."""
    tg, d = x.shape
    k = expert_ids.shape[-1]
    flat_e = expert_ids.reshape(-1)                        # (Tg·k,)
    sort_idx = jnp.argsort(flat_e)
    sorted_e = flat_e[sort_idx]
    token_of = sort_idx // k
    first_occ = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos = jnp.arange(tg * k) - jnp.take(first_occ, sorted_e)
    keep = pos < capacity
    slot = jnp.where(keep, pos, capacity)   # dropped → sacrificial slot

    buf = jnp.zeros((e, capacity + 1, d), dt)
    buf = buf.at[sorted_e, slot].set(x[token_of])
    buf = buf[:, :capacity]
    gate_sorted = gate.reshape(-1)[sort_idx].astype(dt)
    return buf, (sorted_e, slot, token_of, keep, gate_sorted)


def _combine_group(out_buf: jax.Array, meta, tg: int, dt) -> jax.Array:
    """Scatter-add weighted expert outputs back to the group's tokens."""
    sorted_e, slot, token_of, keep, gate_sorted = meta
    e, capacity, d = out_buf.shape
    padded = jnp.concatenate(
        [out_buf, jnp.zeros((e, 1, d), dt)], axis=1)
    vals = padded[sorted_e, slot]
    vals = vals * gate_sorted[:, None] * keep.astype(dt)[:, None]
    return jnp.zeros((tg, d), dt).at[token_of].add(vals)


def moe_ffn(p: dict, x: jax.Array, cfg: LMConfig,
            ) -> tuple[jax.Array, jax.Array]:
    """x (T, d) flat tokens → (out (T, d), aux_loss scalar)."""
    moe = cfg.moe
    t, d = x.shape
    e, k = moe.n_experts, moe.top_k
    dt = x.dtype
    g = _n_groups(t)
    tg = t // g

    # --- routing (fp32)
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate, expert_ids = jax.lax.top_k(probs, k)                # (T, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    aux = load_balance_loss(probs, expert_ids[:, 0], e)

    capacity = max(1, int(moe.capacity_factor * tg * k / e))

    # --- per-group dispatch (vmapped; groups align with data shards so all
    #     index math is shard-local)
    xg = x.reshape(g, tg, d)
    idg = expert_ids.reshape(g, tg, k)
    gateg = gate.reshape(g, tg, k)
    buf, meta = jax.vmap(
        lambda xx, ii, gg: _dispatch_group(xx, ii, gg, capacity, e, dt)
    )(xg, idg, gateg)
    buf = shard(buf, "batch", "experts", None, None)          # (G, E, C, d)

    # --- expert GEMMs (experts sharded over "model"; groups over "data")
    h = jnp.einsum("gecd,edf->gecf", buf, p["w_in"].astype(dt))
    gg = (jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
          if "w_gate" in p else None)
    h = _activation(cfg, h, gg)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(dt))
    out_buf = shard(out_buf, "batch", "experts", None, None)

    # --- combine
    out = jax.vmap(lambda ob, m: _combine_group(ob, m, tg, dt))(
        out_buf, meta)
    return out.reshape(t, d), aux.astype(jnp.float32)
