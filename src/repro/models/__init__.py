"""Model zoo: transformer LM (dense/MoE), SchNet GNN, recsys architectures.

All models are pure-functional param-dict modules built on the ParamSpec DSL
in :mod:`repro.models.layers` — a single source of truth for shapes, init
and logical sharding axes.
"""
