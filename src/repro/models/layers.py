"""ParamSpec DSL + core layers (self-contained; no flax).

A model is described by a pytree of :class:`ParamSpec` leaves; the same tree
yields (a) initialized parameters, (b) logical sharding axes, and (c)
``jax.eval_shape``-compatible abstract params for the multi-pod dry-run —
one source of truth for shape, init and distribution.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np



@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]        # logical axis per dim
    init: str = "normal"                   # normal|zeros|ones|glorot|embed
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(rng: jax.Array, spec_tree: Any) -> Any:
    """Materialise parameters from a ParamSpec tree (deterministic)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))

    def make(key, spec: ParamSpec):
        shape, dt = spec.shape, spec.dtype
        if spec.init == "zeros":
            return jnp.zeros(shape, dt)
        if spec.init == "ones":
            return jnp.ones(shape, dt)
        if spec.init == "normal":
            fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
            std = spec.scale / math.sqrt(fan_in)
            return (jax.random.normal(key, shape, jnp.float32) * std
                    ).astype(dt)
        if spec.init == "glorot":
            fan_in = int(np.prod(shape[:-1])) or 1
            fan_out = shape[-1]
            limit = math.sqrt(6.0 / (fan_in + fan_out)) * spec.scale
            return jax.random.uniform(key, shape, jnp.float32,
                                      -limit, limit).astype(dt)
        if spec.init == "embed":
            return (jax.random.normal(key, shape, jnp.float32)
                    * spec.scale).astype(dt)
        raise ValueError(f"unknown init {spec.init!r}")

    params = [make(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, params)


def abstract_params(spec_tree: Any) -> Any:
    """ShapeDtypeStruct tree (no allocation) — dry-run path."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=_is_spec)


def logical_axes(spec_tree: Any) -> Any:
    """Pytree of logical-axis tuples mirroring the params tree."""
    return jax.tree_util.tree_map(lambda s: s.axes, spec_tree,
                                  is_leaf=_is_spec)


def param_count(spec_tree: Any) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree_util.tree_leaves(spec_tree, is_leaf=_is_spec))


# ---------------------------------------------------------------------------
# layer applications (params are plain dict leaves produced from specs)
# ---------------------------------------------------------------------------


def dense_spec(d_in: int, d_out: int, in_axis: Optional[str],
               out_axis: Optional[str], bias: bool = True,
               init: str = "normal", scale: float = 1.0) -> dict:
    spec = {"w": ParamSpec((d_in, d_out), (in_axis, out_axis), init, scale)}
    if bias:
        spec["b"] = ParamSpec((d_out,), (out_axis,), "zeros")
    return spec


def dense(p: dict, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    w = p["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def rmsnorm_spec(d: int, axis: Optional[str] = None) -> dict:
    return {"scale": ParamSpec((d,), (axis,), "ones")}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


def layernorm_spec(d: int, axis: Optional[str] = None) -> dict:
    return {"scale": ParamSpec((d,), (axis,), "ones"),
            "bias": ParamSpec((d,), (axis,), "zeros")}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def mlp_spec(dims: Sequence[int], in_axis=None, hidden_axis="ff",
             bias: bool = True) -> list:
    specs = []
    for i in range(len(dims) - 1):
        a_in = in_axis if i == 0 else hidden_axis
        a_out = hidden_axis if i < len(dims) - 2 else None
        specs.append(dense_spec(dims[i], dims[i + 1], a_in, a_out, bias))
    return specs


def mlp(p: list, x: jax.Array, act=jax.nn.relu,
        compute_dtype=jnp.bfloat16) -> jax.Array:
    for i, layer in enumerate(p):
        x = dense(layer, x, compute_dtype)
        if i < len(p) - 1:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_angles(head_dim: int, max_len: int, theta: float = 10_000.0,
                ) -> tuple[jax.Array, jax.Array]:
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                        dtype=jnp.float32) / head_dim))
    pos = jnp.arange(max_len, dtype=jnp.float32)
    ang = jnp.outer(pos, freqs)                       # (S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, n, head_dim); cos/sin: (S, head_dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[:, None, :].astype(x.dtype)
    sin = sin[:, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def apply_rope_at(x: jax.Array, cos: jax.Array, sin: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """Decode-time rope: positions (B,) for single-token queries
    x (B, 1, n, hd)."""
    c = cos[positions][:, None, None, :].astype(x.dtype)   # (B,1,1,hd/2)
    s = sin[positions][:, None, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def squared_relu(x: jax.Array) -> jax.Array:
    """Primer/nemotron activation."""
    r = jax.nn.relu(x)
    return r * r
