"""Transformer LM (dense + MoE) — training, prefill, and decode paths.

Layers are *stacked* (leading L axis) and iterated with ``lax.scan``: compile
time and HLO size are O(1) in depth — a 96-layer nemotron-340b lowers as fast
as a 2-layer smoke model.  Activation checkpointing wraps the scanned body
(``remat = none | dots | full``).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.parallel.sharding import shard


def _stack_specs(spec_tree: Any, n: int, axis_name: Optional[str] = None):
    """Prepend a stacked-layer dim to every ParamSpec in the tree."""
    return jax.tree_util.tree_map(
        lambda s: L.ParamSpec((n, *s.shape), (axis_name, *s.axes),
                              s.init, s.scale, s.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, L.ParamSpec))


def ffn_spec(cfg: LMConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    spec = {"w_out": L.ParamSpec((ff, d), ("ff", "fsdp"))}
    spec["w_in"] = L.ParamSpec((d, ff), ("fsdp", "ff"))
    if cfg.ffn == "swiglu":
        spec["w_gate"] = L.ParamSpec((d, ff), ("fsdp", "ff"))
    return spec


def dense_ffn(p: dict, x: jax.Array, cfg: LMConfig) -> jax.Array:
    dt = x.dtype
    h = x @ p["w_in"].astype(dt)
    if cfg.ffn == "swiglu":
        g = x @ p["w_gate"].astype(dt)
        h = jax.nn.silu(g) * h
    elif cfg.ffn == "squared_relu":
        h = L.squared_relu(h)
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "batch", None, "ff")
    return h @ p["w_out"].astype(dt)


def layer_spec(cfg: LMConfig) -> dict:
    spec = {
        "attn_norm": L.rmsnorm_spec(cfg.d_model),
        "attn": A.attention_spec(cfg),
        "ffn_norm": L.rmsnorm_spec(cfg.d_model),
    }
    spec["ffn"] = M.moe_spec(cfg) if cfg.moe else ffn_spec(cfg)
    return spec


def lm_spec(cfg: LMConfig) -> dict:
    spec = {
        "embed": L.ParamSpec((cfg.vocab_size, cfg.d_model),
                             ("vocab", "embed"), "embed", scale=0.02),
        "layers": _stack_specs(layer_spec(cfg), cfg.n_layers),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = L.ParamSpec((cfg.d_model, cfg.vocab_size),
                                      ("embed", "vocab"), "normal")
    return spec


def init(rng: jax.Array, cfg: LMConfig) -> dict:
    return L.init_params(rng, lm_spec(cfg))


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _layer_body(x: jax.Array, lp: dict, cos, sin, cfg: LMConfig,
                ) -> tuple[jax.Array, jax.Array]:
    """One transformer block. Returns (x, aux_loss)."""
    h = A.self_attention(lp["attn"], L.rmsnorm(lp["attn_norm"], x),
                         cos, sin, cfg)
    x = x + h
    y = L.rmsnorm(lp["ffn_norm"], x)
    if cfg.moe:
        b, s, d = y.shape
        out, aux = M.moe_ffn(lp["ffn"], y.reshape(b * s, d), cfg)
        out = out.reshape(b, s, d)
    else:
        out, aux = dense_ffn(lp["ffn"], y, cfg), jnp.zeros((), jnp.float32)
    x = x + out
    x = shard(x, "batch", None, None)
    return x, aux


def _remat_wrap(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def forward_features(params: dict, tokens: jax.Array, cfg: LMConfig):
    """tokens (B, S) → (final hidden states (B, S, d), moe aux loss)."""
    b, s = tokens.shape
    dt = jnp.bfloat16
    x = params["embed"][tokens].astype(dt)            # (B, S, d)
    x = shard(x, "batch", None, None)
    cos, sin = L.rope_angles(cfg.resolved_head_dim, s, cfg.rope_theta)

    body = _remat_wrap(
        lambda x, lp: _layer_body(x, lp, cos, sin, cfg), cfg)

    if cfg.scan_layers:
        def scan_fn(carry, lp):
            x, aux = carry
            x, a = body(x, lp)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda p, i=i: p[i], params["layers"])
            x, a = body(x, lp)
            aux = aux + a
    x = L.rmsnorm(params["final_norm"], x)
    return x, aux


def forward(params: dict, tokens: jax.Array, cfg: LMConfig) -> jax.Array:
    """tokens (B, S) → logits (B, S, V)."""
    x, aux = forward_features(params, tokens, cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        head.astype(jnp.float32))
    logits = shard(logits, "batch", None, "vocab")
    return logits, aux


def _ce_chunk(x_chunk: jax.Array, labels_chunk: jax.Array,
              mask_chunk, head: jax.Array) -> jax.Array:
    """Sum of token NLLs for one chunk.

    CE is ``logsumexp − masked-reduce(gold)`` rather than take_along_axis:
    with the vocab axis sharded, both terms are plain reductions that SPMD
    turns into per-shard partials + one psum — no (T, V) all-gather.
    """
    logits = x_chunk.astype(jnp.float32) @ head.astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(labels_chunk.dtype, logits.shape, 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == labels_chunk[:, None], logits, 0.0), axis=-1)
    nll = lse - gold
    if mask_chunk is not None:
        nll = nll * mask_chunk
    return jnp.sum(nll)


def loss_fn(params: dict, batch: dict, cfg: LMConfig) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy + MoE aux loss.

    The CE runs over token chunks under ``jax.checkpoint``: the full
    (tokens, vocab) logits tensor — the largest buffer of naive LM training
    — is never materialized (chunk logits are recomputed in the backward
    pass).  ``cfg.loss_chunk=None`` restores the single-pass form (used by
    the dry-run cost pass where loop bodies must be unrolled).
    """
    x, aux = forward_features(params, batch["tokens"], cfg)
    b, s, d = x.shape
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    xf = x.reshape(b * s, d)
    labels = batch["labels"].reshape(b * s)
    mask = batch.get("mask")
    mask_f = mask.reshape(b * s) if mask is not None else None

    chunk = cfg.loss_chunk
    if chunk is None or (b * s) <= chunk or (b * s) % chunk != 0:
        nll_sum = _ce_chunk(xf, labels, mask_f, head)
    else:
        n_chunks = (b * s) // chunk
        xc = xf.reshape(n_chunks, chunk, d)
        lc = labels.reshape(n_chunks, chunk)
        mc = (mask_f.reshape(n_chunks, chunk) if mask_f is not None
              else jnp.ones((n_chunks, 1), jnp.float32))
        use_mask = mask_f is not None
        ce_body = jax.checkpoint(
            lambda args: _ce_chunk(args[0], args[1],
                                   args[2] if use_mask else None, head))

        def scan_fn(acc, args):
            return acc + ce_body(args), None

        nll_sum, _ = jax.lax.scan(scan_fn, jnp.zeros((), jnp.float32),
                                  (xc, lc, mc))

    denom = (jnp.maximum(jnp.sum(mask_f), 1.0) if mask_f is not None
             else b * s)
    ce = nll_sum / denom
    aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    total = ce + aux_w * aux / max(cfg.n_layers, 1)
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(params: dict, tokens: jax.Array, cfg: LMConfig,
            cache_len: Optional[int] = None):
    """tokens (B, S) → (last-token logits (B, V), kv caches (L, B, S*, KV, hd)).

    ``cache_len`` pads the cache for subsequent decode steps.
    """
    b, s = tokens.shape
    s_cache = cache_len or s
    dt = jnp.bfloat16
    x = params["embed"][tokens].astype(dt)
    x = shard(x, "batch", None, None)
    cos, sin = L.rope_angles(cfg.resolved_head_dim, max(s, s_cache),
                             cfg.rope_theta)

    def scan_fn(x, lp):
        h, k, v = A.prefill_attention(
            lp["attn"], L.rmsnorm(lp["attn_norm"], x), cos, sin, cfg)
        x = x + h
        y = L.rmsnorm(lp["ffn_norm"], x)
        if cfg.moe:
            bb, ss, d = y.shape
            out, _ = M.moe_ffn(lp["ffn"], y.reshape(bb * ss, d), cfg)
            out = out.reshape(bb, ss, d)
        else:
            out = dense_ffn(lp["ffn"], y, cfg)
        x = x + out
        if s_cache > s:
            pad = [(0, 0), (0, s_cache - s), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (k, v)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(scan_fn, x, params["layers"])
    else:
        all_k, all_v = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda p, i=i: p[i], params["layers"])
            x, (k, v) = scan_fn(x, lp)
            all_k.append(k)
            all_v.append(v)
        ks, vs = jnp.stack(all_k), jnp.stack(all_v)
    x = L.rmsnorm(params["final_norm"], x[:, -1:])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x.astype(jnp.float32) @ head.astype(jnp.float32))[:, 0]
    return logits, (ks, vs)


def init_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> tuple[jax.Array, jax.Array]:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def cache_logical_axes() -> tuple[Optional[str], ...]:
    return (None, "batch", "kv_seq", "kv_heads", None)


def decode_step(params: dict, cache: tuple[jax.Array, jax.Array],
                tokens: jax.Array, pos: jax.Array, cfg: LMConfig):
    """One decode step: tokens (B,) new token ids at position ``pos``.

    Returns (logits (B, V), updated cache).  The layer loop is a scan over
    (params, cache) jointly.
    """
    ks, vs = cache
    b = tokens.shape[0]
    dt = jnp.bfloat16
    x = params["embed"][tokens][:, None, :].astype(dt)     # (B, 1, d)
    s_max = ks.shape[2]
    cos, sin = L.rope_angles(cfg.resolved_head_dim, s_max, cfg.rope_theta)

    def scan_fn(x, layer):
        lp, k_c, v_c = layer
        h, k_c, v_c = A.decode_attention(
            lp["attn"], L.rmsnorm(lp["attn_norm"], x), k_c, v_c, pos,
            cos, sin, cfg)
        x = x + h
        y = L.rmsnorm(lp["ffn_norm"], x)
        if cfg.moe:
            out, _ = M.moe_ffn(lp["ffn"], y.reshape(b, -1), cfg)
            out = out[:, None, :]
        else:
            out = dense_ffn(lp["ffn"], y, cfg)
        return x + out, (k_c, v_c)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(scan_fn, x, (params["layers"], ks, vs))
    else:
        new_k, new_v = [], []
        for i in range(cfg.n_layers):
            layer = jax.tree_util.tree_map(lambda p, i=i: p[i],
                                           (params["layers"], ks, vs))
            x, (k_c, v_c) = scan_fn(x, layer)
            new_k.append(k_c)
            new_v.append(v_c)
        ks, vs = jnp.stack(new_k), jnp.stack(new_v)
    x = L.rmsnorm(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x.astype(jnp.float32) @ head.astype(jnp.float32))[:, 0]
    return logits, (ks, vs)
