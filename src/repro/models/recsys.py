"""Recommendation models: two-tower retrieval, FM, DIN, DCN-v2.

JAX has no native ``nn.EmbeddingBag`` or CSR sparse ops, so the embedding
layer here is built from first principles (``jnp.take`` +
``jax.ops.segment_sum``) — this IS part of the system (assignment brief).
Tables are stored *fused* (one (Σ vocab_f, dim) matrix with per-field row
offsets, FBGEMM-style) and row-sharded over the "vocab" logical axis.

The paper's technique plugs in at the two-tower candidate index: the
``retrieval_cand`` shape scores one query against 10⁶ candidates through a
:class:`~repro.retrieval.index.CompressedIndex` (PCA+int8/1-bit), i.e. the
KB-compression pipeline applied verbatim to recsys retrieval.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DCNConfig, DINConfig, FMConfig, TwoTowerConfig
from repro.models import layers as L
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Row gather: (V, d) × (...,) int → (..., d)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
                  num_segments: int, mode: str = "sum",
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """Ragged multi-hot pooling: gather rows, segment-reduce per bag.

    ids, segment_ids: flat (nnz,) arrays; returns (num_segments, d).
    """
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments)
        n = jax.ops.segment_sum(jnp.ones_like(ids, rows.dtype), segment_ids,
                                num_segments)
        return s / jnp.maximum(n[:, None], 1.0)
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments)
    raise ValueError(mode)


def fused_field_lookup(table: jax.Array, ids: jax.Array,
                       vocab_per_field: int) -> jax.Array:
    """(B, F) per-field ids → (B, F, d) via a fused table with row offsets."""
    n_fields = ids.shape[-1]
    offsets = jnp.arange(n_fields, dtype=ids.dtype) * vocab_per_field
    return jnp.take(table, ids + offsets, axis=0)


# ---------------------------------------------------------------------------
# Two-tower retrieval (RecSys'19 YouTube-style)
# ---------------------------------------------------------------------------


def two_tower_spec(cfg: TwoTowerConfig) -> dict:
    d = cfg.embed_dim
    return {
        "user_table": L.ParamSpec((cfg.user_vocab, d), ("vocab", None),
                                  "embed", 0.02),
        "item_table": L.ParamSpec((cfg.item_vocab, d), ("vocab", None),
                                  "embed", 0.02),
        "user_tower": L.mlp_spec(
            (d * cfg.n_user_features, *cfg.tower_mlp), in_axis=None),
        "item_tower": L.mlp_spec(
            (d * cfg.n_item_features, *cfg.tower_mlp), in_axis=None),
    }


def _maybe_normalize(x: jax.Array, cfg: TwoTowerConfig) -> jax.Array:
    if not cfg.normalize:
        return x
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-9)


def user_embedding(params: dict, user_ids: jax.Array,
                   cfg: TwoTowerConfig) -> jax.Array:
    """(B, n_user_features) hashed ids → (B, d_out) tower output."""
    e = embedding_lookup(params["user_table"], user_ids)     # (B, F, d)
    e = e.reshape(e.shape[0], -1).astype(jnp.bfloat16)
    u = L.mlp(params["user_tower"], e, act=jax.nn.relu)
    return _maybe_normalize(u.astype(jnp.float32), cfg)


def item_embedding(params: dict, item_ids: jax.Array,
                   cfg: TwoTowerConfig) -> jax.Array:
    e = embedding_lookup(params["item_table"], item_ids)
    e = e.reshape(e.shape[0], -1).astype(jnp.bfloat16)
    v = L.mlp(params["item_tower"], e, act=jax.nn.relu)
    return _maybe_normalize(v.astype(jnp.float32), cfg)


def two_tower_loss(params: dict, batch: dict, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction (Yi et al. 2019)."""
    u = user_embedding(params, batch["user_ids"], cfg)       # (B, d)
    v = item_embedding(params, batch["item_ids"], cfg)       # (B, d)
    u = shard(u, "batch", None)
    logits = (u @ v.T) / cfg.temperature                     # (B, B)
    logq = batch.get("log_q")                                # (B,) sampling
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(logits.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(logp[labels, labels])
    return loss, {"softmax_ce": loss}


def two_tower_score(params: dict, batch: dict, cfg: TwoTowerConfig):
    """Serving: per-(user, item) dot scores (B,)."""
    u = user_embedding(params, batch["user_ids"], cfg)
    v = item_embedding(params, batch["item_ids"], cfg)
    return jnp.sum(u * v, axis=-1)


def retrieval_scores(params: dict, batch: dict, cfg: TwoTowerConfig):
    """Retrieval: (B_q, F) users × (N_cand, F) candidates → (B_q, N_cand).

    Batched GEMM over the full candidate set — never a loop.  In production
    the candidate embeddings are precomputed, compressed
    (repro.core) and sharded (repro.retrieval.sharded); this path is the
    uncompressed oracle used to *build* that index.
    """
    u = user_embedding(params, batch["user_ids"], cfg)
    v = item_embedding(params, batch["cand_ids"], cfg)
    v = shard(v, "kb_docs", None)
    return u @ v.T


# ---------------------------------------------------------------------------
# Candidate scoring (retrieval_cand shape) for the ranking models:
# one fixed user/context scored against N candidate items — batched, never a
# loop.  For FM the decomposition makes this a gather + GEMV; DIN/DCN run
# their full interaction per candidate (that is the model's serving cost).
# ---------------------------------------------------------------------------


def fm_candidate_scores(params: dict, batch: dict, cfg: FMConfig):
    """batch: context_ids (1, F−1) fixed fields; cand_ids (N,) item field.

    FM scores decompose: score(ctx, item) = const(ctx) + w_item +
    ⟨Σ_f v_ctx[f], v_item⟩ — O(N·k)."""
    ctx = batch["context_ids"]                              # (1, F-1)
    cand = batch["cand_ids"]                                # (N,)
    v_ctx = fused_field_lookup(params["v"], ctx,
                               cfg.vocab_per_field)[0]      # (F-1, k)
    sum_ctx = jnp.sum(v_ctx, axis=0)                        # (k,)
    # candidate field is the last field: offset rows accordingly
    off = (cfg.n_sparse - 1) * cfg.vocab_per_field
    v_item = embedding_lookup(params["v"], cand + off)      # (N, k)
    w_item = embedding_lookup(params["w_lin"], cand + off)[:, 0]
    const = (params["w0"][0]
             + jnp.sum(fused_field_lookup(params["w_lin"], ctx,
                                          cfg.vocab_per_field)[0])
             + 0.5 * (jnp.sum(sum_ctx * sum_ctx)
                      - jnp.sum(v_ctx * v_ctx)))
    return const + w_item + v_item @ sum_ctx


def din_candidate_scores(params: dict, batch: dict, cfg: DINConfig):
    """batch: history_ids (1, S), context_ids (1, F), cand_ids (N,)."""
    n = batch["cand_ids"].shape[0]
    big = {
        "target_ids": batch["cand_ids"],
        "history_ids": jnp.broadcast_to(batch["history_ids"],
                                        (n, cfg.seq_len)),
        "context_ids": jnp.broadcast_to(
            batch["context_ids"], (n, cfg.n_context_features)),
    }
    return din_logits(params, big, cfg)


def dcn_candidate_scores(params: dict, batch: dict, cfg: DCNConfig):
    """batch: dense (1, n_dense), sparse_ids (1, n_sparse−1), cand_ids (N,)."""
    n = batch["cand_ids"].shape[0]
    sparse = jnp.concatenate(
        [jnp.broadcast_to(batch["sparse_ids"], (n, cfg.n_sparse - 1)),
         batch["cand_ids"][:, None]], axis=-1)
    big = {"dense": jnp.broadcast_to(batch["dense"], (n, cfg.n_dense)),
           "sparse_ids": sparse}
    return dcn_logits(params, big, cfg)


# ---------------------------------------------------------------------------
# Factorization Machine (Rendle, ICDM'10)
# ---------------------------------------------------------------------------


def fm_spec(cfg: FMConfig) -> dict:
    v_total = cfg.n_sparse * cfg.vocab_per_field
    return {
        "w0": L.ParamSpec((1,), (None,), "zeros"),
        "w_lin": L.ParamSpec((v_total, 1), ("vocab", None), "embed", 0.01),
        "v": L.ParamSpec((v_total, cfg.embed_dim), ("vocab", None),
                         "embed", 0.02),
    }


def fm_logits(params: dict, batch: dict, cfg: FMConfig) -> jax.Array:
    """O(n·k) pairwise interactions via the sum-square trick."""
    ids = batch["sparse_ids"]                              # (B, F)
    lin = fused_field_lookup(params["w_lin"], ids,
                             cfg.vocab_per_field)[..., 0]  # (B, F)
    v = fused_field_lookup(params["v"], ids, cfg.vocab_per_field)  # (B,F,k)
    sum_v = jnp.sum(v, axis=1)                             # (B, k)
    sum_sq = jnp.sum(v * v, axis=1)                        # (B, k)
    pair = 0.5 * jnp.sum(sum_v * sum_v - sum_sq, axis=-1)  # (B,)
    return params["w0"][0] + jnp.sum(lin, axis=-1) + pair


def bce_loss(logits: jax.Array, labels: jax.Array):
    ls = jax.nn.log_sigmoid(logits)
    lns = jax.nn.log_sigmoid(-logits)
    loss = -jnp.mean(labels * ls + (1 - labels) * lns)
    return loss, {"bce": loss}


def fm_loss(params: dict, batch: dict, cfg: FMConfig):
    return bce_loss(fm_logits(params, batch, cfg), batch["labels"])


# ---------------------------------------------------------------------------
# DIN (Deep Interest Network, arXiv:1706.06978)
# ---------------------------------------------------------------------------


def din_spec(cfg: DINConfig) -> dict:
    d = cfg.embed_dim
    ctx_total = cfg.n_context_features * cfg.context_vocab
    return {
        "item_table": L.ParamSpec((cfg.item_vocab, d), ("vocab", None),
                                  "embed", 0.02),
        "context_table": L.ParamSpec((ctx_total, d), ("vocab", None),
                                     "embed", 0.02),
        # attention MLP over [hist, target, hist−target, hist⊙target]
        "attn_mlp": L.mlp_spec((4 * d, *cfg.attn_mlp, 1), in_axis=None),
        "mlp": L.mlp_spec(
            (2 * d + cfg.n_context_features * d, *cfg.mlp, 1), in_axis=None),
    }


def din_logits(params: dict, batch: dict, cfg: DINConfig) -> jax.Array:
    dt = jnp.bfloat16
    target = embedding_lookup(params["item_table"],
                              batch["target_ids"]).astype(dt)   # (B, d)
    hist = embedding_lookup(params["item_table"],
                            batch["history_ids"]).astype(dt)    # (B, S, d)
    hist_mask = batch.get("history_mask")
    t = jnp.broadcast_to(target[:, None, :], hist.shape)
    feats = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = L.mlp(params["attn_mlp"], feats, act=jax.nn.sigmoid)[..., 0]  # (B,S)
    if hist_mask is not None:
        w = w * hist_mask.astype(dt)
    interest = jnp.einsum("bs,bsd->bd", w, hist)                # (B, d)
    ctx = embedding_lookup(params["context_table"],
                           batch["context_ids"]).astype(dt)     # (B, F, d)
    z = jnp.concatenate([interest, target,
                         ctx.reshape(ctx.shape[0], -1)], axis=-1)
    return L.mlp(params["mlp"], z, act=jax.nn.relu)[..., 0].astype(jnp.float32)


def din_loss(params: dict, batch: dict, cfg: DINConfig):
    return bce_loss(din_logits(params, batch, cfg), batch["labels"])


# ---------------------------------------------------------------------------
# DCN-v2 (arXiv:2008.13535)
# ---------------------------------------------------------------------------


def dcn_spec(cfg: DCNConfig) -> dict:
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    v_total = cfg.n_sparse * cfg.vocab_per_field
    return {
        "table": L.ParamSpec((v_total, cfg.embed_dim), ("vocab", None),
                             "embed", 0.02),
        "cross": [
            {"w": L.ParamSpec((d0, d0), (None, "ff")),
             "b": L.ParamSpec((d0,), (None,), "zeros")}
            for _ in range(cfg.n_cross_layers)
        ],
        "mlp": L.mlp_spec((d0, *cfg.mlp, 1), in_axis=None),
    }


def dcn_logits(params: dict, batch: dict, cfg: DCNConfig) -> jax.Array:
    dt = jnp.bfloat16
    emb = fused_field_lookup(params["table"], batch["sparse_ids"],
                             cfg.vocab_per_field)               # (B, F, d)
    x0 = jnp.concatenate(
        [batch["dense"].astype(dt), emb.reshape(emb.shape[0], -1).astype(dt)],
        axis=-1)                                                # (B, d0)
    x0 = shard(x0, "batch", None)
    x = x0
    for layer in params["cross"]:
        xw = x @ layer["w"].astype(dt) + layer["b"].astype(dt)
        x = x0 * xw + x                                         # cross-v2
    return L.mlp(params["mlp"], x, act=jax.nn.relu)[..., 0].astype(jnp.float32)


def dcn_loss(params: dict, batch: dict, cfg: DCNConfig):
    return bce_loss(dcn_logits(params, batch, cfg), batch["labels"])
