"""List stores: the tier boundary between an IVF index and its bytes.

An :class:`~repro.retrieval.ivf.IVFIndex` that owns a ``store`` no
longer requires its encoded inverted lists to be resident — the search
path asks the store for each probed list and the store decides what
lives in RAM:

* :class:`ResidentStore` — every list in host memory (today's behaviour:
  results are unchanged; exists so the store-backed search path can be
  validated against an always-hot tier and so tests exercise the
  protocol without an artifact on disk).
* :class:`MmapStore` — a byte-budgeted hot tier over a
  :class:`~repro.storage.format.ChunkReader` memmap.  Recently probed
  lists are promoted into an LRU of materialised host arrays; admission
  is probe-frequency aware (a list enters the hot tier on its second
  touch, so one-off cold scans cannot flush the Zipf head); pinned lists
  (delta-routing targets, anything the caller declares hot) never
  evict; and hit/miss/eviction/bytes-resident counters feed
  ``RetrievalService.stats()``.

Correctness contract: a store only changes *where* list bytes come
from, never *what* they are — searches through any store at any budget
are bit-identical to the fully-resident index (asserted per backend in
``tests/test_storage.py`` and at every budget by
``benchmarks/tiered_bench.py --quick``).

The router (centroids) and any delta segments layered above
(:class:`~repro.retrieval.segments.SegmentedIndex`) are *structurally*
resident — they live on the index object itself, not in the store — so
routing and live updates never take a cold-tier miss.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Iterable, Protocol, runtime_checkable

import numpy as np

from repro.storage.format import ChunkReader


@runtime_checkable
class ListStore(Protocol):
    """What the IVF search path needs from a list-storage tier."""

    n_lists: int
    max_len: int
    encoded_nbytes: int

    def get(self, list_id: int) -> tuple[np.ndarray, np.ndarray]:
        """One inverted list → ``(rows (n, w), ids (n,))`` host arrays."""
        ...

    def prefetch(self, list_ids: Iterable[int]) -> int:
        """Warm the hot tier for the given lists; returns lists touched."""
        ...

    def iter_lists(self):
        """Yield ``(list_id, rows, ids)`` for every list in id order,
        without perturbing hot-tier state — the save/compact walk."""
        ...

    def pin(self, list_ids: Iterable[int]) -> None: ...

    def unpin(self, list_ids: Iterable[int]) -> None: ...

    @property
    def fully_resident(self) -> bool: ...

    def stats(self) -> dict: ...


class ResidentStore:
    """Every list materialised in host memory — the always-hot tier."""

    def __init__(self, lists_rows: list[np.ndarray],
                 lists_ids: list[np.ndarray]):
        if len(lists_rows) != len(lists_ids):
            raise ValueError("rows/ids list count mismatch")
        self._rows = [np.ascontiguousarray(r) for r in lists_rows]
        self._ids = [np.ascontiguousarray(i, dtype=np.int32)
                     for i in lists_ids]
        if not self._rows:
            raise ValueError("ResidentStore needs at least one list")
        self.n_lists = len(self._rows)
        self.max_len = max((len(i) for i in self._ids), default=0)
        self.encoded_nbytes = sum(int(r.nbytes) for r in self._rows)
        self.storage_dtype = self._rows[0].dtype
        self.storage_width = int(self._rows[0].shape[1])
        self.hits = 0

    @classmethod
    def from_padded(cls, storage: np.ndarray, lists: np.ndarray
                    ) -> "ResidentStore":
        """Build from the resident layout: row-major ``storage`` plus the
        (nlist, max_len) −1-padded list table."""
        storage = np.asarray(storage)
        lists = np.asarray(lists)
        rows, ids = [], []
        for row in lists:
            members = row[row >= 0].astype(np.int32)
            rows.append(storage[members])
            ids.append(members)
        return cls(rows, ids)

    def get(self, list_id: int) -> tuple[np.ndarray, np.ndarray]:
        self.hits += 1
        return self._rows[list_id], self._ids[list_id]

    def prefetch(self, list_ids: Iterable[int]) -> int:
        return len(tuple(list_ids))          # already hot

    def iter_lists(self):
        for lid, (rows, ids) in enumerate(zip(self._rows, self._ids)):
            yield lid, rows, ids

    def pin(self, list_ids: Iterable[int]) -> None:
        pass                                 # everything is pinned

    def unpin(self, list_ids: Iterable[int]) -> None:
        pass

    @property
    def fully_resident(self) -> bool:
        return True

    def stats(self) -> dict:
        return {"kind": "resident", "n_lists": self.n_lists,
                "resident_lists": self.n_lists, "pinned_lists": 0,
                "bytes_resident": self.encoded_nbytes,
                "budget_bytes": self.encoded_nbytes,
                "encoded_nbytes": self.encoded_nbytes,
                "hits": self.hits, "misses": 0, "evictions": 0,
                "hit_rate": 1.0 if self.hits else 0.0,
                "fully_resident": True}


class MmapStore:
    """Byte-budgeted hot tier over a memory-mapped chunked artifact.

    ``budget_bytes`` bounds the *hot tier* (materialised host copies of
    encoded list rows); the mmap itself is the OS's problem and costs no
    anonymous memory.  Admission is frequency-aware: a list is promoted
    once it has been touched ``admit_after`` times (default 2 — the
    first touch serves straight from the map, so a one-shot cold scan
    never evicts the working set), or immediately when prefetched or
    pinned.  Eviction is LRU among unpinned lists.  Each chunk's CRC-32
    is verified on its first read from the map, never again for that
    list.
    """

    def __init__(self, reader: ChunkReader, budget_bytes: int, *,
                 admit_after: int = 2):
        if budget_bytes < 0:
            raise ValueError("budget_bytes must be ≥ 0")
        self.reader = reader
        self.budget_bytes = int(budget_bytes)
        self.admit_after = max(1, int(admit_after))
        self.n_lists = reader.n_lists
        self.max_len = reader.max_len
        self.encoded_nbytes = reader.encoded_nbytes
        self.storage_dtype = reader.storage_dtype
        self.storage_width = reader.storage_width
        self._hot: "OrderedDict[int, tuple[np.ndarray, np.ndarray]]" = \
            OrderedDict()
        self._pinned: set[int] = set()
        self._touches = np.zeros(reader.n_lists, np.int64)
        self._verified = np.zeros(reader.n_lists, bool)
        self.bytes_resident = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_read = 0

    # -- internals ---------------------------------------------------------
    def _read(self, list_id: int) -> tuple[np.ndarray, np.ndarray]:
        rows, ids = self.reader.read_list(
            list_id, verify=not self._verified[list_id])
        self._verified[list_id] = True
        self.bytes_read += int(rows.nbytes) + int(ids.nbytes)
        return rows, ids

    def _admit(self, list_id: int, rows: np.ndarray,
               ids: np.ndarray) -> None:
        nbytes = int(rows.nbytes)
        if list_id not in self._pinned and nbytes > self.budget_bytes:
            return                      # one list larger than the whole tier
        # copy out of the map: a hot entry must not keep a page pinned
        self._hot[list_id] = (np.array(rows), np.array(ids))
        self._hot.move_to_end(list_id)
        self.bytes_resident += nbytes
        self._evict_to_budget()

    def _evict_to_budget(self) -> None:
        while self.bytes_resident > self.budget_bytes:
            victim = next((lid for lid in self._hot
                           if lid not in self._pinned), None)
            if victim is None:
                return                  # only pinned lists remain
            rows, _ = self._hot.pop(victim)
            self.bytes_resident -= int(rows.nbytes)
            self.evictions += 1

    # -- ListStore protocol ------------------------------------------------
    def get(self, list_id: int) -> tuple[np.ndarray, np.ndarray]:
        entry = self._hot.get(list_id)
        if entry is not None:
            self._hot.move_to_end(list_id)
            self.hits += 1
            return entry
        self.misses += 1
        self._touches[list_id] += 1
        rows, ids = self._read(list_id)
        if list_id in self._pinned or \
                self._touches[list_id] >= self.admit_after:
            self._admit(list_id, rows, ids)
        return rows, ids

    def prefetch(self, list_ids: Iterable[int]) -> int:
        """Promote the given lists ahead of scoring (the ``prefetch``
        hook: the router's probe table warms the tier before the search
        path asks for bytes)."""
        n = 0
        for lid in list_ids:
            lid = int(lid)
            self._touches[lid] += 1
            if lid not in self._hot:
                self._admit(lid, *self._read(lid))
            n += 1
        return n

    def iter_lists(self):
        """Walk every list straight off the map (hot tier untouched, no
        counter churn) — verifying each unverified chunk's CRC once."""
        for lid in range(self.n_lists):
            rows, ids = self.reader.read_list(
                lid, verify=not self._verified[lid])
            self._verified[lid] = True
            yield lid, rows, ids

    def pin(self, list_ids: Iterable[int]) -> None:
        """Make lists unevictable (and resident now) — e.g. the routing
        targets of live delta segments."""
        for lid in list_ids:
            lid = int(lid)
            self._pinned.add(lid)
            if lid not in self._hot:
                self._admit(lid, *self._read(lid))

    def unpin(self, list_ids: Iterable[int]) -> None:
        for lid in list_ids:
            self._pinned.discard(int(lid))
        self._evict_to_budget()

    @property
    def fully_resident(self) -> bool:
        return len(self._hot) == self.n_lists

    def stats(self) -> dict:
        touched = self.hits + self.misses
        return {"kind": "mmap", "n_lists": self.n_lists,
                "resident_lists": len(self._hot),
                "pinned_lists": len(self._pinned),
                "bytes_resident": self.bytes_resident,
                "budget_bytes": self.budget_bytes,
                "encoded_nbytes": self.encoded_nbytes,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "bytes_read": self.bytes_read,
                "hit_rate": (self.hits / touched) if touched else 0.0,
                "fully_resident": self.fully_resident}
