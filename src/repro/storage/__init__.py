"""Tiered index storage: chunked artifacts + byte-budgeted list stores.

The paper's 100× index compression only pays off in production once the
compressed artifact no longer has to live fully resident: this package
lets an IVF index serve from disk with a byte-budgeted hot tier.

* :mod:`repro.storage.format` — the chunked (v3) artifact layout:
  per-inverted-list chunks with a JSON manifest (offsets, lengths,
  CRC-32 per list), streamed to disk list-by-list and read back through
  one ``np.memmap``.
* :mod:`repro.storage.store` — the :class:`ListStore` tier protocol
  with :class:`ResidentStore` (always hot, unchanged results) and
  :class:`MmapStore` (LRU hot tier, frequency-aware admission, pinning,
  hit/miss/eviction counters).

Front door: ``save_index(index, path, chunked=True)`` writes the v3
layout and ``load_index(path, resident="auto"|"all"|budget_bytes)``
decides residency (see :mod:`repro.retrieval.api`).
"""

from repro.storage.format import (ArtifactError, ChunkReader, ChunkWriter,
                                  is_chunked_artifact, npz_member_nbytes)
from repro.storage.store import ListStore, MmapStore, ResidentStore

__all__ = [
    "ArtifactError", "ChunkReader", "ChunkWriter", "is_chunked_artifact",
    "npz_member_nbytes",
    "ListStore", "MmapStore", "ResidentStore",
]
