"""Chunked (v3) artifact layout: per-IVF-list chunks + JSON manifest.

The v1/v2 ``.npz`` artifact is monolithic: ``load_index`` materialises
every array, so the host must hold the whole encoded storage even when
Zipf-skewed traffic only ever touches a hot subset of the inverted
lists.  The v3 layout makes each inverted list independently
addressable so the cold tail can stay on disk:

    kb_v3/                     (one directory per artifact)
      manifest.json            identity header + per-list chunk table
      chunks.bin               per-list [storage rows | ids], 64-B aligned
      aux.npz                  everything always-resident: pipeline state,
                               router centroids, delta segments, drift

``manifest.json`` carries the same ``meta`` dict a v2 artifact embeds in
``__meta__`` plus a chunk table ``[offset, storage_nbytes, ids_nbytes,
n_rows, crc32]`` per list.  Chunks are written list-by-list
(:class:`ChunkWriter` — peak save RSS stays O(largest list), never
O(corpus)) and read back through one ``np.memmap`` per artifact
(:class:`ChunkReader` — a list read is a slice of the map, not a file
materialisation).  Every chunk carries a CRC-32; a corrupted list fails
loudly with :class:`ArtifactError` naming the list id instead of
returning silently wrong rankings.
"""

from __future__ import annotations

import json
import os
import zipfile
import zlib
from typing import Iterator, Optional

import numpy as np

#: chunk offsets are aligned so a mapped list starts on a cache-line
#: boundary (cheap: ≤ 63 pad bytes per list)
CHUNK_ALIGN = 64

MANIFEST_NAME = "manifest.json"
CHUNKS_NAME = "chunks.bin"
AUX_NAME = "aux.npz"


class ArtifactError(RuntimeError):
    """A saved artifact is structurally broken (missing member, bad
    checksum, truncated chunk) — as opposed to merely unknown/newer."""


def is_chunked_artifact(path: str) -> bool:
    """Is ``path`` a v3 chunked-artifact directory?"""
    return os.path.isdir(path) and \
        os.path.isfile(os.path.join(path, MANIFEST_NAME))


def npz_member_nbytes(path: str) -> dict[str, int]:
    """{member name: array nbytes} for an ``.npz`` without reading data.

    Parses only each member's ``.npy`` header (dtype + shape) through the
    zip directory, so meta queries on a multi-GB artifact stay O(headers).
    """
    out: dict[str, int] = {}
    with zipfile.ZipFile(path) as zf:
        for info in zf.infolist():
            name = info.filename
            if not name.endswith(".npy"):
                continue
            with zf.open(info) as f:
                version = np.lib.format.read_magic(f)
                # savez writes 1.0 headers; 2.0/3.0 share one layout
                read = (np.lib.format.read_array_header_1_0
                        if version[0] == 1
                        else np.lib.format.read_array_header_2_0)
                shape, _, dtype = read(f)
            out[name[:-len(".npy")]] = \
                int(np.prod(shape, dtype=np.int64)) * int(dtype.itemsize)
    return out


def _align(n: int, align: int = CHUNK_ALIGN) -> int:
    return -(-n // align) * align


class ChunkWriter:
    """Stream per-list chunks to ``chunks.bin``, one list at a time.

    Usage::

        w = ChunkWriter(path, storage_dtype=..., storage_width=...)
        for rows, ids in per_list_rows():     # any order-stable iterator
            w.write_list(rows, ids)
        w.finish(meta, aux_arrays)            # aux.npz + manifest.json

    Nothing larger than one list's rows is ever held for the chunk
    member; ``aux_arrays`` (pipeline state, centroids, segments) are the
    small always-resident side and go through ``np.savez``.
    """

    def __init__(self, path: str, *, storage_dtype, storage_width: int,
                 ids_dtype=np.int32, align: int = CHUNK_ALIGN):
        self.path = path
        self.storage_dtype = np.dtype(storage_dtype)
        self.storage_width = int(storage_width)
        self.ids_dtype = np.dtype(ids_dtype)
        self.align = int(align)
        self.chunks: list[dict] = []
        os.makedirs(path, exist_ok=True)
        self._f = open(os.path.join(path, CHUNKS_NAME), "wb")
        self._pos = 0
        self._finished = False

    def write_list(self, rows: np.ndarray, ids: np.ndarray) -> None:
        """Append one inverted list: (n, w) encoded rows + (n,) doc ids."""
        rows = np.ascontiguousarray(rows, dtype=self.storage_dtype)
        ids = np.ascontiguousarray(ids, dtype=self.ids_dtype)
        if rows.ndim != 2 or rows.shape[1] != self.storage_width:
            raise ValueError(f"list rows must be (n, {self.storage_width}), "
                             f"got {rows.shape}")
        if ids.shape != (rows.shape[0],):
            raise ValueError(f"ids must be ({rows.shape[0]},), "
                             f"got {ids.shape}")
        offset = _align(self._pos, self.align)
        if offset != self._pos:
            self._f.write(b"\0" * (offset - self._pos))
        stor_b = rows.tobytes()
        ids_b = ids.tobytes()
        crc = zlib.crc32(ids_b, zlib.crc32(stor_b))
        self._f.write(stor_b)
        self._f.write(ids_b)
        self._pos = offset + len(stor_b) + len(ids_b)
        self.chunks.append({"offset": offset,
                            "storage_nbytes": len(stor_b),
                            "ids_nbytes": len(ids_b),
                            "n_rows": int(rows.shape[0]),
                            "crc32": crc})

    def finish(self, meta: dict, aux_arrays: dict) -> dict:
        """Write ``aux.npz`` + ``manifest.json``; returns the manifest."""
        if self._finished:
            raise RuntimeError("ChunkWriter.finish called twice")
        self._f.close()
        self._finished = True
        aux_path = os.path.join(self.path, AUX_NAME)
        np.savez(aux_path, **{k: np.asarray(v)
                              for k, v in aux_arrays.items()})
        manifest = {
            "format": meta.get("format", "repro-index"),
            "format_version": meta.get("format_version", 3),
            "meta": meta,
            "storage_dtype": self.storage_dtype.str,
            "storage_width": self.storage_width,
            "ids_dtype": self.ids_dtype.str,
            "align": self.align,
            "n_lists": len(self.chunks),
            "max_len": max((c["n_rows"] for c in self.chunks), default=0),
            "encoded_nbytes": sum(c["storage_nbytes"] for c in self.chunks),
            "ids_nbytes": sum(c["ids_nbytes"] for c in self.chunks),
            "aux_nbytes": sum(npz_member_nbytes(aux_path).values()),
            "chunks": [[c["offset"], c["storage_nbytes"], c["ids_nbytes"],
                        c["n_rows"], c["crc32"]] for c in self.chunks],
        }
        with open(os.path.join(self.path, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, sort_keys=True)
            f.write("\n")
        return manifest


class ChunkReader:
    """Memory-mapped view over a v3 artifact's per-list chunks.

    ``read_list`` returns zero-copy views into the map (the caller copies
    on admission to a hot tier); ``verify=True`` checks the chunk's
    CRC-32 and raises :class:`ArtifactError` naming the list id on
    mismatch.  The manifest is parsed eagerly (it is the identity
    header); the map itself is opened lazily on the first list read.
    """

    def __init__(self, path: str):
        self.path = path
        mpath = os.path.join(path, MANIFEST_NAME)
        if not os.path.isfile(mpath):
            raise ArtifactError(f"{path}: no {MANIFEST_NAME} — not a "
                                "chunked artifact directory")
        with open(mpath) as f:
            self.manifest = json.load(f)
        self.meta = self.manifest["meta"]
        self.storage_dtype = np.dtype(self.manifest["storage_dtype"])
        self.storage_width = int(self.manifest["storage_width"])
        self.ids_dtype = np.dtype(self.manifest["ids_dtype"])
        self.n_lists = int(self.manifest["n_lists"])
        self.max_len = int(self.manifest["max_len"])
        self.encoded_nbytes = int(self.manifest["encoded_nbytes"])
        self.aux_nbytes = int(self.manifest["aux_nbytes"])
        self.chunks = [tuple(c) for c in self.manifest["chunks"]]
        self._mm: Optional[np.memmap] = None

    def _map(self) -> np.ndarray:
        if self._mm is None:
            cpath = os.path.join(self.path, CHUNKS_NAME)
            if not os.path.isfile(cpath):
                raise ArtifactError(f"{self.path}: missing {CHUNKS_NAME}")
            size = os.path.getsize(cpath)
            need = max((off + sb + ib for off, sb, ib, _, _ in self.chunks),
                       default=0)
            if size < need:
                raise ArtifactError(
                    f"{self.path}: {CHUNKS_NAME} truncated "
                    f"({size} bytes < {need} in manifest)")
            self._mm = (np.memmap(cpath, dtype=np.uint8, mode="r")
                        if size else np.zeros(0, np.uint8))
        return self._mm

    def list_nbytes(self, list_id: int) -> int:
        """Encoded storage bytes of one list (ids excluded — they are the
        same ids a resident index keeps in its padded list table)."""
        return self.chunks[list_id][1]

    def read_list(self, list_id: int, verify: bool = True
                  ) -> tuple[np.ndarray, np.ndarray]:
        """One inverted list → ``(rows (n, w), ids (n,))`` mmap views."""
        if not 0 <= list_id < self.n_lists:
            raise IndexError(f"list id {list_id} out of range "
                             f"[0, {self.n_lists})")
        off, stor_b, ids_b, n_rows, crc = self.chunks[list_id]
        mm = self._map()
        raw = mm[off: off + stor_b + ids_b]
        if verify and zlib.crc32(raw.tobytes()) != crc:
            raise ArtifactError(
                f"{self.path}: checksum mismatch on inverted list "
                f"{list_id} (chunk at offset {off}, {stor_b + ids_b} "
                "bytes) — artifact is corrupt, rebuild or restore it")
        rows = np.frombuffer(raw[:stor_b], dtype=self.storage_dtype) \
            .reshape(n_rows, self.storage_width)
        ids = np.frombuffer(raw[stor_b:], dtype=self.ids_dtype)
        return rows, ids

    def iter_lists(self, verify: bool = True
                   ) -> Iterator[tuple[int, np.ndarray, np.ndarray]]:
        for lid in range(self.n_lists):
            rows, ids = self.read_list(lid, verify=verify)
            yield lid, rows, ids

    def load_aux(self):
        """The always-resident side (``np.load`` handle over aux.npz)."""
        apath = os.path.join(self.path, AUX_NAME)
        if not os.path.isfile(apath):
            raise ArtifactError(f"{self.path}: missing {AUX_NAME}")
        return np.load(apath, allow_pickle=False)

    def close(self) -> None:
        self._mm = None
