"""OPQ-style learned rotation before extreme quantization.

1-bit (sign) quantization keeps only the orthant of each vector: its error
depends entirely on how the data sits relative to the coordinate axes.  An
*orthogonal* rotation R is free at search time — R Rᵀ = I means
q·x = (qR)·(xR), so rotating docs and queries together preserves every
inner product exactly — but it re-aims the sign grid at the data.
Following OPQ (Ge et al., CVPR 2013), R is learned by alternating
minimisation of the quantization error ‖XR − Q(XR)‖²:

    1. B ← Q(XR)                 (quantize under the current rotation)
    2. R ← U Vᵀ,  U Σ Vᵀ = XᵀB  (orthogonal Procrustes solution)

Placed between PCA and the 1-bit quantizer (``pca_rot_onebit`` in the
method registry) it recovers a large part of the recall the sign grid
loses after PCA concentrates variance on few axes.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.preprocess import Transform


def _sign_targets(z: jax.Array, offset: float) -> jax.Array:
    """Q(z) for the offset-α 1-bit codebook: values in {−α, 1 − α} scaled
    to the codebook's reconstruction levels (±0.5 for the paper's α=0.5)."""
    return jnp.where(z >= 0.0, 1.0 - offset, -offset)


class LearnedRotation(Transform):
    """Learn an orthogonal rotation minimising 1-bit quantization error.

    Applied identically to docs and queries (the two-population convention
    is deliberately ignored: a per-population rotation would break the
    q·x = (qR)·(xR) identity the float path relies on).
    """

    name = "learned_rotation"
    state_keys = ("rotation",)

    def __init__(self, n_iters: int = 10, offset: float = 0.5,
                 max_fit_samples: Optional[int] = 65536):
        super().__init__()
        self.n_iters = int(n_iters)
        self.offset = float(offset)
        self.max_fit_samples = max_fit_samples

    def init_config(self):
        return {"n_iters": self.n_iters, "offset": self.offset,
                "max_fit_samples": self.max_fit_samples}

    def fit(self, docs, queries=None, rng=None):
        x = jnp.asarray(docs, jnp.float32)
        if self.max_fit_samples is not None and \
                x.shape[0] > self.max_fit_samples:
            if rng is None:
                rng = jax.random.PRNGKey(0)
            idx = jax.random.choice(rng, x.shape[0],
                                    (self.max_fit_samples,), replace=False)
            x = x[idx]
        d = x.shape[-1]
        r = jnp.eye(d, dtype=jnp.float32)
        for _ in range(self.n_iters):
            b = _sign_targets(x @ r, self.offset)
            u, _, vt = jnp.linalg.svd(x.T @ b, full_matrices=False)
            r = u @ vt
        self.state = {"rotation": r}
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        return x @ self.state["rotation"]

    def inverse(self, z: jax.Array) -> jax.Array:
        return z @ self.state["rotation"].T

    def output_dim(self, input_dim: int) -> int:
        return input_dim
