"""Distance-preserving and contrastive dimension reduction (paper §5.4).

The paper's negative results, implemented for completeness and ablation:

* **Similarity learning** — fit f minimizing
  ``MSE(sim(f(tᵢ), f(tⱼ)), sim(tᵢ, tⱼ))`` over sampled pairs, where f is a
  linear projection (or small MLP).  The optimization goal matches retrieval
  better than reconstruction loss, but the paper found it slow and
  under-performing (between sparse projection and PCA) — which our
  reproduction confirms (benchmarks/table2_compression.py --extras).

* **Contrastive learning** — InfoNCE with nearest neighbours in the original
  space as positives and distant points as negatives.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.preprocess import Transform
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class DistanceLearnerConfig:
    dim: int = 128
    sim: str = "ip"           # ip | l2
    lr: float = 1e-3
    batch_size: int = 256
    steps: int = 2000
    hidden: int = 0           # 0 → linear projection; else 1 hidden layer
    seed: int = 0


class SimilarityPreservingProjection(Transform):
    """Learn f with MSE(sim(f(x), f(y)), sim(x, y)) on random pairs."""

    name = "distance_learning"

    state_keys = ("w1", "b1")

    def __init__(self, config: DistanceLearnerConfig | None = None, **kw):
        super().__init__()
        self.config = config or DistanceLearnerConfig(**kw)
        self.params = None

    def init_config(self):
        return dataclasses.asdict(self.config)

    def load_state(self, sd):
        super().load_state(sd)
        self.params = dict(self.state) if self.fitted else None
        return self

    def _apply(self, params, x):
        if "w2" in params:
            h = jnp.tanh(x @ params["w1"] + params["b1"])
            return h @ params["w2"] + params["b2"]
        return x @ params["w1"] + params["b1"]

    def _sim(self, a, b):
        if self.config.sim == "ip":
            return jnp.einsum("id,jd->ij", a, b)
        d2 = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :]
              - 2 * jnp.einsum("id,jd->ij", a, b))
        return -d2

    def fit(self, docs, queries=None, rng=None):
        cfg = self.config
        x = jnp.asarray(docs, jnp.float32)
        d_in = x.shape[-1]
        rng = rng if rng is not None else jax.random.PRNGKey(cfg.seed)
        k1, k2, k_loop = jax.random.split(rng, 3)
        if cfg.hidden:
            params = {
                "w1": jax.random.normal(k1, (d_in, cfg.hidden)) / np.sqrt(d_in),
                "b1": jnp.zeros((cfg.hidden,)),
                "w2": jax.random.normal(k2, (cfg.hidden, cfg.dim))
                      / np.sqrt(cfg.hidden),
                "b2": jnp.zeros((cfg.dim,)),
            }
        else:
            params = {"w1": jax.random.normal(k1, (d_in, cfg.dim))
                            / np.sqrt(d_in),
                      "b1": jnp.zeros((cfg.dim,))}

        tx = opt_lib.adamw(cfg.lr)
        opt_state = tx.init(params)

        def loss_fn(params, xa, xb):
            target = self._sim(xa, xb)
            pred = self._sim(self._apply(params, xa), self._apply(params, xb))
            return jnp.mean(jnp.square(pred - target))

        @jax.jit
        def step(params, opt_state, key):
            ka, kb = jax.random.split(key)
            ia = jax.random.randint(ka, (cfg.batch_size,), 0, x.shape[0])
            ib = jax.random.randint(kb, (cfg.batch_size,), 0, x.shape[0])
            loss, grads = jax.value_and_grad(loss_fn)(params, x[ia], x[ib])
            updates, opt_state = tx.update(grads, opt_state, params)
            return opt_lib.apply_updates(params, updates), opt_state, loss

        keys = jax.random.split(k_loop, cfg.steps)
        for k in keys:
            params, opt_state, _ = step(params, opt_state, k)
        self.params = params
        for name, v in params.items():
            self.state[name] = v
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        return self._apply(self.params, x)

    def output_dim(self, input_dim):
        return self.config.dim


class ContrastiveProjection(Transform):
    """InfoNCE over original-space nearest neighbours (paper §5.4, ¶2)."""

    name = "contrastive"
    state_keys = ("w",)

    def __init__(self, dim: int = 128, lr: float = 1e-3, steps: int = 1000,
                 batch_size: int = 128, n_neighbors: int = 4,
                 temperature: float = 0.1, seed: int = 0):
        super().__init__()
        self.dim, self.lr, self.steps = dim, lr, steps
        self.batch_size, self.n_neighbors = batch_size, n_neighbors
        self.temperature, self.seed = temperature, seed
        self.params = None

    def init_config(self):
        return {"dim": self.dim, "lr": self.lr, "steps": self.steps,
                "batch_size": self.batch_size,
                "n_neighbors": self.n_neighbors,
                "temperature": self.temperature, "seed": self.seed}

    def load_state(self, sd):
        super().load_state(sd)
        self.params = {"w": self.state["w"]} if self.fitted else None
        return self

    def fit(self, docs, queries=None, rng=None):
        x = jnp.asarray(docs, jnp.float32)
        n, d_in = x.shape
        rng = rng if rng is not None else jax.random.PRNGKey(self.seed)
        k_init, k_loop = jax.random.split(rng)
        params = {"w": jax.random.normal(k_init, (d_in, self.dim))
                       / np.sqrt(d_in)}

        # Precompute positives: nearest neighbour (excluding self) on a
        # subsample — O(n²) is fine at fit-set scale (≤ ~50k).
        sub = min(n, 20000)
        xs = x[:sub]
        sims = xs @ xs.T
        sims = sims - 1e9 * jnp.eye(sub)
        positives = jnp.argmax(sims, axis=1)

        tx = opt_lib.adamw(self.lr)
        opt_state = tx.init(params)
        temp = self.temperature
        batch_size = self.batch_size

        def loss_fn(params, anchors, pos):
            za = anchors @ params["w"]
            zp = pos @ params["w"]
            za = za / (jnp.linalg.norm(za, axis=-1, keepdims=True) + 1e-9)
            zp = zp / (jnp.linalg.norm(zp, axis=-1, keepdims=True) + 1e-9)
            logits = za @ zp.T / temp
            labels = jnp.arange(za.shape[0])
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(logp[labels, labels])

        @jax.jit
        def step(params, opt_state, key):
            idx = jax.random.randint(key, (batch_size,), 0, sub)
            anchors = xs[idx]
            pos = xs[positives[idx]]
            loss, grads = jax.value_and_grad(loss_fn)(params, anchors, pos)
            updates, opt_state = tx.update(grads, opt_state, params)
            return opt_lib.apply_updates(params, updates), opt_state, loss

        for k in jax.random.split(k_loop, self.steps):
            params, opt_state, _ = step(params, opt_state, k)
        self.params = params
        self.state["w"] = params["w"]
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        return x @ self.params["w"]

    def output_dim(self, input_dim):
        return self.dim
