"""Named factory for every compression configuration in the paper.

``build_method(name, dim=..)`` returns a ready-to-fit
:class:`~repro.core.pipeline.CompressionPipeline`.  Names mirror the rows of
paper Table 2; pre/post-processing (center+normalize) is applied per the
paper's recommendation unless ``pre=False`` / ``post=False``.
"""

from __future__ import annotations

from typing import Optional

from repro.core.autoencoder import (PAPER_L1, Autoencoder, AutoencoderConfig)
from repro.core.distance_learning import (ContrastiveProjection,
                                          SimilarityPreservingProjection)
from repro.core.pca import PCA
from repro.core.pipeline import CompressionPipeline
from repro.core.preprocess import (Center, CenterNorm, Normalize, Transform,
                                   ZScore)
from repro.core.quantization import (FloatCast, Int8Quantizer,
                                     OneBitQuantizer)
from repro.core.random_projection import (DimensionDrop, GaussianProjection,
                                          GreedyDimensionDrop,
                                          SparseProjection)
from repro.core.rotation import LearnedRotation

import jax.numpy as jnp

METHODS = (
    "original",
    "gaussian_projection", "sparse_projection",
    "dim_drop", "greedy_dim_drop",
    "pca", "pca_scaled",
    "ae_linear", "ae_full", "ae_shallow",
    "ae_linear_l1", "ae_full_l1", "ae_shallow_l1",
    "fp16", "int8", "onebit", "onebit_offset0",
    "pca_onebit", "pca_int8", "pca_rot_onebit",
    "distance_learning", "contrastive",
)


def _core_stages(name: str, dim: int, *, greedy_scorer=None,
                 ae_epochs: int = 5) -> list[Transform]:
    if name == "original":
        return []
    if name == "gaussian_projection":
        return [GaussianProjection(dim)]
    if name == "sparse_projection":
        return [SparseProjection(dim)]
    if name == "dim_drop":
        return [DimensionDrop(dim)]
    if name == "greedy_dim_drop":
        return [GreedyDimensionDrop(dim, scorer=greedy_scorer)]
    if name == "pca":
        return [PCA(dim)]
    if name == "pca_scaled":
        return [PCA(dim, scale_components="paper")]
    if name.startswith("ae_"):
        variant = {"ae_linear": "linear", "ae_full": "full",
                   "ae_shallow": "shallow_decoder"}[name.replace("_l1", "")]
        l1 = PAPER_L1 if name.endswith("_l1") else 0.0
        return [Autoencoder(AutoencoderConfig(
            variant=variant, bottleneck=dim, l1=l1, epochs=ae_epochs))]
    if name == "fp16":
        return [FloatCast(jnp.float16)]
    if name == "int8":
        return [Int8Quantizer()]
    if name == "onebit":
        return [OneBitQuantizer(offset=0.5)]
    if name == "onebit_offset0":
        return [OneBitQuantizer(offset=0.0)]
    if name == "pca_onebit":
        # paper: PCA(245) + 1-bit = 100× compression
        return [PCA(dim), OneBitQuantizer(offset=0.5)]
    if name == "pca_int8":
        # paper: PCA(128) + int8 = 24× compression
        return [PCA(dim), Int8Quantizer()]
    if name == "pca_rot_onebit":
        # same 100×-compression storage as pca_onebit, but an OPQ-style
        # learned rotation re-aims the sign grid after PCA concentrates
        # variance on few axes — free at search time (orthogonal)
        return [PCA(dim), LearnedRotation(), OneBitQuantizer(offset=0.5)]
    if name == "distance_learning":
        return [SimilarityPreservingProjection(dim=dim)]
    if name == "contrastive":
        return [ContrastiveProjection(dim=dim)]
    raise ValueError(f"unknown compression method {name!r}; "
                     f"known: {METHODS}")


def build_method(name: str, dim: int = 128, *, pre: bool = True,
                 post: bool = True, greedy_scorer=None,
                 ae_epochs: int = 5) -> CompressionPipeline:
    """Build a pipeline for a named Table-2 row.

    ``pre``/``post`` toggle the center+normalize wrapping (paper §6 recommends
    both).  Post-processing is skipped for pure precision reduction at the
    storage level — the paper applies it in the *evaluation* representation,
    which is what our benchmark does too.
    """
    stages: list[Transform] = []
    if pre:
        stages.append(CenterNorm())
    core = _core_stages(name, dim, greedy_scorer=greedy_scorer,
                        ae_epochs=ae_epochs)
    stages.extend(core)
    if post and core:
        stages.append(CenterNorm())
    return CompressionPipeline(stages)


def method_compression_ratio(name: str, dim: int, input_dim: int = 768) -> float:
    pipe = build_method(name, dim, pre=False, post=False)
    return pipe.compression_ratio(input_dim)


# ---------------------------------------------------------------------------
# transform registry: declarative (name, config) ↔ Transform instances
# ---------------------------------------------------------------------------

#: class name → class, for every pipeline stage the repo ships.  The index
#: artifact format (:mod:`repro.retrieval.api`) records each stage as
#: ``(type name, init_config())`` and rebuilds the skeleton through this
#: table before loading fitted state into it.
TRANSFORMS: dict[str, type] = {}


def register_transform(cls: type) -> type:
    """Register a :class:`Transform` subclass for declarative rebuild."""
    TRANSFORMS[cls.__name__] = cls
    return cls


for _cls in (Center, CenterNorm, Normalize, ZScore, PCA, FloatCast,
             Int8Quantizer, OneBitQuantizer, DimensionDrop,
             GreedyDimensionDrop, GaussianProjection, SparseProjection,
             Autoencoder, SimilarityPreservingProjection,
             ContrastiveProjection, LearnedRotation):
    register_transform(_cls)


def transform_spec(t: Transform) -> tuple[str, dict]:
    """``(type name, constructor kwargs)`` descriptor for one stage."""
    return type(t).__name__, t.init_config()


def build_transform(name: str, config: Optional[dict] = None) -> Transform:
    """Rebuild an (unfitted) transform from its :func:`transform_spec`."""
    if name not in TRANSFORMS:
        raise KeyError(f"unknown transform {name!r}; registered: "
                       f"{sorted(TRANSFORMS)} — register_transform() custom "
                       "stages before loading artifacts that use them")
    return TRANSFORMS[name](**(config or {}))


def pipeline_spec(pipeline: CompressionPipeline) -> list[tuple[str, dict]]:
    """Stage descriptors for a whole pipeline (see :func:`transform_spec`)."""
    return [transform_spec(t) for t in pipeline.transforms]


def build_pipeline_from_spec(stages) -> CompressionPipeline:
    """Rebuild an unfitted pipeline from :func:`pipeline_spec` output."""
    return CompressionPipeline(
        [build_transform(name, dict(cfg)) for name, cfg in stages])
