"""Random-projection dimension reduction (paper §4.1).

Four methods, in the paper's increasing order of quality:

* sparse random projection  (Achlioptas ±√3 entries, density 1/3)
* Gaussian random projection
* random dimension dropping (keep a random subset of coordinates)
* greedy dimension dropping (one-shot: score each dimension by the retrieval
  loss when it alone is removed; drop the least-useful ones) — deterministic
  and the best of the family (Table 2).

All four are expressible as a single (d, d') matrix, which matters for
deployment: the compressed index applier is one GEMM regardless of method.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.preprocess import Transform


class DimensionDrop(Transform):
    """Keep a random subset of d' coordinates (paper f_drop)."""

    name = "dim_drop"
    state_keys = ("keep",)

    def __init__(self, dim: int):
        super().__init__()
        self.dim = int(dim)

    def init_config(self):
        return {"dim": self.dim}

    def fit(self, docs, queries=None, rng=None):
        d = docs.shape[-1]
        if rng is None:
            rng = jax.random.PRNGKey(0)
        keep = jax.random.permutation(rng, d)[: self.dim]
        self.state["keep"] = jnp.sort(keep)
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        return jnp.take(x, self.state["keep"], axis=-1)

    def output_dim(self, input_dim):
        return self.dim


class GreedyDimensionDrop(Transform):
    """One-shot greedy selection of the d' most retrieval-useful dimensions.

    Paper §4.1: for each dimension i, evaluate retrieval quality with i
    removed (L_i); keep the d' dimensions whose removal hurts most.  The
    scorer is injected (callable (Q, D) → metric) so it can run on a
    subsample; the selection is deterministic given the scorer.
    """

    name = "greedy_dim_drop"
    state_keys = ("keep",)

    def __init__(self, dim: int,
                 scorer: Optional[Callable[[jax.Array, jax.Array], float]] = None,
                 max_eval_queries: int = 512, max_eval_docs: int = 16384):
        super().__init__()
        self.dim = int(dim)
        self.scorer = scorer
        self.max_eval_queries = max_eval_queries
        self.max_eval_docs = max_eval_docs

    def init_config(self):
        # scorer is a callable, not serializable — a reloaded instance can
        # apply its fitted "keep" but needs a fresh scorer to re-fit
        return {"dim": self.dim, "max_eval_queries": self.max_eval_queries,
                "max_eval_docs": self.max_eval_docs}

    def fit(self, docs, queries=None, rng=None):
        if self.scorer is None:
            raise ValueError("GreedyDimensionDrop needs a scorer; use "
                             "repro.retrieval.rprecision.make_dim_drop_scorer")
        losses = self.scorer(queries, docs)     # (d,) quality WITHOUT dim i
        # Quality when i removed is LOW for important dims → keep ascending.
        self.state["keep"] = jnp.sort(jnp.argsort(losses)[: self.dim])
        self.state["per_dim_quality"] = losses
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        return jnp.take(x, self.state["keep"], axis=-1)

    def output_dim(self, input_dim):
        return self.dim


class GaussianProjection(Transform):
    """x ↦ x @ R,  R_ij ~ N(0, 1/d')."""

    name = "gaussian_projection"
    state_keys = ("matrix",)

    def __init__(self, dim: int):
        super().__init__()
        self.dim = int(dim)

    def init_config(self):
        return {"dim": self.dim}

    def fit(self, docs, queries=None, rng=None):
        d = docs.shape[-1]
        if rng is None:
            rng = jax.random.PRNGKey(0)
        self.state["matrix"] = (
            jax.random.normal(rng, (d, self.dim), jnp.float32)
            / jnp.sqrt(jnp.asarray(self.dim, jnp.float32)))
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        return x @ self.state["matrix"]

    def output_dim(self, input_dim):
        return self.dim


class SparseProjection(Transform):
    """Achlioptas sparse random projection.

    R_ij = ±√(s/d') with prob 1/(2s) each, 0 with prob 1−1/s  (s = 3).
    """

    name = "sparse_projection"
    state_keys = ("matrix",)

    def __init__(self, dim: int, s: float = 3.0):
        super().__init__()
        self.dim = int(dim)
        self.s = float(s)

    def init_config(self):
        return {"dim": self.dim, "s": self.s}

    def fit(self, docs, queries=None, rng=None):
        d = docs.shape[-1]
        if rng is None:
            rng = jax.random.PRNGKey(0)
        k_sign, k_mask = jax.random.split(rng)
        signs = jax.random.rademacher(k_sign, (d, self.dim), jnp.float32)
        mask = jax.random.bernoulli(k_mask, 1.0 / self.s, (d, self.dim))
        scale = jnp.sqrt(self.s / self.dim)
        self.state["matrix"] = signs * mask.astype(jnp.float32) * scale
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        return x @ self.state["matrix"]

    def output_dim(self, input_dim):
        return self.dim
