"""Pre/post-processing transforms (paper §3.3, Appendix A).

The paper's central practical finding: **center then normalize, both before and
after dimension reduction**, computing the statistics for queries and documents
*separately*.  All transforms follow the two-population convention: ``fit``
receives (docs, queries) and stores per-population statistics; ``__call__``
takes ``kind`` ∈ {"docs", "queries"}.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


class Transform:
    """Base class for fit/apply index transforms.

    Subclasses implement :meth:`fit` (estimate state from data) and
    :meth:`__call__` (apply to new data).  All state is stored as jnp arrays in
    ``self.state`` so pipelines serialize uniformly.
    """

    name: str = "identity"

    #: state keys that must be present once fitted — ``load_state`` refuses
    #: an incomplete dict instead of silently producing a broken transform.
    state_keys: tuple[str, ...] = ()

    def __init__(self) -> None:
        self.state: dict[str, jax.Array] = {}
        self.fitted = False

    # -- declarative reconstruction ----------------------------------------
    def init_config(self) -> dict:
        """Constructor kwargs that rebuild an equivalent (unfitted) instance.

        Everything the transform needs *besides* fitted state — used by the
        index artifact format (:mod:`repro.retrieval.api`) to reconstruct a
        pipeline skeleton before loading state into it.  Values must be
        JSON-serializable.  (Named ``init_config`` because several
        transforms keep their config dataclass in ``self.config``.)
        """
        return {}

    # -- fitting ----------------------------------------------------------
    def fit(self, docs: jax.Array, queries: Optional[jax.Array] = None,
            rng: Optional[jax.Array] = None) -> "Transform":
        self.fitted = True
        return self

    # -- application ------------------------------------------------------
    def __call__(self, x: jax.Array, kind: str = "docs") -> jax.Array:
        return x

    # -- bookkeeping -------------------------------------------------------
    def output_dim(self, input_dim: int) -> int:
        return input_dim

    def bits_per_dim(self, bits_in: float) -> float:
        """Storage bits per dimension after this transform (32.0 for fp32)."""
        return bits_in

    def state_dict(self) -> dict:
        return {"name": self.name, "state": dict(self.state),
                "fitted": self.fitted}

    def load_state(self, sd: dict) -> "Transform":
        fitted = bool(sd["fitted"])
        if fitted:
            missing = set(self.state_keys) - set(sd["state"])
            if missing:
                raise ValueError(
                    f"{type(self).__name__}.load_state: fitted state is "
                    f"missing keys {sorted(missing)} "
                    f"(have {sorted(sd['state'])})")
        self.state = {k: jnp.asarray(v) for k, v in sd["state"].items()}
        self.fitted = fitted
        return self

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}(fitted={self.fitted})"


def _mean(x: jax.Array) -> jax.Array:
    return jnp.mean(x.astype(jnp.float32), axis=0)


def _std(x: jax.Array) -> jax.Array:
    return jnp.std(x.astype(jnp.float32), axis=0) + 1e-12


class Center(Transform):
    """x ← x − mean;   means estimated separately for docs and queries."""

    name = "center"
    state_keys = ("mean_docs", "mean_queries")

    def fit(self, docs, queries=None, rng=None):
        self.state["mean_docs"] = _mean(docs)
        self.state["mean_queries"] = (
            _mean(queries) if queries is not None else self.state["mean_docs"])
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        mean = self.state["mean_queries" if kind == "queries" else "mean_docs"]
        return x - mean


class Normalize(Transform):
    """x ← x / ||x||₂  (row-wise; stateless)."""

    name = "normalize"

    def fit(self, docs, queries=None, rng=None):
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
        return x / jnp.maximum(norm, 1e-12)


class ZScore(Transform):
    """x ← (x − mean) / std  (per-dimension; includes centering, App. A)."""

    name = "zscore"
    state_keys = ("mean_docs", "std_docs", "mean_queries", "std_queries")

    def fit(self, docs, queries=None, rng=None):
        self.state["mean_docs"] = _mean(docs)
        self.state["std_docs"] = _std(docs)
        if queries is not None:
            self.state["mean_queries"] = _mean(queries)
            self.state["std_queries"] = _std(queries)
        else:
            self.state["mean_queries"] = self.state["mean_docs"]
            self.state["std_queries"] = self.state["std_docs"]
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        sfx = "queries" if kind == "queries" else "docs"
        return (x - self.state[f"mean_{sfx}"]) / self.state[f"std_{sfx}"]


class CenterNorm(Transform):
    """The paper's recommended composite: center then L2-normalize.

    Equivalent to ``Center → Normalize`` but fused (one pass, one kernel).
    """

    name = "center_norm"
    state_keys = ("mean_docs", "mean_queries")

    def fit(self, docs, queries=None, rng=None):
        self.state["mean_docs"] = _mean(docs)
        self.state["mean_queries"] = (
            _mean(queries) if queries is not None else self.state["mean_docs"])
        self.fitted = True
        return self

    def __call__(self, x, kind="docs"):
        mean = self.state["mean_queries" if kind == "queries" else "mean_docs"]
        y = x - mean
        norm = jnp.linalg.norm(y, axis=-1, keepdims=True)
        return y / jnp.maximum(norm, 1e-12)


@dataclasses.dataclass(frozen=True)
class PreprocessSpec:
    """Declarative pre/post-processing configuration.

    ``mode`` ∈ {"none", "center", "norm", "center_norm", "zscore",
    "zscore_norm"} — the rows of paper Table 5.
    """

    mode: str = "center_norm"

    def build(self) -> list[Transform]:
        if self.mode == "none":
            return []
        if self.mode == "center":
            return [Center()]
        if self.mode == "norm":
            return [Normalize()]
        if self.mode == "center_norm":
            return [CenterNorm()]
        if self.mode == "zscore":
            return [ZScore()]
        if self.mode == "zscore_norm":
            return [ZScore(), Normalize()]
        raise ValueError(f"unknown preprocess mode: {self.mode!r}")


def fit_apply(transforms: list[Transform], docs: jax.Array,
              queries: jax.Array, rng=None) -> tuple[jax.Array, jax.Array]:
    """Fit each transform in order, applying as we go. Returns final (D, Q)."""
    for t in transforms:
        t.fit(docs, queries, rng=rng)
        docs = t(docs, "docs")
        queries = t(queries, "queries")
    return docs, queries
