"""Autoencoder index compression (paper §4.3).

Three bottleneck architectures from the paper (768 → 128 default):

1. ``linear``          — e₁ = L(768→128),                    r₁ = L(128→768)
2. ``full``            — e₂ = L→tanh→L→tanh→L (768,512,256,128), r₂ = mirror
3. ``shallow_decoder`` — e₃ = e₂,                            r₃ = L(128→768)

plus optional L1 regularization on all weights (Table 3: batch 128, Adam,
lr 1e-3, λ_L1 = 10^-5.9).  Loss is MSE reconstruction; only the encoder is
applied at compression time.  The paper finds ``shallow_decoder`` (+L1) best —
the bottleneck representation must stay "close to linear-decodable", which
regularizes the encoder.

Training runs data-parallel under ``jax.jit`` (donated state), and the fit set
convention matches PCA: docs / queries / both (Fig. 4 bottom row).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.preprocess import Transform
from repro.train import optimizer as opt_lib

# Paper Table 3 hyperparameters.
PAPER_BATCH_SIZE = 128
PAPER_LR = 1e-3
PAPER_L1 = 10 ** -5.9


def _init_linear(rng, d_in, d_out):
    # Glorot-uniform, zero bias (matches the paper's PyTorch defaults closely
    # enough; exact init scheme is not performance-critical here).
    limit = float(np.sqrt(6.0 / (d_in + d_out)))
    w = jax.random.uniform(rng, (d_in, d_out), jnp.float32, -limit, limit)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def _apply_linear(p, x):
    return x @ p["w"] + p["b"]


def _mlp_dims(variant: str, d_in: int, d_bottleneck: int) -> list[int]:
    if variant == "linear":
        return [d_in, d_bottleneck]
    # full / shallow_decoder encoder: d → 512 → 256 → bottleneck (paper dims
    # scale if d_in != 768: use geometric interpolation).
    if d_in == 768:
        return [768, 512, 256, d_bottleneck]
    mid1 = int(2 ** round(np.log2(np.sqrt(d_in * np.sqrt(d_in * d_bottleneck)))))
    mid2 = int(2 ** round(np.log2(np.sqrt(mid1 * d_bottleneck))))
    dims = [d_in, max(mid1, d_bottleneck), max(mid2, d_bottleneck), d_bottleneck]
    return dims


def init_autoencoder(rng, variant: str, d_in: int, d_bottleneck: int) -> dict:
    enc_dims = _mlp_dims(variant, d_in, d_bottleneck)
    if variant == "linear":
        dec_dims = [d_bottleneck, d_in]
    elif variant == "full":
        dec_dims = enc_dims[::-1]
    elif variant == "shallow_decoder":
        dec_dims = [d_bottleneck, d_in]
    else:
        raise ValueError(f"unknown autoencoder variant {variant!r}")
    keys = jax.random.split(rng, len(enc_dims) + len(dec_dims))
    enc = [_init_linear(keys[i], enc_dims[i], enc_dims[i + 1])
           for i in range(len(enc_dims) - 1)]
    dec = [_init_linear(keys[len(enc_dims) + i], dec_dims[i], dec_dims[i + 1])
           for i in range(len(dec_dims) - 1)]
    return {"enc": enc, "dec": dec}


def encode(params: dict, x: jax.Array) -> jax.Array:
    h = x
    n = len(params["enc"])
    for i, layer in enumerate(params["enc"]):
        h = _apply_linear(layer, h)
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def decode(params: dict, z: jax.Array) -> jax.Array:
    h = z
    n = len(params["dec"])
    for i, layer in enumerate(params["dec"]):
        h = _apply_linear(layer, h)
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def reconstruction_loss(params: dict, x: jax.Array) -> jax.Array:
    return jnp.mean(jnp.square(decode(params, encode(params, x)) - x))


@dataclasses.dataclass(frozen=True)
class AutoencoderConfig:
    variant: str = "shallow_decoder"   # linear | full | shallow_decoder
    bottleneck: int = 128
    l1: float = 0.0                    # PAPER_L1 to enable
    lr: float = PAPER_LR
    batch_size: int = PAPER_BATCH_SIZE
    epochs: int = 5
    fit_on: str = "docs"               # docs | queries | both
    seed: int = 0


class Autoencoder(Transform):
    """Trainable autoencoder transform (paper §4.3)."""

    name = "autoencoder"

    def __init__(self, config: AutoencoderConfig | None = None, **kw):
        super().__init__()
        self.config = config or AutoencoderConfig(**kw)
        self.params: Optional[dict] = None
        self.loss_history: list[float] = []

    def init_config(self):
        return dataclasses.asdict(self.config)

    # -- fitting ------------------------------------------------------------
    def _fit_set(self, docs, queries):
        cfg = self.config
        if cfg.fit_on == "docs" or queries is None:
            return docs
        if cfg.fit_on == "queries":
            return queries
        return jnp.concatenate([docs, queries], axis=0)

    def fit(self, docs, queries=None, rng=None):
        cfg = self.config
        x = np.asarray(self._fit_set(docs, queries), np.float32)
        d_in = x.shape[-1]
        rng = rng if rng is not None else jax.random.PRNGKey(cfg.seed)
        k_init, k_shuffle = jax.random.split(rng)
        params = init_autoencoder(k_init, cfg.variant, d_in, cfg.bottleneck)

        tx = opt_lib.adamw(cfg.lr, l1=cfg.l1)
        opt_state = tx.init(params)

        @jax.jit
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(reconstruction_loss)(params, batch)
            updates, opt_state = tx.update(grads, opt_state, params)
            return opt_lib.apply_updates(params, updates), opt_state, loss

        n = x.shape[0]
        bs = min(cfg.batch_size, n)
        steps_per_epoch = max(1, n // bs)
        shuffle_rng = np.random.default_rng(cfg.seed)
        for _ in range(cfg.epochs):
            perm = shuffle_rng.permutation(n)
            for s in range(steps_per_epoch):
                batch = jnp.asarray(x[perm[s * bs:(s + 1) * bs]])
                params, opt_state, loss = train_step(params, opt_state, batch)
            self.loss_history.append(float(loss))

        self.params = params
        # flatten into .state for serialization
        for i, layer in enumerate(params["enc"]):
            self.state[f"enc{i}_w"] = layer["w"]
            self.state[f"enc{i}_b"] = layer["b"]
        for i, layer in enumerate(params["dec"]):
            self.state[f"dec{i}_w"] = layer["w"]
            self.state[f"dec{i}_b"] = layer["b"]
        self.fitted = True
        return self

    def load_state(self, sd):
        super().load_state(sd)
        enc, dec = [], []
        i = 0
        while f"enc{i}_w" in self.state:
            enc.append({"w": self.state[f"enc{i}_w"],
                        "b": self.state[f"enc{i}_b"]})
            i += 1
        i = 0
        while f"dec{i}_w" in self.state:
            dec.append({"w": self.state[f"dec{i}_w"],
                        "b": self.state[f"dec{i}_b"]})
            i += 1
        if self.fitted and not enc:
            # layer count varies with the variant, so the static state_keys
            # check can't cover it — a fitted AE must have ≥ 1 encoder layer
            raise ValueError("Autoencoder.load_state: fitted state has no "
                             f"enc0_w/enc0_b layers (keys: "
                             f"{sorted(self.state)})")
        self.params = {"enc": enc, "dec": dec}
        return self

    # -- application ----------------------------------------------------------
    def __call__(self, x, kind="docs"):
        if self.params is None:
            raise RuntimeError("Autoencoder not fitted")
        return encode(self.params, x)

    def inverse(self, z):
        return decode(self.params, z)

    def output_dim(self, input_dim):
        return self.config.bottleneck
