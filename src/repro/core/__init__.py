"""The paper's primary contribution: unsupervised post-hoc KB index compression.

Public API::

    from repro.core import (CompressionPipeline, PCA, Autoencoder,
                            Int8Quantizer, OneBitQuantizer, CenterNorm,
                            build_method)
"""

from repro.core.autoencoder import (Autoencoder, AutoencoderConfig, PAPER_L1)
from repro.core.distance_learning import (ContrastiveProjection,
                                          SimilarityPreservingProjection)
from repro.core.pca import PCA, fit_pca_distributed, moments
from repro.core.pipeline import CompressionPipeline
from repro.core.preprocess import (Center, CenterNorm, Normalize,
                                   PreprocessSpec, Transform, ZScore)
from repro.core.quantization import (FloatCast, Int8Quantizer,
                                     OneBitQuantizer, compression_ratio,
                                     pack_bits, unpack_bits)
from repro.core.random_projection import (DimensionDrop, GaussianProjection,
                                          GreedyDimensionDrop,
                                          SparseProjection)
from repro.core.registry import METHODS, build_method, method_compression_ratio

__all__ = [
    "Autoencoder", "AutoencoderConfig", "PAPER_L1",
    "ContrastiveProjection", "SimilarityPreservingProjection",
    "PCA", "fit_pca_distributed", "moments",
    "CompressionPipeline",
    "Center", "CenterNorm", "Normalize", "PreprocessSpec", "Transform",
    "ZScore",
    "FloatCast", "Int8Quantizer", "OneBitQuantizer", "compression_ratio",
    "pack_bits", "unpack_bits",
    "DimensionDrop", "GaussianProjection", "GreedyDimensionDrop",
    "SparseProjection",
    "METHODS", "build_method", "method_compression_ratio",
]
