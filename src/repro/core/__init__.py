"""The paper's primary contribution: unsupervised post-hoc KB index compression.

Public API::

    from repro.core import (CompressionPipeline, PCA, Autoencoder,
                            Int8Quantizer, OneBitQuantizer, CenterNorm,
                            build_method)
"""

from repro.core.autoencoder import (Autoencoder, AutoencoderConfig, PAPER_L1)
from repro.core.distance_learning import (ContrastiveProjection,
                                          SimilarityPreservingProjection)
from repro.core.pca import PCA, fit_pca_distributed, moments
from repro.core.pipeline import CompressionPipeline
from repro.core.preprocess import (Center, CenterNorm, Normalize,
                                   PreprocessSpec, Transform, ZScore)
from repro.core.quantization import (FloatCast, Int8Quantizer,
                                     OneBitQuantizer, compression_ratio,
                                     pack_bits, unpack_bits)
from repro.core.random_projection import (DimensionDrop, GaussianProjection,
                                          GreedyDimensionDrop,
                                          SparseProjection)
from repro.core.rotation import LearnedRotation
from repro.core.registry import (METHODS, TRANSFORMS, build_method,
                                 build_pipeline_from_spec, build_transform,
                                 method_compression_ratio, pipeline_spec,
                                 register_transform, transform_spec)

__all__ = [
    "Autoencoder", "AutoencoderConfig", "PAPER_L1",
    "ContrastiveProjection", "SimilarityPreservingProjection",
    "PCA", "fit_pca_distributed", "moments",
    "CompressionPipeline",
    "Center", "CenterNorm", "Normalize", "PreprocessSpec", "Transform",
    "ZScore",
    "FloatCast", "Int8Quantizer", "OneBitQuantizer", "compression_ratio",
    "pack_bits", "unpack_bits",
    "DimensionDrop", "GaussianProjection", "GreedyDimensionDrop",
    "SparseProjection",
    "LearnedRotation",
    "METHODS", "build_method", "method_compression_ratio",
    "TRANSFORMS", "build_pipeline_from_spec", "build_transform",
    "pipeline_spec", "register_transform", "transform_spec",
]
