"""Precision reduction (paper §4.4) — fp16, int8, and 1-bit quantization.

Each quantizer is a :class:`Transform` whose ``__call__`` returns the
*dequantized* float values (quantize→dequantize round-trip), which is how the
paper evaluates retrieval on reduced-precision indexes.  For actual deployment
each quantizer also exposes ``encode``/``decode``: ``encode`` emits the compact
storage representation (fp16 / int8 / bit-packed uint32) consumed directly by
the Pallas scoring kernels in :mod:`repro.kernels`, so the index never needs to
be materialized at full precision on device.

The 1-bit scheme follows §4.4: with centered data,
``f_α(x_i) = (1 − α)  if x_i ≥ 0 else (0 − α)``.
α = 0.5 gives values ±0.5 which, unlike {0, 1} (Yamada et al., 2021),
distinguishes agree/disagree under inner-product similarity; the two are
equivalent once post-processing (center+normalize) is applied — both facts are
reproduced in ``benchmarks/table2_compression.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.preprocess import Transform

# ---------------------------------------------------------------------------
# bit packing helpers (shared with kernels/binary_ip)
# ---------------------------------------------------------------------------


def pack_bits(x: jax.Array) -> jax.Array:
    """Pack the sign bits of ``x`` (…, d) into uint32 words (…, d/32).

    Bit j of word w encodes sign(x[..., 32*w + j]) — 1 for x ≥ 0.
    d must be a multiple of 32 (pad upstream if needed).
    """
    d = x.shape[-1]
    if d % 32 != 0:
        raise ValueError(f"pack_bits needs d % 32 == 0, got d={d}")
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(*x.shape[:-1], d // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, d: int) -> jax.Array:
    """Inverse of :func:`pack_bits` → ±1 int8 array of trailing dim ``d``."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    signs = bits.astype(jnp.int8) * jnp.int8(2) - jnp.int8(1)
    return signs.reshape(*words.shape[:-1], d)


# ---------------------------------------------------------------------------
# quantizers
# ---------------------------------------------------------------------------


class FloatCast(Transform):
    """fp32 → fp16/bf16 (2× compression, §4.4 "Precision 16-bit")."""

    name = "float_cast"

    def __init__(self, dtype=jnp.float16):
        super().__init__()
        self.dtype = jnp.dtype(dtype)

    def init_config(self):
        return {"dtype": self.dtype.name}

    def fit(self, docs, queries=None, rng=None):
        self.fitted = True
        return self

    def encode(self, x, kind="docs"):
        return x.astype(self.dtype)

    def decode(self, x):
        return x.astype(jnp.float32)

    def __call__(self, x, kind="docs"):
        return self.decode(self.encode(x, kind))

    def bits_per_dim(self, bits_in):
        return self.dtype.itemsize * 8


class Int8Quantizer(Transform):
    """Per-dimension affine int8 quantization (4× compression).

    scale_j = (max_j − min_j)/255, zero_j = min_j, fitted on the document
    index (the population whose storage dominates).  Queries use the same
    codebook so that quantized inner products remain comparable.
    """

    name = "int8"
    state_keys = ("scale", "zero")

    def __init__(self, percentile: float = 100.0):
        super().__init__()
        # percentile < 100 clips outliers before fitting the range
        self.percentile = float(percentile)

    def init_config(self):
        return {"percentile": self.percentile}

    def fit(self, docs, queries=None, rng=None):
        x = docs.astype(jnp.float32)
        if self.percentile >= 100.0:
            lo, hi = jnp.min(x, axis=0), jnp.max(x, axis=0)
        else:
            q = self.percentile / 100.0
            lo = jnp.quantile(x, 1 - q, axis=0)
            hi = jnp.quantile(x, q, axis=0)
        scale = jnp.maximum(hi - lo, 1e-12) / 255.0
        self.state["scale"] = scale
        self.state["zero"] = lo
        self.fitted = True
        return self

    def encode(self, x, kind="docs"):
        q = jnp.round((x - self.state["zero"]) / self.state["scale"])
        return jnp.clip(q, 0, 255).astype(jnp.uint8)

    def decode(self, q):
        return (q.astype(jnp.float32) * self.state["scale"]
                + self.state["zero"])

    def __call__(self, x, kind="docs"):
        return self.decode(self.encode(x, kind))

    def bits_per_dim(self, bits_in):
        return 8.0


class OneBitQuantizer(Transform):
    """1-bit-per-dimension quantization with offset α (32× compression).

    ``offset=0.5`` → values ±0.5 (paper's recommendation for IP similarity);
    ``offset=0.0`` → values {0, 1} (Yamada et al., 2021).
    ``encode`` emits bit-packed uint32 words (d/32 per vector).
    """

    name = "onebit"

    def __init__(self, offset: float = 0.5):
        super().__init__()
        self.offset = float(offset)

    def init_config(self):
        return {"offset": self.offset}

    def fit(self, docs, queries=None, rng=None):
        self.fitted = True
        return self

    def encode(self, x, kind="docs"):
        d = x.shape[-1]
        if d % 32 != 0:
            pad = 32 - d % 32
            x = jnp.pad(x, [*[(0, 0)] * (x.ndim - 1), (0, pad)],
                        constant_values=-1.0)  # pad bits decode to 0−α (sign −)
        return pack_bits(x)

    def decode(self, words, d: int | None = None):
        if d is None:
            d = words.shape[-1] * 32
        signs = unpack_bits(words, words.shape[-1] * 32)[..., :d]
        bit = (signs > 0).astype(jnp.float32)
        return bit - self.offset

    def __call__(self, x, kind="docs"):
        bit = (x >= 0).astype(jnp.float32)
        return bit - self.offset

    def bits_per_dim(self, bits_in):
        return 1.0


def compression_ratio(input_dim: int, transforms: list[Transform],
                      base_bits: float = 32.0) -> float:
    """Storage compression factor of a transform chain vs fp32 input."""
    dim, bits = input_dim, base_bits
    for t in transforms:
        dim = t.output_dim(dim)
        bits = t.bits_per_dim(bits)
    return (input_dim * base_bits) / (dim * bits)


def simulate_storage_bytes(n_vectors: int, input_dim: int,
                           transforms: list[Transform]) -> int:
    dim, bits = input_dim, 32.0
    for t in transforms:
        dim = t.output_dim(dim)
        bits = t.bits_per_dim(bits)
    return int(np.ceil(n_vectors * dim * bits / 8))
