"""Principal component analysis for index compression (paper §4.2).

The paper's key findings, all implemented here:

* PCA to 128 dims retains ~94–96% retrieval performance (6× compression).
* What PCA is *fitted on* (docs / queries / both) only matters when the data is
  not centered (queries happen to be closer to the origin, Table 1).
* The covariance can be estimated from very few samples (~1k, §5.1) — so we
  also expose a streaming/distributed moment accumulator that psum-reduces
  per-shard moments across a mesh: fitting PCA on a 1.8B-document index costs
  one pass and one (d², ) all-reduce.
* *Component scaling* (§4.2): down-scaling the top-5 eigenvector projections by
  (0.5, 0.8, 0.8, 0.9, 0.8) systematically beats vanilla PCA.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.preprocess import Transform

# Paper §4.2: grid-searched scaling of the top-5 principal components.
PAPER_COMPONENT_SCALES: tuple[float, ...] = (0.5, 0.8, 0.8, 0.9, 0.8)


def moments(x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-batch (count, sum, sum-of-outer-products) in float32.

    These are sufficient statistics for the covariance; they add across
    batches/shards, so the distributed fit is a ``psum`` of this triple.
    """
    x = x.astype(jnp.float32)
    n = jnp.asarray(x.shape[0], jnp.float32)
    s = jnp.sum(x, axis=0)
    ss = x.T @ x
    return n, s, ss


def covariance_from_moments(n: jax.Array, s: jax.Array,
                            ss: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(mean, covariance) from accumulated moments."""
    mean = s / n
    cov = ss / n - jnp.outer(mean, mean)
    return mean, cov


def fit_pca_from_cov(mean: jax.Array, cov: jax.Array, dim: int,
                     ) -> dict[str, jax.Array]:
    """Eigendecompose a (d, d) covariance; keep top-``dim`` components.

    Returns state dict {mean, components (d, dim), eigenvalues (dim,)} with
    components ordered by descending eigenvalue.
    """
    # eigh returns ascending eigenvalues; flip. Covariance is symmetric PSD.
    evals, evecs = jnp.linalg.eigh(cov)
    order = jnp.argsort(evals)[::-1][:dim]
    return {
        "mean": mean,
        "components": evecs[:, order],          # (d, dim), orthonormal cols
        "eigenvalues": jnp.maximum(evals[order], 0.0),
    }


class PCA(Transform):
    """PCA projection ``x ↦ (x − μ) @ W`` with optional component scaling.

    Parameters
    ----------
    dim: target dimensionality d'.
    fit_on: "docs" | "queries" | "both" — which population estimates the
        covariance (paper Fig. 4).
    scale_components: optional per-component multipliers for the leading
        components (paper §4.2 "Component Scaling"); ``None`` disables,
        ``"paper"`` uses the paper's grid-searched (0.5, 0.8, 0.8, 0.9, 0.8).
    max_fit_samples: subsample cap for the fit set (paper §5.1 shows ≥ d'
        samples suffice).
    """

    name = "pca"
    state_keys = ("mean", "components", "eigenvalues")

    def __init__(self, dim: int, fit_on: str = "docs",
                 scale_components=None, max_fit_samples: Optional[int] = None):
        super().__init__()
        if fit_on not in ("docs", "queries", "both"):
            raise ValueError(f"fit_on must be docs|queries|both, got {fit_on}")
        self.dim = int(dim)
        self.fit_on = fit_on
        if scale_components == "paper":
            scale_components = PAPER_COMPONENT_SCALES
        self.scale_components = (
            tuple(float(s) for s in scale_components)
            if scale_components is not None else None)
        self.max_fit_samples = max_fit_samples

    def init_config(self):
        return {"dim": self.dim, "fit_on": self.fit_on,
                "scale_components": (list(self.scale_components)
                                     if self.scale_components is not None
                                     else None),
                "max_fit_samples": self.max_fit_samples}

    # -- fitting -----------------------------------------------------------
    def _fit_set(self, docs, queries):
        if self.fit_on == "docs" or queries is None:
            return docs
        if self.fit_on == "queries":
            return queries
        return jnp.concatenate([docs, queries], axis=0)

    def fit(self, docs, queries=None, rng=None):
        x = self._fit_set(docs, queries)
        if self.max_fit_samples is not None and x.shape[0] > self.max_fit_samples:
            if rng is None:
                rng = jax.random.PRNGKey(0)
            idx = jax.random.choice(rng, x.shape[0],
                                    (self.max_fit_samples,), replace=False)
            x = x[idx]
        mean, cov = covariance_from_moments(*moments(x))
        self.state = fit_pca_from_cov(mean, cov, self.dim)
        if self.scale_components is not None:
            k = min(len(self.scale_components), self.dim)
            scales = jnp.ones((self.dim,), jnp.float32)
            scales = scales.at[:k].set(jnp.asarray(self.scale_components[:k]))
            self.state["scales"] = scales
        self.fitted = True
        return self

    def fit_from_moments(self, n, s, ss):
        """Fit from pre-accumulated (possibly psum-reduced) moments."""
        mean, cov = covariance_from_moments(n, s, ss)
        self.state = fit_pca_from_cov(mean, cov, self.dim)
        if self.scale_components is not None:
            k = min(len(self.scale_components), self.dim)
            scales = jnp.ones((self.dim,), jnp.float32)
            scales = scales.at[:k].set(jnp.asarray(self.scale_components[:k]))
            self.state["scales"] = scales
        self.fitted = True
        return self

    # -- application --------------------------------------------------------
    def projection_matrix(self) -> jax.Array:
        """(d, d') matrix including component scaling — single-GEMM apply."""
        w = self.state["components"]
        if "scales" in self.state:
            w = w * self.state["scales"][None, :]
        return w

    def __call__(self, x, kind="docs"):
        w = self.projection_matrix()
        return (x - self.state["mean"]) @ w

    def inverse(self, z: jax.Array) -> jax.Array:
        """Approximate reconstruction (for reconstruction-loss analysis)."""
        w = self.state["components"]
        if "scales" in self.state:
            z = z / self.state["scales"][None, :]
        return z @ w.T + self.state["mean"]

    def output_dim(self, input_dim: int) -> int:
        return self.dim

    def explained_variance_ratio(self) -> jax.Array:
        ev = self.state["eigenvalues"]
        return ev / jnp.maximum(jnp.sum(ev), 1e-12)


def fit_pca_distributed(x_sharded: jax.Array, dim: int,
                        mesh: jax.sharding.Mesh,
                        axis: str = "data") -> PCA:
    """Fit PCA on a row-sharded index without gathering it.

    ``x_sharded`` is a (N, d) global array sharded over ``axis``.  Each shard
    computes local moments; XLA inserts the cross-device reduction for the
    (d,)+(d,d) sums.  Cost: one pass over local rows + one all-reduce of
    ~d² floats — independent of N.
    """
    @jax.jit
    def _moments(x):
        return moments(x)

    n, s, ss = _moments(x_sharded)       # pjit reduces across shards
    pca = PCA(dim)
    return pca.fit_from_moments(n, s, ss)
