"""Composable compression pipelines (the paper's full recipe as one object).

A :class:`CompressionPipeline` is an ordered list of transforms, e.g. the
paper's best 24× configuration::

    pipe = CompressionPipeline([
        CenterNorm(),                 # pre-processing  (§3.3)
        PCA(128, scale_components="paper"),
        CenterNorm(),                 # post-processing (§6)
        Int8Quantizer(),              # precision reduction (§4.4)
    ])
    pipe.fit(doc_embs, query_embs)
    docs_c  = pipe.transform(doc_embs, "docs")
    query_c = pipe.transform(q, "queries")

``fit`` threads the data through each stage as it fits (a stage sees the
output of its predecessors — matching the paper, where e.g. PCA is fitted on
already centered+normalized vectors).  Pipelines serialize to a flat dict of
arrays (``state_dict``/``load_state_dict``) for checkpointing, and report
their storage compression ratio.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

from repro.core import quantization as quant
from repro.core.preprocess import Transform


class CompressionPipeline:
    def __init__(self, transforms: Sequence[Transform]):
        self.transforms = list(transforms)

    # -- fitting -------------------------------------------------------------
    def fit(self, docs: jax.Array, queries: Optional[jax.Array] = None,
            rng: Optional[jax.Array] = None) -> "CompressionPipeline":
        if rng is None:
            rng = jax.random.PRNGKey(0)
        for t in self.transforms:
            rng, sub = jax.random.split(rng)
            t.fit(docs, queries, rng=sub)
            docs = t(docs, "docs")
            if queries is not None:
                queries = t(queries, "queries")
        return self

    def fit_transform(self, docs, queries=None, rng=None):
        """Fit, then return (docs', queries') transformed by the full chain."""
        self.fit(docs, queries, rng)
        docs_t = self.transform(docs, "docs")
        queries_t = (self.transform(queries, "queries")
                     if queries is not None else None)
        return docs_t, queries_t

    # -- application -----------------------------------------------------------
    def transform(self, x: jax.Array, kind: str = "docs") -> jax.Array:
        for t in self.transforms:
            x = t(x, kind)
        return x

    def __call__(self, x, kind="docs"):
        return self.transform(x, kind)

    # -- storage accounting ------------------------------------------------------
    def compression_ratio(self, input_dim: int) -> float:
        return quant.compression_ratio(input_dim, self.transforms)

    def output_dim(self, input_dim: int) -> int:
        for t in self.transforms:
            input_dim = t.output_dim(input_dim)
        return input_dim

    # -- serialization -----------------------------------------------------------
    def state_dict(self) -> dict:
        return {"stages": [t.state_dict() for t in self.transforms],
                "types": [type(t).__name__ for t in self.transforms]}

    def load_state_dict(self, sd: dict) -> "CompressionPipeline":
        types = sd.get("types")
        if types is not None:
            have = [type(t).__name__ for t in self.transforms]
            if have != list(types):
                raise ValueError(
                    f"pipeline stage mismatch: state dict has {list(types)}, "
                    f"object has {have}")
        if len(sd["stages"]) != len(self.transforms):
            raise ValueError(
                f"pipeline length mismatch: state dict has "
                f"{len(sd['stages'])} stages, object has "
                f"{len(self.transforms)}")
        for t, stage_sd in zip(self.transforms, sd["stages"]):
            t.load_state(stage_sd)
        return self

    def save(self, path: str) -> None:
        flat: dict[str, np.ndarray] = {}
        for i, t in enumerate(self.transforms):
            for k, v in t.state.items():
                flat[f"{i}:{type(t).__name__}:{k}"] = np.asarray(v)
        np.savez(path, **flat)

    def load(self, path: str) -> "CompressionPipeline":
        """Load ``save`` output, routed through :meth:`load_state_dict`.

        Every stage goes through its own ``load_state`` so per-stage
        validation runs: a stateful stage whose keys are incomplete in the
        file raises instead of coming back half-fitted.  (Stages with no
        state in the file — Normalize, quantizer-style stateless transforms
        — are loaded as fitted with empty state, which their ``state_keys``
        check accepts only when they truly need none.)
        """
        data = np.load(path)
        per_stage: list[dict] = [{} for _ in self.transforms]
        for key in data.files:
            i_str, tname, k = key.split(":", 2)
            i = int(i_str)
            if not 0 <= i < len(self.transforms):
                raise ValueError(
                    f"pipeline file has stage index {i}, object has only "
                    f"{len(self.transforms)} stages")
            have = type(self.transforms[i]).__name__
            if have != tname:
                raise ValueError(
                    f"pipeline stage {i} mismatch: file has {tname}, "
                    f"object has {have}")
            per_stage[i][k] = data[key]
        sd = {"types": [type(t).__name__ for t in self.transforms],
              "stages": [{"name": t.name, "state": st, "fitted": True}
                         for t, st in zip(self.transforms, per_stage)]}
        return self.load_state_dict(sd)

    def __repr__(self) -> str:
        inner = ", ".join(type(t).__name__ for t in self.transforms)
        return f"CompressionPipeline([{inner}])"
