"""Dense retrieval substrate: exact & approximate top-k, metrics, sharding.

The declarative front door is :mod:`repro.retrieval.api`::

    spec = IndexSpec(method="pca_int8", dim=128, ivf=(200, 100))
    index = build_index(spec, docs, queries_sample)
    index.save("kb.npz");  index = load_index("kb.npz")
"""

from repro.retrieval.api import (Index, IndexSpec, ShardSpec, build_index,
                                 load_index, load_index_meta, save_index)
from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFFlatIndex, IVFIndex
from repro.retrieval.rprecision import (make_dim_drop_scorer, r_precision,
                                        recall_at_k,
                                        retrieved_relevant_counts)
from repro.retrieval.scorers import (Scorer, backend_tail_stages, get_scorer,
                                     register_scorer, scorer_for_pipeline,
                                     scorer_names)
from repro.retrieval.segments import DriftMonitor, SegmentedIndex
from repro.retrieval.sharded import ShardedCompressedIndex, ShardedIVFIndex
from repro.retrieval.topk import (masked_topk_by_id, resolve_k,
                                  topk_score_then_id, topk_search)
from repro.storage import (ArtifactError, ListStore, MmapStore,
                           ResidentStore, is_chunked_artifact)

__all__ = [
    "Index", "IndexSpec", "ShardSpec", "build_index", "load_index",
    "load_index_meta", "save_index",
    "CompressedIndex", "DenseIndex", "IVFFlatIndex", "IVFIndex",
    "DriftMonitor", "SegmentedIndex",
    "ShardedCompressedIndex", "ShardedIVFIndex",
    "Scorer", "backend_tail_stages", "get_scorer", "register_scorer",
    "scorer_for_pipeline", "scorer_names",
    "make_dim_drop_scorer", "r_precision", "recall_at_k",
    "retrieved_relevant_counts",
    "masked_topk_by_id", "resolve_k", "topk_score_then_id", "topk_search",
    "ArtifactError", "ListStore", "MmapStore", "ResidentStore",
    "is_chunked_artifact",
]
