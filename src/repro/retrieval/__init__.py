"""Dense retrieval substrate: exact & approximate top-k, metrics, sharding."""

from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFFlatIndex
from repro.retrieval.rprecision import (make_dim_drop_scorer, r_precision,
                                        retrieved_relevant_counts)
from repro.retrieval.scorers import (Scorer, get_scorer, register_scorer,
                                     scorer_for_pipeline, scorer_names)
from repro.retrieval.sharded import ShardedCompressedIndex
from repro.retrieval.topk import topk_search

__all__ = [
    "CompressedIndex", "DenseIndex", "IVFFlatIndex",
    "ShardedCompressedIndex",
    "Scorer", "get_scorer", "register_scorer", "scorer_for_pipeline",
    "scorer_names",
    "make_dim_drop_scorer", "r_precision", "retrieved_relevant_counts",
    "topk_search",
]
