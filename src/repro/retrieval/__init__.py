"""Dense retrieval substrate: exact & approximate top-k, metrics, sharding."""

from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFFlatIndex
from repro.retrieval.rprecision import (make_dim_drop_scorer, r_precision,
                                        retrieved_relevant_counts)
from repro.retrieval.topk import topk_search

__all__ = [
    "CompressedIndex", "DenseIndex", "IVFFlatIndex",
    "make_dim_drop_scorer", "r_precision", "retrieved_relevant_counts",
    "topk_search",
]
