"""Dense retrieval substrate: exact & approximate top-k, metrics, sharding."""

from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFFlatIndex, IVFIndex
from repro.retrieval.rprecision import (make_dim_drop_scorer, r_precision,
                                        recall_at_k,
                                        retrieved_relevant_counts)
from repro.retrieval.scorers import (Scorer, backend_tail_stages, get_scorer,
                                     register_scorer, scorer_for_pipeline,
                                     scorer_names)
from repro.retrieval.sharded import ShardedCompressedIndex, ShardedIVFIndex
from repro.retrieval.topk import topk_search

__all__ = [
    "CompressedIndex", "DenseIndex", "IVFFlatIndex", "IVFIndex",
    "ShardedCompressedIndex", "ShardedIVFIndex",
    "Scorer", "backend_tail_stages", "get_scorer", "register_scorer",
    "scorer_for_pipeline", "scorer_names",
    "make_dim_drop_scorer", "r_precision", "recall_at_k",
    "retrieved_relevant_counts",
    "topk_search",
]
