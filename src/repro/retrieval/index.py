"""Dense and compressed KB indexes (single-host reference implementation).

:class:`DenseIndex` is the uncompressed baseline; :class:`CompressedIndex`
applies a fitted :class:`~repro.core.pipeline.CompressionPipeline` and stores
the *encoded* representation (fp16 / int8 / bit-packed words).  All scoring
dispatches through the pluggable :mod:`~repro.retrieval.scorers` backends —
the same objects that power the sharded path
(:mod:`repro.retrieval.sharded`) and the serving engine (:mod:`repro.serve`).

The quantized search path is jit-compiled end to end: float query stages,
query-side encoding, kernel scoring, and top-k all live in one traced graph,
so repeated calls pay no per-call Python dispatch or storage decode.
"""

from __future__ import annotations

import copy
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pipeline import CompressionPipeline
from repro.retrieval.scorers import (Scorer, apply_float_stages,
                                     scorer_for_pipeline)
from repro.retrieval.topk import resolve_k, topk_search


class DenseIndex:
    """Flat exact-search index over float vectors."""

    def __init__(self, docs: jax.Array, sim: str = "ip"):
        self.docs = jnp.asarray(docs)
        self.sim = sim
        self.spec = None               # set by api.build_index / api.load_index

    def __len__(self) -> int:
        return int(self.docs.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.docs.size * self.docs.dtype.itemsize)

    def search(self, queries: jax.Array, k: int,
               doc_chunk: int = 131072) -> tuple[jax.Array, jax.Array]:
        k = resolve_k(k, len(self))
        return topk_search(queries, self.docs, k, sim=self.sim,
                           doc_chunk=doc_chunk)

    def add(self, docs: jax.Array) -> "DenseIndex":
        self.docs = jnp.concatenate([self.docs, jnp.asarray(docs)], axis=0)
        return self

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        return {"docs": self.docs}

    def load_state_dict(self, sd: dict) -> "DenseIndex":
        self.docs = jnp.asarray(sd["docs"])
        return self

    def save(self, path: str) -> None:
        from repro.retrieval.api import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str) -> "DenseIndex":
        from repro.retrieval.api import load_index
        return load_index(path, expect=cls)


class CompressedIndex:
    """Thin orchestrator: float pipeline stages + a scorer backend.

    ``backend`` ∈ {"auto", "jnp", "pallas"}: which scoring path decodes the
    quantized storage.  "auto" uses Pallas kernels on TPU and the jnp oracle
    elsewhere (both produce identical rankings; see tests/test_kernels_*).
    """

    def __init__(self, pipeline: CompressionPipeline, sim: str = "ip",
                 backend: str = "auto"):
        self.pipeline = pipeline
        self.sim = sim
        self.backend = backend
        self.float_stages, self.scorer = scorer_for_pipeline(
            pipeline, sim=sim, backend=backend)
        self.storage: Optional[jax.Array] = None
        self.spec = None               # set by api.build_index / api.load_index
        self._n_docs = 0
        self._dim = 0
        self._version = 0      # bumped on add; to_ivf promotions check it
        self._decoded_cache: Optional[jax.Array] = None
        self._search_fn = None

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array, queries_sample: Optional[jax.Array],
              pipeline: CompressionPipeline, sim: str = "ip",
              backend: str = "auto", rng=None) -> "CompressedIndex":
        """Fit ``pipeline`` on the corpus, then encode it into an index.

        Note: prefer the declarative front door,
        :func:`repro.retrieval.api.build_index` — one entry point for every
        index kind, with save/load built in.  ``build`` stays supported for
        hand-assembled pipelines.
        """
        pipeline.fit(docs, queries_sample, rng=rng)
        idx = cls(pipeline, sim=sim, backend=backend)
        idx.add(docs)
        return idx

    def add(self, docs: jax.Array) -> "CompressedIndex":
        x = apply_float_stages(self.float_stages, docs, "docs")
        self._dim = int(x.shape[-1])
        enc = self.scorer.encode_docs(x)
        if self.storage is None:
            self.storage = enc
        else:
            self.storage = jnp.concatenate([self.storage, enc], axis=0)
        self._n_docs = int(self.storage.shape[0])
        self._version += 1
        self._decoded_cache = None     # storage changed: drop the float view
        return self

    def __len__(self) -> int:
        return self._n_docs

    @property
    def nbytes(self) -> int:
        assert self.storage is not None
        return int(self.storage.size * self.storage.dtype.itemsize)

    # -- search ------------------------------------------------------------
    def encode_queries(self, queries: jax.Array) -> jax.Array:
        """Queries through the float stages (no query-side quantization)."""
        return apply_float_stages(self.float_stages, queries, "queries")

    def decoded_docs(self) -> jax.Array:
        """Float view of the storage, decoded once and cached.

        For plain-float storage this *is* the storage; for fp16 the upcast
        is computed on first use and reused by every subsequent ``search``.
        Deliberate latency-for-memory trade: the cached f32 view lives
        alongside the fp16 storage (6 B/dim resident vs 2 B/dim stored) —
        ``nbytes`` reports the storage alone.
        """
        if type(self.scorer) is Scorer:
            return self.storage
        if self._decoded_cache is None:
            self._decoded_cache = self.scorer.decode(self.storage)
        return self._decoded_cache

    def _fused_search_fn(self):
        """jit'd end-to-end search: stages → encode → kernel scores → top-k."""
        if self._search_fn is None:
            stages = tuple(self.float_stages)
            scorer = self.scorer

            @functools.partial(jax.jit, static_argnames=("k",))
            def _search(queries, storage, params, *, k):
                q = queries
                for t in stages:
                    q = t(q, "queries")
                q = scorer.encode_queries(q)
                scores = scorer.scores(q, storage, params=params)
                return jax.lax.top_k(scores, k)

            self._search_fn = _search
        return self._search_fn

    def to_ivf(self, nlist: int = 200, nprobe: int = 100,
               docs: Optional[jax.Array] = None, kmeans_iters: int = 15,
               rng=None, train_size: int = 100_000):
        """Promote this index to approximate (IVF) search for free.

        The fitted pipeline, scorer backend, and encoded storage are shared
        with the returned :class:`~repro.retrieval.ivf.IVFIndex` — nothing
        is re-encoded.  The k-means router is fitted on the float *decode*
        of the stored representation (routing and scoring then agree on
        what the index actually contains); pass the original ``docs`` (same
        corpus, same order) to route on exact float vectors instead.
        """
        from repro.retrieval.ivf import IVFIndex

        if self.storage is None:
            raise ValueError("index is empty — add docs before to_ivf")
        ivf = IVFIndex(self.pipeline, nlist=nlist, nprobe=nprobe,
                       sim=self.sim, backend=self.backend,
                       kmeans_iters=kmeans_iters)
        # carry over the already-fitted stages and scorer state (recorded
        # dims/codebooks) rather than the fresh ones __init__ derived; the
        # scorer is deep-copied because encode_docs mutates it — a later
        # ivf.fit()/add() on a different corpus must not corrupt ours
        ivf.float_stages = self.float_stages
        ivf.scorer = copy.deepcopy(self.scorer)
        if docs is not None:
            x_route = apply_float_stages(self.float_stages, docs, "docs")
            if int(x_route.shape[0]) != self._n_docs:
                raise ValueError("docs must be the indexed corpus "
                                 f"({self._n_docs} rows), got "
                                 f"{int(x_route.shape[0])}")
        elif self.scorer.name in ("float", "fp16"):
            x_route = self.decoded_docs()   # exact search reuses this cache
        else:
            # int8/1-bit exact search never reads the float view — keep the
            # full-corpus decode a k-means-lifetime temporary, not a cache
            x_route = self.scorer.decode(self.storage)
        ivf._install(self.storage, x_route, rng=rng, train_size=train_size)
        # the promotion shares this index's storage: a later add() here
        # would silently miss from the IVF view, so pin our version and
        # let IVFIndex.search fail loudly instead
        ivf._source = (self, self._version)
        return ivf

    def search(self, queries: jax.Array, k: int,
               doc_chunk: int = 131072) -> tuple[jax.Array, jax.Array]:
        k = resolve_k(k, self._n_docs)
        if self.scorer.name not in ("float", "fp16"):
            # quantized storage: one fused graph, no host-side dispatch
            fn = self._fused_search_fn()
            return fn(jnp.asarray(queries), self.storage,
                      self.scorer.params(), k=k)
        # float / fp16 storage: stream the (cached) float view in chunks so
        # arbitrarily large indexes never materialise a full score matrix
        q = self.encode_queries(queries)
        return topk_search(q, self.decoded_docs(), k, sim=self.sim,
                           doc_chunk=doc_chunk)

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        """Everything needed to reconstruct searches without the corpus:
        pipeline state (incl. scorer codebooks), the encoded storage, and
        the bookkeeping counters."""
        return {"pipeline": self.pipeline.state_dict(),
                "storage": self.storage,
                "scorer_extra": self.scorer.extra_state(),
                "n_docs": self._n_docs, "dim": self._dim,
                "version": self._version}

    def load_state_dict(self, sd: dict) -> "CompressedIndex":
        self.pipeline.load_state_dict(sd["pipeline"])
        # the scorer holds the *same* quantizer object as the pipeline's
        # trailing stage, so its codebooks are now loaded too
        self.storage = jnp.asarray(sd["storage"])
        self.scorer.load_extra_state(sd.get("scorer_extra", {}))
        self._n_docs = int(sd["n_docs"])
        self._dim = int(sd["dim"])
        self._version = int(sd.get("version", 0))
        self._decoded_cache = None
        self._search_fn = None
        return self

    def save(self, path: str) -> None:
        from repro.retrieval.api import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str) -> "CompressedIndex":
        from repro.retrieval.api import load_index
        return load_index(path, expect=cls)
