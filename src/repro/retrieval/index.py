"""Dense and compressed KB indexes (single-host reference implementation).

:class:`DenseIndex` is the uncompressed baseline; :class:`CompressedIndex`
applies a fitted :class:`~repro.core.pipeline.CompressionPipeline` and stores
the *encoded* representation (fp16 / int8 / bit-packed words).  All scoring
dispatches through the pluggable :mod:`~repro.retrieval.scorers` backends —
the same objects that power the sharded path
(:mod:`repro.retrieval.sharded`) and the serving engine (:mod:`repro.serve`).

The quantized search path is jit-compiled end to end: float query stages,
query-side encoding, kernel scoring, and top-k all live in one traced graph,
so repeated calls pay no per-call Python dispatch or storage decode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CompressionPipeline
from repro.retrieval.scorers import (Scorer, apply_float_stages,
                                     scorer_for_pipeline)
from repro.retrieval.topk import topk_search


class DenseIndex:
    """Flat exact-search index over float vectors."""

    def __init__(self, docs: jax.Array, sim: str = "ip"):
        self.docs = jnp.asarray(docs)
        self.sim = sim

    def __len__(self) -> int:
        return int(self.docs.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.docs.size * self.docs.dtype.itemsize)

    def search(self, queries: jax.Array, k: int,
               doc_chunk: int = 131072) -> tuple[jax.Array, jax.Array]:
        return topk_search(queries, self.docs, k, sim=self.sim,
                           doc_chunk=doc_chunk)

    def add(self, docs: jax.Array) -> "DenseIndex":
        self.docs = jnp.concatenate([self.docs, jnp.asarray(docs)], axis=0)
        return self


class CompressedIndex:
    """Thin orchestrator: float pipeline stages + a scorer backend.

    ``backend`` ∈ {"auto", "jnp", "pallas"}: which scoring path decodes the
    quantized storage.  "auto" uses Pallas kernels on TPU and the jnp oracle
    elsewhere (both produce identical rankings; see tests/test_kernels_*).
    """

    def __init__(self, pipeline: CompressionPipeline, sim: str = "ip",
                 backend: str = "auto"):
        self.pipeline = pipeline
        self.sim = sim
        self.backend = backend
        self.float_stages, self.scorer = scorer_for_pipeline(
            pipeline, sim=sim, backend=backend)
        self.storage: Optional[jax.Array] = None
        self._n_docs = 0
        self._dim = 0
        self._decoded_cache: Optional[jax.Array] = None
        self._search_fn = None

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array, queries_sample: Optional[jax.Array],
              pipeline: CompressionPipeline, sim: str = "ip",
              backend: str = "auto", rng=None) -> "CompressedIndex":
        pipeline.fit(docs, queries_sample, rng=rng)
        idx = cls(pipeline, sim=sim, backend=backend)
        idx.add(docs)
        return idx

    def add(self, docs: jax.Array) -> "CompressedIndex":
        x = apply_float_stages(self.float_stages, docs, "docs")
        self._dim = int(x.shape[-1])
        enc = self.scorer.encode_docs(x)
        if self.storage is None:
            self.storage = enc
        else:
            self.storage = jnp.concatenate([self.storage, enc], axis=0)
        self._n_docs = int(self.storage.shape[0])
        self._decoded_cache = None     # storage changed: drop the float view
        return self

    def __len__(self) -> int:
        return self._n_docs

    @property
    def nbytes(self) -> int:
        assert self.storage is not None
        return int(self.storage.size * self.storage.dtype.itemsize)

    # -- search ------------------------------------------------------------
    def encode_queries(self, queries: jax.Array) -> jax.Array:
        """Queries through the float stages (no query-side quantization)."""
        return apply_float_stages(self.float_stages, queries, "queries")

    def decoded_docs(self) -> jax.Array:
        """Float view of the storage, decoded once and cached.

        For plain-float storage this *is* the storage; for fp16 the upcast
        is computed on first use and reused by every subsequent ``search``.
        Deliberate latency-for-memory trade: the cached f32 view lives
        alongside the fp16 storage (6 B/dim resident vs 2 B/dim stored) —
        ``nbytes`` reports the storage alone.
        """
        if type(self.scorer) is Scorer:
            return self.storage
        if self._decoded_cache is None:
            self._decoded_cache = self.scorer.decode(self.storage)
        return self._decoded_cache

    def _fused_search_fn(self):
        """jit'd end-to-end search: stages → encode → kernel scores → top-k."""
        if self._search_fn is None:
            stages = tuple(self.float_stages)
            scorer = self.scorer

            @functools.partial(jax.jit, static_argnames=("k",))
            def _search(queries, storage, params, *, k):
                q = queries
                for t in stages:
                    q = t(q, "queries")
                q = scorer.encode_queries(q)
                scores = scorer.scores(q, storage, params=params)
                return jax.lax.top_k(scores, k)

            self._search_fn = _search
        return self._search_fn

    def search(self, queries: jax.Array, k: int,
               doc_chunk: int = 131072) -> tuple[jax.Array, jax.Array]:
        if self.scorer.name not in ("float", "fp16"):
            # quantized storage: one fused graph, no host-side dispatch
            fn = self._fused_search_fn()
            return fn(jnp.asarray(queries), self.storage,
                      self.scorer.params(), k=min(k, self._n_docs))
        # float / fp16 storage: stream the (cached) float view in chunks so
        # arbitrarily large indexes never materialise a full score matrix
        q = self.encode_queries(queries)
        return topk_search(q, self.decoded_docs(), k, sim=self.sim,
                           doc_chunk=doc_chunk)
