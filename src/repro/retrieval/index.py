"""Dense and compressed KB indexes (single-host reference implementation).

:class:`DenseIndex` is the uncompressed baseline; :class:`CompressedIndex`
applies a fitted :class:`~repro.core.pipeline.CompressionPipeline` and stores
the *encoded* representation (fp16 / int8 / bit-packed words) — scoring then
runs through the matching kernel path (Pallas on TPU; jnp oracle on CPU).

The multi-pod sharded variant lives in :mod:`repro.retrieval.sharded`.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CompressionPipeline
from repro.core.quantization import Int8Quantizer, OneBitQuantizer, FloatCast
from repro.retrieval.topk import topk_search


class DenseIndex:
    """Flat exact-search index over float vectors."""

    def __init__(self, docs: jax.Array, sim: str = "ip"):
        self.docs = jnp.asarray(docs)
        self.sim = sim

    def __len__(self) -> int:
        return int(self.docs.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.docs.size * self.docs.dtype.itemsize)

    def search(self, queries: jax.Array, k: int,
               doc_chunk: int = 131072) -> tuple[jax.Array, jax.Array]:
        return topk_search(queries, self.docs, k, sim=self.sim,
                           doc_chunk=doc_chunk)

    def add(self, docs: jax.Array) -> "DenseIndex":
        self.docs = jnp.concatenate([self.docs, jnp.asarray(docs)], axis=0)
        return self


class CompressedIndex:
    """Index stored in compressed form; queries compressed at search time.

    ``backend`` ∈ {"auto", "jnp", "pallas"}: which scoring path decodes the
    quantized storage.  "auto" uses Pallas kernels on TPU and the jnp oracle
    elsewhere (both produce identical rankings; see tests/test_kernels_*).
    """

    def __init__(self, pipeline: CompressionPipeline, sim: str = "ip",
                 backend: str = "auto"):
        self.pipeline = pipeline
        self.sim = sim
        self.backend = backend
        self.storage: Optional[jax.Array] = None
        self._quantizer = None
        self._n_docs = 0
        self._dim = 0

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array, queries_sample: Optional[jax.Array],
              pipeline: CompressionPipeline, sim: str = "ip",
              backend: str = "auto", rng=None) -> "CompressedIndex":
        idx = cls(pipeline, sim=sim, backend=backend)
        pipeline.fit(docs, queries_sample, rng=rng)
        idx.add(docs)
        return idx

    def _split_pipeline(self):
        """Split transforms into (float stages, trailing quantizer|None)."""
        stages = self.pipeline.transforms
        if stages and isinstance(stages[-1],
                                 (Int8Quantizer, OneBitQuantizer, FloatCast)):
            return stages[:-1], stages[-1]
        return stages, None

    def add(self, docs: jax.Array) -> "CompressedIndex":
        float_stages, quantizer = self._split_pipeline()
        x = jnp.asarray(docs)
        for t in float_stages:
            x = t(x, "docs")
        self._dim = int(x.shape[-1])
        self._quantizer = quantizer
        enc = quantizer.encode(x, "docs") if quantizer is not None else x
        if self.storage is None:
            self.storage = enc
        else:
            self.storage = jnp.concatenate([self.storage, enc], axis=0)
        self._n_docs = int(self.storage.shape[0])
        return self

    def __len__(self) -> int:
        return self._n_docs

    @property
    def nbytes(self) -> int:
        assert self.storage is not None
        return int(self.storage.size * self.storage.dtype.itemsize)

    # -- search ------------------------------------------------------------
    def _use_pallas(self) -> bool:
        if self.backend == "pallas":
            return True
        if self.backend == "jnp":
            return False
        return jax.default_backend() == "tpu"

    def encode_queries(self, queries: jax.Array) -> jax.Array:
        float_stages, _ = self._split_pipeline()
        q = jnp.asarray(queries)
        for t in float_stages:
            q = t(q, "queries")
        return q

    def search(self, queries: jax.Array, k: int,
               doc_chunk: int = 131072) -> tuple[jax.Array, jax.Array]:
        q = self.encode_queries(queries)
        quantizer = self._quantizer
        if quantizer is None:
            return topk_search(q, self.storage, k, sim=self.sim,
                               doc_chunk=doc_chunk)
        if isinstance(quantizer, OneBitQuantizer):
            from repro.kernels.binary_ip import ops as binary_ops
            q_enc = quantizer(q, "queries")  # ±offset float; sim reduces to IP
            scores = binary_ops.binary_ip_scores(
                q_enc, self.storage, self._dim,
                offset=quantizer.offset,
                use_pallas=self._use_pallas())
            kk = min(k, self._n_docs)
            return jax.lax.top_k(scores, kk)
        if isinstance(quantizer, Int8Quantizer):
            from repro.kernels.int8_ip import ops as int8_ops
            scores = int8_ops.int8_scores(
                q, self.storage,
                scale=quantizer.state["scale"], zero=quantizer.state["zero"],
                sim=self.sim, use_pallas=self._use_pallas())
            kk = min(k, self._n_docs)
            return jax.lax.top_k(scores, kk)
        # FloatCast: decode is a dtype view; score directly
        docs = quantizer.decode(self.storage)
        return topk_search(q, docs, k, sim=self.sim, doc_chunk=doc_chunk)
