"""Scorer backends: one scoring code path from kernel to fleet.

A :class:`Scorer` owns the *storage representation* of an index (float /
fp16 / int8 codes / bit-packed words) and knows how to score float queries
against it through the matching kernel path (Pallas on TPU, jnp oracle
elsewhere).  :class:`~repro.retrieval.index.CompressedIndex`,
:class:`~repro.retrieval.sharded.ShardedCompressedIndex`, and
:mod:`repro.serve` all dispatch through the same scorer objects, so the
quantized kernels serve single-host, sharded, and streaming-request
workloads identically.

Design contract (everything shard_map / jit needs):

* ``encode_docs(x)`` / ``encode_queries(q)`` — storage resp. query-side
  representation.  ``x``/``q`` have already passed through the pipeline's
  *float* stages; the scorer handles only the final precision step.
* ``params()`` — the jnp arrays scoring depends on (quantizer codebooks).
  Passed explicitly through ``shard_map`` so nothing is closed over.
* ``scores(q, storage, params=None)`` — dense (Q, D) similarity.  Pure and
  traceable: safe to call under ``jit`` and inside ``shard_map`` on a
  storage *shard*.
* ``decode(storage)`` — float view of the storage (shadow scoring,
  fallback paths).

Scorers are selected from a pipeline's trailing quantizer via
:func:`scorer_for_pipeline` (or by name via :func:`get_scorer`).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.pipeline import CompressionPipeline
from repro.core.preprocess import Transform
from repro.core.quantization import FloatCast, Int8Quantizer, OneBitQuantizer
from repro.retrieval.topk import similarity


def _resolve_pallas(backend: str) -> bool:
    """backend ∈ {"auto", "jnp", "pallas"} → use the Pallas kernel path?"""
    if backend == "pallas":
        return True
    if backend == "jnp":
        return False
    if backend == "auto":
        return jax.default_backend() == "tpu"
    raise ValueError(f"unknown backend {backend!r}")


class Scorer:
    """Base scorer: float storage, plain GEMM similarity."""

    name = "float"

    def __init__(self, sim: str = "ip", backend: str = "auto"):
        self.sim = sim
        self.backend = backend

    @property
    def use_pallas(self) -> bool:
        return _resolve_pallas(self.backend)

    # -- encoding ---------------------------------------------------------
    def encode_docs(self, x: jax.Array) -> jax.Array:
        return x

    def encode_queries(self, q: jax.Array) -> jax.Array:
        return q

    # -- scoring ----------------------------------------------------------
    def params(self) -> dict[str, jax.Array]:
        """Arrays ``scores`` reads — threaded through shard_map explicitly."""
        return {}

    def scores(self, q: jax.Array, storage: jax.Array,
               params: Optional[dict] = None) -> jax.Array:
        return similarity(q, storage, self.sim)

    def scores_gathered(self, q: jax.Array, gathered: jax.Array,
                        params: Optional[dict] = None) -> jax.Array:
        """Per-query candidate scoring: (Q, d) × (Q, C, w) → (Q, C).

        The IVF path gathers each query's probed inverted lists into its own
        candidate block; scoring vmaps the backend's regular ``scores``
        kernel over the query axis, so every storage format reuses the same
        kernel code for approximate search.  Pure and traceable, like
        ``scores``.
        """
        p = params if params is not None else self.params()

        def _one(qi, gi):
            return self.scores(qi[None, :], gi, params=p)[0]

        return jax.vmap(_one)(q, gathered)

    # -- persistence -------------------------------------------------------
    def extra_state(self) -> dict:
        """Scorer-owned scalars outside the quantizer's state (artifact
        format; codebooks live in the pipeline's stage state already)."""
        return {}

    def load_extra_state(self, sd: dict) -> None:
        pass

    # -- float view -------------------------------------------------------
    def decode(self, storage: jax.Array) -> jax.Array:
        return storage

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}(sim={self.sim!r}, backend={self.backend!r})"


class FloatCastScorer(Scorer):
    """fp16/bf16 storage; scoring upcasts once (callers cache the view)."""

    name = "fp16"

    def __init__(self, quantizer: FloatCast, sim: str = "ip",
                 backend: str = "auto"):
        super().__init__(sim=sim, backend=backend)
        self.quantizer = quantizer

    def encode_docs(self, x):
        return self.quantizer.encode(x, "docs")

    def scores(self, q, storage, params=None):
        return similarity(q, self.quantizer.decode(storage), self.sim)

    def decode(self, storage):
        return self.quantizer.decode(storage)


class Int8Scorer(Scorer):
    """uint8 codes; affine decode folded into the int8 IP kernel."""

    name = "int8"

    def __init__(self, quantizer: Int8Quantizer, sim: str = "ip",
                 backend: str = "auto"):
        super().__init__(sim=sim, backend=backend)
        self.quantizer = quantizer

    def encode_docs(self, x):
        return self.quantizer.encode(x, "docs")

    def params(self):
        return {"scale": self.quantizer.state["scale"],
                "zero": self.quantizer.state["zero"]}

    def scores(self, q, storage, params=None):
        from repro.kernels.int8_ip import ops as int8_ops
        p = params if params is not None else self.params()
        return int8_ops.int8_scores(q, storage, scale=p["scale"],
                                    zero=p["zero"], sim=self.sim,
                                    use_pallas=self.use_pallas)

    def decode(self, storage):
        return self.quantizer.decode(storage)


class OneBitScorer(Scorer):
    """Bit-packed uint32 storage; sign-matmul kernel scoring.

    ``dim`` is the logical (unpadded) float dimensionality — needed because
    the packed words round it up to a multiple of 32.  It is recorded at
    ``encode_docs`` time and must be set before scoring raw storage.
    """

    name = "onebit"

    def __init__(self, quantizer: OneBitQuantizer, sim: str = "ip",
                 backend: str = "auto", dim: Optional[int] = None):
        super().__init__(sim=sim, backend=backend)
        self.quantizer = quantizer
        self.dim = dim

    def extra_state(self):
        return {"dim": self.dim}

    def load_extra_state(self, sd):
        if sd.get("dim") is not None:
            self.dim = int(sd["dim"])

    def encode_docs(self, x):
        self.dim = int(x.shape[-1])
        return self.quantizer.encode(x, "docs")

    def encode_queries(self, q):
        # offset-encoded floats: only signs reach the kernel, the offset
        # correction is applied analytically inside binary_ip_scores.
        return self.quantizer(q, "queries")

    def scores(self, q, storage, params=None):
        from repro.kernels.binary_ip import ops as binary_ops
        if self.dim is None:
            raise ValueError("OneBitScorer.dim unset — encode_docs first or "
                             "pass dim= at construction")
        return binary_ops.binary_ip_scores(
            q, storage, self.dim, offset=self.quantizer.offset,
            use_pallas=self.use_pallas)

    def decode(self, storage):
        return self.quantizer.decode(storage, self.dim)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# quantizer class → scorer factory.  Extend with register_scorer().
_SCORER_FOR_QUANTIZER: dict[type, Callable[..., Scorer]] = {}
_SCORER_BY_NAME: dict[str, Callable[..., Scorer]] = {}


def register_scorer(name: str, quantizer_cls: Optional[type],
                    factory: Callable[..., Scorer]) -> None:
    """Register a scorer backend under ``name`` (and its quantizer class).

    ``factory(quantizer, sim=..., backend=...) → Scorer``; for quantizer-less
    backends (plain float) the quantizer argument is None.
    """
    _SCORER_BY_NAME[name] = factory
    if quantizer_cls is not None:
        _SCORER_FOR_QUANTIZER[quantizer_cls] = factory


register_scorer("float", None,
                lambda quantizer=None, **kw: Scorer(**kw))
register_scorer("fp16", FloatCast,
                lambda quantizer=None, **kw: FloatCastScorer(
                    quantizer or FloatCast(), **kw))
register_scorer("int8", Int8Quantizer,
                lambda quantizer=None, **kw: Int8Scorer(
                    quantizer or Int8Quantizer(), **kw))
register_scorer("onebit", OneBitQuantizer,
                lambda quantizer=None, **kw: OneBitScorer(
                    quantizer or OneBitQuantizer(), **kw))


def scorer_names() -> tuple[str, ...]:
    return tuple(_SCORER_BY_NAME)


def backend_tail_stages() -> dict[str, list[Transform]]:
    """Canonical {backend name: trailing pipeline stages} sweep table.

    One place for tests and benchmarks that cover every scorer backend;
    stages are stateful once fitted, so each call returns fresh instances
    (never share them across pipelines).
    """
    return {"float": [], "fp16": [FloatCast()],
            "int8": [Int8Quantizer()], "onebit": [OneBitQuantizer(0.5)]}


def get_scorer(name: str, quantizer: Optional[Transform] = None,
               sim: str = "ip", backend: str = "auto") -> Scorer:
    if name not in _SCORER_BY_NAME:
        raise KeyError(f"unknown scorer {name!r}; have {scorer_names()}")
    return _SCORER_BY_NAME[name](quantizer, sim=sim, backend=backend)


def apply_float_stages(stages, x: jax.Array, kind: str) -> jax.Array:
    """Run docs/queries through a pipeline's float stages (shared by the
    single-host index, the sharded index, and the shadow scorer — one
    definition so the three paths can never diverge)."""
    x = jnp.asarray(x)
    for t in stages:
        x = t(x, kind)
    return x


def _factory_for(quantizer: Transform) -> Optional[Callable[..., Scorer]]:
    factory = _SCORER_FOR_QUANTIZER.get(type(quantizer))
    if factory is not None:
        return factory
    for cls, factory in _SCORER_FOR_QUANTIZER.items():
        if isinstance(quantizer, cls):
            return factory
    return None


def split_pipeline(pipeline: CompressionPipeline
                   ) -> tuple[list[Transform], Optional[Transform]]:
    """Split transforms into (float stages, trailing quantizer|None)."""
    stages = list(pipeline.transforms)
    if stages and _factory_for(stages[-1]) is not None:
        return stages[:-1], stages[-1]
    return stages, None


def scorer_for_pipeline(pipeline: CompressionPipeline, sim: str = "ip",
                        backend: str = "auto"
                        ) -> tuple[list[Transform], Scorer]:
    """(float stages, scorer) for a pipeline's storage representation."""
    float_stages, quantizer = split_pipeline(pipeline)
    if quantizer is None:
        return float_stages, Scorer(sim=sim, backend=backend)
    return float_stages, _factory_for(quantizer)(quantizer, sim=sim,
                                                 backend=backend)
