"""Mutable-index subsystem: delta segments + tombstones over a frozen main.

The paper compresses a *static* KB; production knowledge bases churn.
:class:`SegmentedIndex` makes any index — single-host or sharded over a
mesh — mutable without ever re-fitting the compression pipeline:

* **Delta segments** — ``add(docs)`` encodes the new rows through the
  *frozen* fitted pipeline (same float stages, same scorer backend, same
  codebooks as the main index) into a small append-only segment.  Search
  scores every delta row with the same scorer kernels as the main index
  and merges the layers with the one strict ``(score desc, id asc)`` tie
  order (:func:`repro.retrieval.topk.masked_topk_by_id`), so a segmented
  search ranks bit-identically to a single index holding the same rows.
* **Tombstones** — ``delete(ids)`` marks global doc ids dead.  Dead rows
  are masked out of every layer at search time; the main layer is probed
  ``k + #dead(main)`` deep so the surviving top-k is exactly the top-k of
  a freshly built index over the surviving corpus.
* **Global doc ids** — a monotonic allocator assigns each added row an id
  that survives compaction (results keep meaning the same documents
  across a hot-swap).  ``search`` returns these global ids, never raw
  storage positions.
* **IVF mains** — added rows are routed to the *existing* centroids at
  ``add`` time (the label is stored per delta row) and a delta row only
  competes when its list is probed, so segmented IVF search reproduces
  exactly what one IVF index with the same centroids over all rows would
  return.
* **Drift monitor** — the fitted pipeline is frozen, so incrementally
  added docs encoded through it must be *watched*, not trusted: a
  :class:`DriftMonitor` tracks running mean/norm statistics of added docs
  against the pipeline's fitted centering statistics, and
  :meth:`SegmentedIndex.needs_compaction` turns drift (or a fat delta /
  tombstone fraction) into a compaction trigger.
* **Compaction** — :meth:`compact` folds segments + tombstones into a
  fresh main index (storage rows are *moved*, never re-encoded; IVF mains
  refit only the cheap k-means router on the decoded storage) and returns
  a new :class:`SegmentedIndex` with the same global ids, ready to be
  staged → canaried → promoted through
  :class:`repro.serve.service.RetrievalService` while the old index keeps
  serving.

Concurrency: mutation (``add``/``delete``) swaps an immutable snapshot
under a lock; ``search`` reads one snapshot reference and never blocks,
so a background drain loop keeps serving while updates land.
"""

from __future__ import annotations

import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFFlatIndex, IVFIndex
from repro.retrieval.kmeans import assign
from repro.retrieval.scorers import Scorer, apply_float_stages
from repro.retrieval.sharded import ShardedCompressedIndex, ShardedIVFIndex
from repro.retrieval.topk import masked_topk_by_id, resolve_k, similarity

#: mains whose storage fans out over a mesh — the delta layer stays
#: host-side (deltas are small by the compaction contract) and scores
#: through the same scorer, so the cross-layer merge is bit-comparable
_SHARDED_MAINS = (ShardedCompressedIndex, ShardedIVFIndex)


def fitted_center_mean(pipeline) -> Optional[np.ndarray]:
    """The doc-side mean of the pipeline's first fitted centering stage.

    This is the reference the drift monitor compares added docs against:
    the paper's key practical finding is that retrieval quality hinges on
    centering/normalization statistics, so docs drifting away from the
    fitted mean are exactly the ones a frozen pipeline encodes worst.
    """
    if pipeline is None:
        return None
    for t in getattr(pipeline, "transforms", []):
        if t.fitted and "mean_docs" in t.state:
            return np.asarray(t.state["mean_docs"], np.float64)
    return None


class DriftMonitor:
    """Running mean/norm statistics of added docs vs. the fitted center.

    ``mean_shift`` is the L2 distance between the running mean of every
    doc added since the last compaction and the pipeline's fitted doc
    mean, normalised by the mean row norm of the added docs — ~0 when the
    additions come from the fitted distribution, growing toward 1 as they
    drift to a different region of embedding space.
    """

    def __init__(self, ref_mean: Optional[np.ndarray] = None):
        self.ref_mean = (np.asarray(ref_mean, np.float64)
                         if ref_mean is not None else None)
        self.n_added = 0
        self._sum: Optional[np.ndarray] = None
        self._norm_sum = 0.0

    def update(self, docs: np.ndarray) -> None:
        x = np.asarray(docs, np.float64)
        if x.ndim != 2 or x.shape[0] == 0:
            return
        s = x.sum(axis=0)
        self._sum = s if self._sum is None else self._sum + s
        self._norm_sum += float(np.linalg.norm(x, axis=1).sum())
        self.n_added += int(x.shape[0])

    @property
    def mean_shift(self) -> float:
        if self.n_added == 0:
            return 0.0
        mean = self._sum / self.n_added
        ref = (self.ref_mean if self.ref_mean is not None
               else np.zeros_like(mean))
        scale = self._norm_sum / self.n_added + 1e-12
        return float(np.linalg.norm(mean - ref) / scale)

    def stats(self) -> dict:
        return {
            "n_added": self.n_added,
            "mean_norm": (self._norm_sum / self.n_added
                          if self.n_added else float("nan")),
            "ref_norm": (float(np.linalg.norm(self.ref_mean))
                         if self.ref_mean is not None else None),
            "mean_shift": self.mean_shift,
        }

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        return {"n_added": self.n_added,
                "sum": self._sum, "norm_sum": self._norm_sum}

    def load_state_dict(self, sd: dict) -> "DriftMonitor":
        self.n_added = int(sd["n_added"])
        self._sum = (np.asarray(sd["sum"], np.float64)
                     if sd.get("sum") is not None else None)
        self._norm_sum = float(sd["norm_sum"])
        return self


class _Segment:
    """One append-only delta: scorer-encoded rows + their global ids."""

    __slots__ = ("storage", "gids", "labels")

    def __init__(self, storage: jax.Array, gids: np.ndarray,
                 labels: Optional[np.ndarray]):
        self.storage = storage
        self.gids = gids
        self.labels = labels

    def __len__(self) -> int:
        return int(self.gids.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.storage.size * self.storage.dtype.itemsize)


class _Snapshot:
    """Immutable view the search path binds to (mutations swap a new one)."""

    __slots__ = ("segments", "tomb", "next_gid", "n_live", "n_main_dead",
                 "_delta", "_tomb_j")

    def __init__(self, segments: tuple, tomb: np.ndarray, next_gid: int,
                 n_live: int, n_main_dead: int):
        self.segments = segments
        self.tomb = tomb                    # bool over the whole gid space
        self.next_gid = next_gid
        self.n_live = n_live
        self.n_main_dead = n_main_dead
        self._delta = None                  # lazy concat of all segments
        self._tomb_j = None

    @property
    def n_delta(self) -> int:
        return sum(len(s) for s in self.segments)

    def tomb_j(self) -> jax.Array:
        if self._tomb_j is None:
            self._tomb_j = jnp.asarray(self.tomb)
        return self._tomb_j

    def delta(self):
        """(storage, gids_np, gids_j, labels_j|None) across all segments."""
        if self._delta is None:
            storage = jnp.concatenate([s.storage for s in self.segments],
                                      axis=0)
            gids = np.concatenate([s.gids for s in self.segments])
            labels = None
            if self.segments[0].labels is not None:
                labels = jnp.asarray(
                    np.concatenate([s.labels for s in self.segments]))
            self._delta = (storage, gids, jnp.asarray(gids), labels)
        return self._delta


class SegmentedIndex:
    """Delta segments + tombstones layered over an immutable main index.

    ``main`` is any index whose pipeline is already fitted
    (:class:`DenseIndex`, :class:`CompressedIndex`, :class:`IVFIndex` /
    :class:`IVFFlatIndex`, or the sharded wrappers
    :class:`~repro.retrieval.sharded.ShardedCompressedIndex` /
    :class:`~repro.retrieval.sharded.ShardedIVFIndex`); its storage is
    adopted as the base layer and never touched again.  With a sharded
    main the delta layer stays host-side — deltas are small by the
    compaction contract — and compaction folds on the host, then
    re-shards the folded main over the same mesh in one step.
    """

    def __init__(self, main, *, spec=None, drift_threshold: float = 0.35,
                 max_delta_fraction: float = 0.25):
        if isinstance(main, SegmentedIndex):
            raise TypeError("SegmentedIndex cannot wrap another "
                            "SegmentedIndex")
        if not isinstance(main, (DenseIndex, CompressedIndex, IVFIndex)
                          + _SHARDED_MAINS):
            raise TypeError(
                f"SegmentedIndex cannot wrap a {type(main).__name__} — "
                "mains are Dense/Compressed/IVF indexes or their sharded "
                "wrappers")
        if len(main) == 0:
            raise ValueError("main index is empty — build it first")
        if getattr(main, "residual", False):
            raise TypeError(
                "SegmentedIndex cannot wrap a residual-encoded IVF main: "
                "delta rows are encoded without the routed-centroid "
                "subtraction, so cross-layer scores would not be "
                "comparable — build the main with residual=False")
        self.main = main
        self._sharded = isinstance(main, _SHARDED_MAINS)
        # the single-host core the compaction machinery folds: the wrapped
        # IVFIndex for a sharded IVF main, the main itself otherwise
        self._core = main.ivf if isinstance(main, ShardedIVFIndex) else main
        self.spec = getattr(main, "spec", None) if spec is None else spec
        self.sim = main.sim
        self.drift_threshold = float(drift_threshold)
        self.max_delta_fraction = float(max_delta_fraction)
        if isinstance(main, DenseIndex):
            self.float_stages: list = []
            self.scorer = Scorer(sim=main.sim, backend="jnp")
            pipeline = None
        else:
            self.float_stages = main.float_stages
            self.scorer = main.scorer
            pipeline = main.pipeline
        self.drift = DriftMonitor(fitted_center_mean(pipeline))
        self._is_ivf = isinstance(main, (IVFIndex, ShardedIVFIndex))
        self._main_version = getattr(main, "_version", None)
        n_main = len(main)
        self._main_gids = np.arange(n_main, dtype=np.int32)
        self._main_gids_j: Optional[jax.Array] = None
        self._lock = threading.Lock()
        self._state = _Snapshot(segments=(),
                                tomb=np.zeros(n_main, bool),
                                next_gid=n_main, n_live=n_main,
                                n_main_dead=0)

    # -- internal: adopt a post-compaction / loaded identity ---------------
    def _restore(self, *, main_gids: np.ndarray, tomb: np.ndarray,
                 next_gid: int, segments: tuple = (),
                 drift_sd: Optional[dict] = None) -> "SegmentedIndex":
        assert len(main_gids) == len(self.main)
        self._main_gids = np.asarray(main_gids, np.int32)
        self._main_gids_j = None
        segments = tuple(segments)
        tomb = np.asarray(tomb, bool)
        n_main_dead = int(tomb[self._main_gids].sum())
        n_dead = n_main_dead + sum(int(tomb[s.gids].sum())
                                   for s in segments)
        n_delta = sum(len(s) for s in segments)
        self._state = _Snapshot(segments, tomb, int(next_gid),
                                len(self.main) + n_delta - n_dead,
                                n_main_dead)
        if drift_sd is not None:
            self.drift.load_state_dict(drift_sd)
        return self

    # -- sizing ------------------------------------------------------------
    def __len__(self) -> int:
        """Live (searchable) docs: main + deltas − tombstones."""
        return self._state.n_live

    @property
    def n_deltas(self) -> int:
        return self._state.n_delta

    @property
    def n_segments(self) -> int:
        return len(self._state.segments)

    @property
    def n_tombstoned(self) -> int:
        st = self._state
        return len(self.main) + st.n_delta - st.n_live

    @property
    def next_gid(self) -> int:
        return self._state.next_gid

    @property
    def nbytes(self) -> int:
        st = self._state
        return self.main.nbytes + sum(s.nbytes for s in st.segments)

    @property
    def nprobe(self) -> Optional[int]:
        """Probe width of an IVF main (None otherwise) — lets the serving
        engine accept per-request ``nprobe`` overrides transparently."""
        return self.main.nprobe if self._is_ivf else None

    # -- mutation ----------------------------------------------------------
    def add(self, docs: jax.Array) -> "SegmentedIndex":
        """Append docs as a new delta segment (frozen-pipeline encode).

        Rows get fresh global ids from the monotonic allocator; for IVF
        mains each row is routed to the existing centroids and only
        competes when its list is probed — identical reachability to docs
        that were in the corpus at fit time.
        """
        docs = jnp.asarray(docs)
        if docs.ndim != 2 or docs.shape[0] == 0:
            raise ValueError("add needs a (n ≥ 1, d) doc block, got shape "
                             f"{docs.shape}")
        x = apply_float_stages(self.float_stages, docs, "docs")
        enc = self.scorer.encode_docs(x)
        labels = None
        if self._is_ivf:
            labels = np.asarray(assign(jnp.asarray(x, jnp.float32),
                                       self.main.centroids)).astype(np.int32)
        n = int(enc.shape[0])
        with self._lock:
            st = self._state
            gids = np.arange(st.next_gid, st.next_gid + n, dtype=np.int32)
            seg = _Segment(enc, gids, labels)
            tomb = np.concatenate([st.tomb, np.zeros(n, bool)])
            self.drift.update(np.asarray(docs))
            self._state = _Snapshot(st.segments + (seg,), tomb,
                                    st.next_gid + n, st.n_live + n,
                                    st.n_main_dead)
        store = getattr(self.main, "store", None)
        if labels is not None and store is not None:
            # a delta row competes whenever its routed list is probed — pin
            # those lists so merging main + delta never takes a cold miss
            store.pin(np.unique(labels).tolist())
        return self

    def validate_ids(self, ids: Sequence[int],
                     n_pending_add: int = 0) -> np.ndarray:
        """Normalise a delete-id list and bounds-check it, mutating nothing.

        Returns the unique sorted ids; raises ``KeyError`` for ids the
        allocator never handed out.  Callers composing add+delete use this
        to validate *before* the add lands, keeping the pair atomic —
        ``n_pending_add`` extends the bound over the ids the pending add
        block is about to be assigned, so deleting a doc from the same
        update call stays legal.
        """
        ids = np.unique(np.asarray(ids, np.int64).ravel())
        bound = self._state.next_gid + int(n_pending_add)
        if ids.size and (ids[0] < 0 or ids[-1] >= bound):
            bad = ids[(ids < 0) | (ids >= bound)]
            raise KeyError(f"unknown doc ids {bad.tolist()[:8]} "
                           f"(allocator is at {bound})")
        return ids

    def delete(self, ids: Sequence[int]) -> int:
        """Tombstone global doc ids; returns how many were newly deleted.

        Unknown ids (never allocated) raise ``KeyError``; deleting an
        already-dead id is a no-op (idempotent), so replaying a delete
        log is safe.
        """
        with self._lock:
            ids = self.validate_ids(ids)
            if ids.size == 0:
                return 0
            st = self._state
            newly = ids[~st.tomb[ids]]
            if newly.size == 0:
                return 0
            tomb = st.tomb.copy()
            tomb[newly] = True
            n_main_dead = int(tomb[self._main_gids].sum())
            new = _Snapshot(st.segments, tomb, st.next_gid,
                            st.n_live - int(newly.size), n_main_dead)
            # segments are unchanged: the concatenated delta view (and its
            # device copy) carries over — deletes stay O(tombstones), not
            # O(delta bytes), on the serving path
            new._delta = st._delta
            self._state = new
            return int(newly.size)

    # -- search ------------------------------------------------------------
    def _main_gids_device(self) -> jax.Array:
        if self._main_gids_j is None:
            self._main_gids_j = jnp.asarray(self._main_gids)
        return self._main_gids_j

    def search(self, queries: jax.Array, k: int,
               nprobe: Optional[int] = None
               ) -> tuple[jax.Array, jax.Array]:
        """Top-``min(k, live docs)`` across main + delta layers.

        Returns ``(scores, global ids)`` ranked by the strict
        ``(score desc, id asc)`` order; tombstoned rows never appear.
        ``nprobe`` overrides the probe width when the main is IVF (the
        same width gates which delta rows are reachable).
        """
        if self._main_version is not None and \
                getattr(self.main, "_version", None) != self._main_version:
            raise ValueError(
                "main index changed under the SegmentedIndex (add/fit was "
                "called on it directly); mutate through the SegmentedIndex "
                "only")
        st = self._state
        queries = jnp.asarray(queries)
        k_eff = resolve_k(k, st.n_live)
        gid_map = self._main_gids_device()

        nprobe_r = None
        if self._is_ivf:
            nprobe_r = self.main._resolve_nprobe(nprobe)
        elif nprobe is not None:
            raise ValueError("per-request nprobe needs an IVF main; "
                             f"{type(self.main).__name__} has none")

        # main layer: probe deep enough that tombstones cannot crowd the
        # surviving top-k out of the candidate set
        k_main = min(k_eff + st.n_main_dead, len(self.main))
        if self._is_ivf:
            vals_m, pos_m = self.main.search(queries, k_main,
                                             nprobe=nprobe_r)
        else:
            vals_m, pos_m = self.main.search(queries, k_main)
        gids_m = jnp.where(pos_m >= 0, gid_map[jnp.maximum(pos_m, 0)], -1)

        if not st.segments and st.n_main_dead == 0:
            return vals_m, gids_m          # fast path: nothing layered yet

        tomb_j = st.tomb_j()
        dead_m = jnp.where(gids_m >= 0, tomb_j[jnp.maximum(gids_m, 0)],
                           False)
        vals_m = jnp.where(dead_m, -jnp.inf, vals_m)
        gids_m = jnp.where(dead_m, -1, gids_m)

        if st.segments:
            storage_d, _, gids_dj, labels_d = st.delta()
            q_f = apply_float_stages(self.float_stages, queries, "queries")
            q_e = self.scorer.encode_queries(q_f)
            vals_d = self.scorer.scores(q_e, storage_d,
                                        params=self.scorer.params())
            if self._is_ivf:
                # same coarse routing as the main layer: a delta row only
                # competes when the list it was assigned to is probed
                cs = similarity(q_f, self.main.centroids, self.sim)
                _, probes = jax.lax.top_k(cs, nprobe_r)
                probed = jnp.any(probes[:, :, None] ==
                                 labels_d[None, None, :], axis=1)
                vals_d = jnp.where(probed, vals_d, -jnp.inf)
            dead_d = tomb_j[gids_dj]
            vals_d = jnp.where(dead_d[None, :], -jnp.inf, vals_d)
            ids_d = jnp.broadcast_to(gids_dj[None, :],
                                     (queries.shape[0], gids_dj.shape[0]))
            vals = jnp.concatenate([vals_m, vals_d], axis=1)
            ids = jnp.concatenate([gids_m, ids_d], axis=1)
        else:
            vals, ids = vals_m, gids_m
        return masked_topk_by_id(vals, ids, k_eff)

    def prefetch(self, queries: jax.Array,
                 nprobe: Optional[int] = None) -> int:
        """Warm a store-backed IVF main's hot tier with the probe table
        for ``queries``; returns lists touched (0 when fully resident)."""
        if not self._is_ivf:
            return 0
        return self.main.prefetch(queries, nprobe=nprobe)

    # -- drift / compaction policy ----------------------------------------
    def needs_compaction(self) -> bool:
        """Fold time?  True when the delta or tombstone fraction outgrows
        ``max_delta_fraction``, or added docs drifted past
        ``drift_threshold`` from the pipeline's fitted centering stats."""
        st = self._state
        total = len(self.main) + st.n_delta
        if st.n_delta > self.max_delta_fraction * total:
            return True
        if (total - st.n_live) > self.max_delta_fraction * total:
            return True
        return self.drift.mean_shift > self.drift_threshold

    def mutable_stats(self) -> dict:
        """Snapshot for ``RetrievalService.stats()`` and dashboards."""
        st = self._state
        return {
            "n_live": st.n_live,
            "n_main": len(self.main),
            "n_delta": st.n_delta,
            "segments": len(st.segments),
            "tombstones": len(self.main) + st.n_delta - st.n_live,
            "next_gid": st.next_gid,
            "drift": self.drift.stats(),
            "needs_compaction": self.needs_compaction(),
        }

    def place(self) -> "SegmentedIndex":
        """Force the main's mesh placement now (no-op for single-host
        mains) — the serving layer's all-or-none staging hook."""
        fn = getattr(self.main, "place", None)
        if fn is not None:
            fn()
        return self

    def shard_stats(self) -> Optional[list]:
        """Per-shard rollup when the main is sharded (None otherwise):
        the main's own rollup plus how many live delta rows would fold
        into each shard's lists (routed label → owning shard)."""
        fn = getattr(self.main, "shard_stats", None)
        if fn is None:
            return None
        rows = fn()
        for r in rows:
            r["n_delta"] = 0
        st = self._state
        owner = getattr(self.main, "list_owner", None)
        if owner is not None and st.segments:
            labels = np.concatenate([s.labels for s in st.segments])
            gids = np.concatenate([s.gids for s in st.segments])
            counts = np.bincount(owner[labels[~st.tomb[gids]]],
                                 minlength=len(rows))
            for r in rows:
                r["n_delta"] = int(counts[r["shard"]])
        return rows

    # -- compaction --------------------------------------------------------
    def _main_storage(self) -> jax.Array:
        if isinstance(self.main, DenseIndex):
            return self.main.docs
        return self.main.storage

    def _iter_folded_lists(self, st: _Snapshot):
        """List-major fold stream for IVF compaction.

        Yields ``(lid, rows, new_ids, gids)`` per inverted list in list
        order: the list's alive main rows (storage-position order)
        followed by its alive delta rows (segment order), with ``new_ids``
        the sequential row positions of the folded index.  Works off
        either a resident main or its store (one list materialised at a
        time — the whole main is never decoded or concatenated).
        """
        main = self.main
        tomb = st.tomb
        if st.segments:
            d_rows = np.concatenate(
                [np.asarray(s.storage) for s in st.segments])
            d_gids = np.concatenate([s.gids for s in st.segments])
            d_labels = np.concatenate([s.labels for s in st.segments])
            alive_d = ~tomb[d_gids]
            order = np.argsort(d_labels[alive_d], kind="stable")
            d_rows = d_rows[alive_d][order]
            d_gids = d_gids[alive_d][order]
            d_labels = d_labels[alive_d][order]
        else:
            d_labels = np.zeros(0, np.int32)
            d_rows = d_gids = None
        if main.store is not None:
            main_iter = main.store.iter_lists()
        else:
            lists_np = np.asarray(main.lists)
            storage_np = np.asarray(main.storage)

            def _resident_iter():
                for lid in range(main.nlist):
                    members = lists_np[lid]
                    members = members[members >= 0]
                    yield lid, storage_np[members], members

            main_iter = _resident_iter()
        pos = 0
        for lid, rows_m, ids_m in main_iter:
            gids_m = self._main_gids[np.asarray(ids_m)]
            alive = ~tomb[gids_m]
            parts_r = [np.asarray(rows_m)[alive]]
            parts_g = [gids_m[alive]]
            lo = np.searchsorted(d_labels, lid, "left")
            hi = np.searchsorted(d_labels, lid, "right")
            if hi > lo:
                parts_r.append(d_rows[lo:hi])
                parts_g.append(d_gids[lo:hi])
            rows = (np.concatenate(parts_r) if len(parts_r) > 1
                    else parts_r[0])
            gids = (np.concatenate(parts_g) if len(parts_g) > 1
                    else parts_g[0])
            new_ids = np.arange(pos, pos + len(gids), dtype=np.int32)
            pos += len(gids)
            yield lid, rows, new_ids, gids

    def _make_ivf_like_main(self) -> IVFIndex:
        """Fresh unfitted shell with the main's ctor params + frozen
        scorer state (shared by every IVF compaction flavour)."""
        main = self._core
        if isinstance(main, IVFFlatIndex):
            new_main = IVFFlatIndex(
                nlist=main._nlist_requested, nprobe=main.nprobe,
                sim=main.sim, kmeans_iters=main.kmeans_iters,
                kmeans_init=main.kmeans_init, balanced=main.balanced)
        else:
            new_main = IVFIndex(
                main.pipeline, nlist=main._nlist_requested,
                nprobe=main.nprobe, sim=main.sim, backend=main.backend,
                kmeans_iters=main.kmeans_iters,
                kmeans_init=main.kmeans_init, balanced=main.balanced)
        new_main.float_stages = self.float_stages
        new_main.scorer.load_extra_state(self.scorer.extra_state())
        return new_main

    def _reshard_main(self, new_main):
        """Wrap a freshly folded single-host main over the old main's mesh
        — compaction for sharded mains is fold + re-shard in one step."""
        main = self.main
        if isinstance(main, ShardedIVFIndex):
            out = ShardedIVFIndex(new_main, main.mesh,
                                  doc_axis=main.doc_axes,
                                  query_axis=main.query_axis)
        else:
            out = ShardedCompressedIndex(
                new_main.pipeline, main.mesh, sim=new_main.sim,
                backend=main.backend, doc_axis=main.doc_axes,
                query_axis=main.query_axis)
            out.scorer.load_extra_state(new_main.scorer.extra_state())
            out._storage_host = new_main.storage
            out._n_docs = len(new_main)
            out._dim = new_main._dim
        out.spec = getattr(new_main, "spec", None)
        return out

    def _wrap_compacted(self, new_main, st: _Snapshot,
                        gids: np.ndarray) -> "SegmentedIndex":
        new_main.spec = getattr(self.main, "spec", None)
        if self._sharded:
            new_main = self._reshard_main(new_main)
        out = SegmentedIndex(new_main, spec=self.spec,
                             drift_threshold=self.drift_threshold,
                             max_delta_fraction=self.max_delta_fraction)
        # tombstoned ids stay marked forever: the gid space has holes after
        # compaction, and a replayed delete of a folded id must stay a no-op
        out._restore(main_gids=gids, tomb=st.tomb.copy(),
                     next_gid=st.next_gid)
        return out

    def _compact_chunked(self, st: _Snapshot, out_path: str,
                         resident) -> "SegmentedIndex":
        """Fold straight into a chunked (v3) artifact at ``out_path`` —
        list-by-list, keeping the existing router, without decoding (or
        even concatenating) the main storage — then serve the fold back
        at the requested residency."""
        from repro.retrieval.api import (_chunked_header, _write_chunked,
                                         load_index)
        main = self.main
        meta, aux = _chunked_header(main, None, self.spec)
        meta["index"]["n_docs"] = st.n_live
        meta["index"]["version"] = main._version + 1
        if main.store is not None:
            dtype = main.store.storage_dtype
            width = main.store.storage_width
        else:
            dtype = main.storage.dtype
            width = int(main.storage.shape[1])
        gid_parts = []

        def _rows():
            for _, rows, new_ids, gids in self._iter_folded_lists(st):
                gid_parts.append(gids)
                yield rows, new_ids

        _write_chunked(out_path, meta, aux, _rows(), storage_dtype=dtype,
                       storage_width=width, n_lists=main.nlist)
        new_main = load_index(out_path, resident=resident)
        return self._wrap_compacted(new_main, st, np.concatenate(gid_parts))

    def compact(self, rng=None, *, out_path: Optional[str] = None,
                resident="auto") -> "SegmentedIndex":
        """Fold segments + tombstones into a fresh main; returns a NEW
        SegmentedIndex (self keeps serving unchanged).

        Storage rows are moved, never re-encoded — the fitted pipeline,
        scorer codebooks, and global doc ids all carry over, so rankings
        over the surviving rows are unchanged for exact mains.  Resident
        IVF mains refit only the k-means router (on the float decode of
        the moved storage, exactly like ``CompressedIndex.to_ivf``), which
        is the point of drift-triggered compaction: the router re-centers
        on what the index now actually contains.

        Two tiered flavours change that default:

        * ``out_path=`` (IVF mains only) streams the fold list-by-list
          into a chunked v3 artifact at that path — the existing router is
          kept (delta rows were routed to it, so the fold is exact), the
          main storage is never decoded, and the returned index serves the
          artifact at ``resident=`` residency.
        * A store-backed main without ``out_path`` folds in memory through
          the same routed path (no decode, no refit) into a fully-resident
          new main.
        """
        st = self._state
        main = self.main
        if st.n_live == 0:
            raise ValueError("cannot compact to an empty index — every doc "
                             "is tombstoned")
        if out_path is not None:
            if self._sharded:
                raise TypeError(
                    "chunked compaction (out_path=) folds on a single "
                    "host — sharded mains compact in memory and re-shard; "
                    "save the compacted index and re-load it tiered "
                    "instead")
            if not self._is_ivf:
                raise TypeError("chunked compaction (out_path=) lays out "
                                "IVF inverted lists — "
                                f"{type(main).__name__} has none")
            return self._compact_chunked(st, out_path, resident)
        if self._is_ivf and main.store is not None:
            rows_all, labels_all, gid_parts = [], [], []
            for lid, rows, _, gids in self._iter_folded_lists(st):
                rows_all.append(rows)
                labels_all.append(np.full(len(gids), lid, np.int32))
                gid_parts.append(gids)
            new_main = self._make_ivf_like_main()
            new_main._install_routed(np.concatenate(rows_all),
                                     np.concatenate(labels_all),
                                     main.centroids, main._dim)
            return self._wrap_compacted(new_main, st,
                                        np.concatenate(gid_parts))
        alive_main = ~st.tomb[self._main_gids]
        parts = [jnp.asarray(self._main_storage())[jnp.asarray(alive_main)]]
        gid_parts = [self._main_gids[alive_main]]
        for seg in st.segments:
            alive = ~st.tomb[seg.gids]
            parts.append(seg.storage[jnp.asarray(alive)])
            gid_parts.append(seg.gids[alive])
        storage = jnp.concatenate(parts, axis=0)
        gids = np.concatenate(gid_parts)

        if isinstance(main, DenseIndex):
            new_main = DenseIndex(storage, sim=main.sim)
        elif self._is_ivf:
            new_main = self._make_ivf_like_main()
            x_route = new_main.scorer.decode(storage)
            new_main._install(storage, x_route, rng=rng)
        else:
            new_main = CompressedIndex(main.pipeline, sim=main.sim,
                                       backend=main.backend)
            new_main.float_stages = self.float_stages
            new_main.scorer.load_extra_state(self.scorer.extra_state())
            new_main.storage = storage
            new_main._n_docs = int(storage.shape[0])
            new_main._dim = main._dim
            new_main._version = 1
        return self._wrap_compacted(new_main, st, gids)

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        st = self._state
        return {
            "main": self.main.state_dict(),
            "main_kind": type(self.main).__name__,
            "main_gids": self._main_gids,
            "tombstones": np.flatnonzero(st.tomb).astype(np.int64),
            "next_gid": st.next_gid,
            "segments": [{"storage": s.storage, "gids": s.gids,
                          "labels": s.labels} for s in st.segments],
            "drift": self.drift.state_dict(),
        }

    def save(self, path: str) -> None:
        from repro.retrieval.api import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str) -> "SegmentedIndex":
        from repro.retrieval.api import load_index
        return load_index(path, expect=cls)
