"""IVF approximate nearest-neighbour search over quantized storage.

Reproduces the paper's Figure-1 retrieval condition (FAISS ``IndexIVFFlat``,
nlist=200, nprobe=100) and extends it to the compressed-serving path: a
k-means coarse quantizer partitions the index into ``nlist`` inverted lists;
search scores only the ``nprobe`` lists nearest to each query.

Unlike the seed implementation (full float32 docs, bespoke einsum scoring),
:class:`IVFIndex` stores the inverted lists in *scorer-backend storage*
(float / fp16 / int8 codes / bit-packed 1-bit words, via the
:mod:`repro.retrieval.scorers` registry) and scores probed candidates through
the same kernel paths as exact search — so ANN search compounds with the
paper's compression instead of forfeiting it.  The whole query path is one
jit graph per (k, nprobe): float stages → coarse routing → list gather →
``scorer.scores_gathered`` → masked top-k.

Implementation notes (TPU/JAX adaptation): inverted lists are stored as one
padded (nlist, max_len) id matrix so probing is a dense gather; masked
scoring keeps everything jit-compatible.  For the production multi-pod path
the lists are partitioned over devices (:class:`repro.retrieval.sharded.
ShardedIVFIndex`) — IVF then reduces per-device compute by nprobe/nlist
while the collective schedule is unchanged.

Degenerate corpora are handled explicitly: ``fit`` clamps the effective
``nlist`` to the number of documents (a k-means run can still leave a
cluster empty — those lists are simply padded), and ``search`` always
returns ``min(k, n_docs)`` columns, padding truly-unreachable slots (fewer
than k candidates probed) with score ``-inf`` and id ``-1``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CompressionPipeline
from repro.retrieval.kmeans import assign, assign_balanced, kmeans_fit
from repro.retrieval.scorers import (Scorer, apply_float_stages,
                                     scorer_for_pipeline)
from repro.retrieval.topk import (masked_topk_by_id, merge_topk_block,
                                  resolve_k, resolve_nprobe, similarity,
                                  topk_score_then_id)

__all__ = ["IVFIndex", "IVFFlatIndex", "build_padded_lists",
           "probe_and_score", "masked_topk_by_id", "topk_score_then_id"]


#: probe slots gathered + scored per streaming step.  Merging is
#: associative under the strict (score desc, id asc) order, so any
#: grouping returns identical results — the block size only trades peak
#: memory (``g·max_len`` candidate rows) against per-step dispatch
#: overhead.  Measured on the CPU jnp path (100k docs, nlist=512,
#: nprobe=64, int8): 2 beats 1 by ~10% and beats 4–16 by 1.4–2.3× —
#: wider blocks thrash cache on the gather and widen every merge.
PROBE_BLOCK = 2


def _pad_probe(probe: jax.Array, lists: jax.Array, extras: list[jax.Array],
               g: int):
    """Pad the probe table to a multiple of ``g`` slots with a phantom
    all-pad list (id ``nlist``), so grouped streaming never double-counts
    a real list.  ``extras`` are per-(query, probe) columns (e.g. routed
    centroid scores) padded alongside; their pad value is irrelevant —
    every phantom candidate is masked by id −1."""
    nlist = lists.shape[0]
    lists_ext = jnp.concatenate(
        [lists, jnp.full((1, lists.shape[1]), -1, lists.dtype)])
    npad = -(-probe.shape[1] // g) * g
    if npad != probe.shape[1]:
        fill = npad - probe.shape[1]
        probe = jnp.concatenate(
            [probe, jnp.full((probe.shape[0], fill), nlist, probe.dtype)],
            axis=1)
        extras = [jnp.concatenate(
            [e, jnp.zeros((e.shape[0], fill), e.dtype)], axis=1)
            for e in extras]
    return probe, lists_ext, extras


def probe_and_score(q: jax.Array, centroids: jax.Array, lists: jax.Array,
                    storage: jax.Array, scorer: Scorer, params, sim: str,
                    nprobe: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Coarse-route ``q`` to ``nprobe`` lists, gather and score candidates.

    Returns ``(scores, cand, valid)``: scores ``(Q, C)`` with pad slots at
    ``-inf``, the gathered candidate row ids ``(Q, C)`` (−1 pads), and the
    validity mask.  The caller maps ``cand`` to output ids (global ids on
    the single host, shard-local → global via a gids table when sharded).

    The probed lists are gathered and scored ``PROBE_BLOCK`` slots at a
    time inside a ``lax.scan``, so the peak intermediate is one
    ``(Q, g·max_len, w)`` block — never the full
    ``(Q, nprobe·max_len, w)`` gather the old implementation
    materialised.  Output column order is unchanged (probe-major), so
    results are identical.
    """
    cscores = similarity(q, centroids, sim)
    _, probe = jax.lax.top_k(cscores, nprobe)          # (Q, nprobe)
    qe = scorer.encode_queries(q)
    g = min(PROBE_BLOCK, nprobe)
    probe, lists_ext, _ = _pad_probe(probe, lists, [], g)
    n_q = q.shape[0]
    steps = jnp.moveaxis(probe.reshape(n_q, -1, g), 1, 0)   # (S, Q, g)

    def step(_, pj):                                   # pj: (Q, g) slots
        cand_j = lists_ext[pj].reshape(n_q, -1)        # (Q, g·L)
        gathered = storage[jnp.maximum(cand_j, 0)]     # (Q, g·L, w)
        s_j = scorer.scores_gathered(qe, gathered, params=params)
        return None, (s_j, cand_j)

    _, (s, cand) = jax.lax.scan(step, None, steps)     # (S, Q, g·L)
    width = nprobe * lists.shape[1]
    s = jnp.moveaxis(s, 0, 1).reshape(n_q, -1)[:, :width]
    cand = jnp.moveaxis(cand, 0, 1).reshape(n_q, -1)[:, :width]
    valid = cand >= 0
    return jnp.where(valid, s, -jnp.inf), cand, valid


def build_padded_lists(labels: np.ndarray, nlist: int) -> np.ndarray:
    """(n_docs,) cluster labels → (nlist, max_len) id matrix, −1 padded.

    Empty clusters become all-pad rows (the ``nlist > n_docs`` /
    empty-bucket case), never a crash.  One stable argsort buckets every
    doc — O(n log n + nlist), not a per-cluster scan — and keeps doc ids
    ascending within each list (the tie order the search paths rely on).
    """
    order = np.argsort(labels, kind="stable").astype(np.int32)
    counts = np.bincount(labels, minlength=nlist)
    max_len = max(1, int(counts.max(initial=0)))
    lists = np.full((nlist, max_len), -1, np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for c in range(nlist):
        b = order[starts[c]: starts[c + 1]]
        lists[c, : len(b)] = b
    return lists


class IVFIndex:
    """Quantized IVF index: coarse k-means router over scorer-backend storage.

    ``pipeline`` follows :class:`~repro.retrieval.index.CompressedIndex`
    semantics: float stages transform docs/queries, a trailing quantizer (if
    any) selects the scorer backend that owns the stored representation.
    ``pipeline=None`` stores plain float (the classic IVF-Flat).

    ``fit`` clamps the effective ``nlist`` to the corpus size; ``nprobe``
    is clamped to ``nlist`` at search time and can be overridden per call
    (and per request through :class:`repro.serve.ServeEngine`).
    """

    def __init__(self, pipeline: Optional[CompressionPipeline] = None,
                 nlist: int = 200, nprobe: int = 100, sim: str = "ip",
                 backend: str = "auto", kmeans_iters: int = 15,
                 residual: bool = False, kmeans_init: str = "random",
                 balanced: bool = False):
        if nlist < 1:
            raise ValueError("nlist must be ≥ 1")
        if residual and sim != "ip":
            raise ValueError("residual encoding is IP-only: the routed "
                             "q·centroid correction is an inner-product "
                             f"identity (got sim={sim!r})")
        self.pipeline = pipeline if pipeline is not None \
            else CompressionPipeline([])
        self.nlist = nlist
        self._nlist_requested = nlist  # clamp is per-fit, never sticky
        self.nprobe = nprobe
        self.sim = sim
        self.backend = backend
        self.kmeans_iters = kmeans_iters
        self.residual = residual       # store encode(x − centroid[label])
        self.kmeans_init = kmeans_init  # "random" (historical) or "++"
        self.balanced = balanced       # capacity-aware list assignment
        self.float_stages, self.scorer = scorer_for_pipeline(
            self.pipeline, sim=sim, backend=backend)
        self.centroids: Optional[jax.Array] = None   # (nlist, d) float routing
        self.lists: Optional[jax.Array] = None       # (nlist, max_len), −1 pad
        self.storage: Optional[jax.Array] = None     # scorer-encoded rows
        self.spec = None               # set by api.build_index / api.load_index
        self._labels: Optional[np.ndarray] = None    # (n_docs,) cluster ids
        self._n_docs = 0
        self._dim = 0
        self._version = 0      # bumped on every fit/add; snapshots check it
        self._source = None    # (CompressedIndex, version) when promoted
        self._search_fn = None
        self._list_layout = None       # lazy list-major (version, stor, ids)
        self._fused_reference_only = False   # tests: force the jnp ref mirror
        self.store = None              # ListStore when tiered (storage=None)
        self._store_fns = None         # lazy (route_fn, step_fn) jit pair

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array,
              queries_sample: Optional[jax.Array] = None,
              pipeline: Optional[CompressionPipeline] = None, *,
              nlist: int = 200, nprobe: int = 100, sim: str = "ip",
              backend: str = "auto", kmeans_iters: int = 15,
              residual: bool = False, kmeans_init: str = "random",
              balanced: bool = False, rng=None) -> "IVFIndex":
        """Fit the pipeline on ``docs`` then fit the IVF structure."""
        pipeline = pipeline if pipeline is not None else CompressionPipeline([])
        pipeline.fit(docs, queries_sample, rng=rng)
        idx = cls(pipeline, nlist=nlist, nprobe=nprobe, sim=sim,
                  backend=backend, kmeans_iters=kmeans_iters,
                  residual=residual, kmeans_init=kmeans_init,
                  balanced=balanced)
        return idx.fit(docs, rng=rng)

    def fit(self, docs: jax.Array, rng=None,
            train_size: int = 100_000) -> "IVFIndex":
        """Encode ``docs`` through the (already fitted) pipeline and build
        the coarse router + inverted lists."""
        x = apply_float_stages(self.float_stages, docs, "docs")
        if self.residual:
            # route first, then encode what the router cannot explain:
            # storage = encode(x − centroid[label]).  At IP scoring time the
            # routed q·centroid term is added back, so for float storage the
            # identity q·(x−c) + q·c = q·x makes residual encoding *exact*;
            # for quantized storage the encoder only has to cover the
            # (much smaller) residual range, cutting quantization error.
            x = jnp.asarray(x, jnp.float32)
            if x.shape[0] == 0:
                raise ValueError("cannot fit an IVF index on an empty corpus")
            self._fit_router(x, rng=rng, train_size=train_size)
            res = x - self.centroids[jnp.asarray(self._labels)]
            return self._finish_install(self.scorer.encode_docs(res), x)
        storage = self.scorer.encode_docs(x)
        return self._install(storage, x, rng=rng, train_size=train_size)

    def _fit_router(self, x_route: jax.Array, rng=None,
                    train_size: int = 100_000) -> None:
        """k-means centroids + list assignment from float routing vectors."""
        n_docs = int(x_route.shape[0])
        if rng is None:
            rng = jax.random.PRNGKey(0)
        # clamp to this corpus, from the *requested* nlist — a refit on a
        # larger corpus gets the configured list count back
        self.nlist = max(1, min(self._nlist_requested, n_docs))
        train = x_route
        if n_docs > train_size:
            sel = jax.random.choice(rng, n_docs, (train_size,), replace=False)
            train = x_route[sel]
        self.centroids = kmeans_fit(train, self.nlist, self.kmeans_iters,
                                    rng, init=self.kmeans_init)
        if self.balanced and n_docs > self.nlist:
            labels = assign_balanced(x_route, self.centroids)
        else:
            labels = assign(x_route, self.centroids)
        self._labels = np.asarray(labels)
        self.lists = jnp.asarray(build_padded_lists(self._labels, self.nlist))

    def _finish_install(self, storage: jax.Array, x_route: jax.Array
                        ) -> "IVFIndex":
        self.storage = storage
        self._n_docs = int(storage.shape[0])
        self._dim = int(x_route.shape[-1])
        self._version += 1
        self._source = None    # fresh fit: no longer a shared-storage view
        self._search_fn = None
        self._list_layout = None
        self.store = None      # a fresh fit is fully resident
        self._store_fns = None
        return self

    def _install(self, storage: jax.Array, x_route: jax.Array, rng=None,
                 train_size: int = 100_000) -> "IVFIndex":
        """Install pre-encoded ``storage`` with routing vectors ``x_route``
        (float, same row order) — shared by ``fit`` and
        :meth:`CompressedIndex.to_ivf <repro.retrieval.index.CompressedIndex.to_ivf>`."""
        if self.residual:
            raise ValueError("residual IVF cannot adopt pre-encoded storage "
                             "(rows must be re-encoded against the routed "
                             "centroids) — use fit()")
        n_docs = int(storage.shape[0])
        if n_docs == 0:
            raise ValueError("cannot fit an IVF index on an empty corpus")
        x_route = jnp.asarray(x_route, jnp.float32)
        self._fit_router(x_route, rng=rng, train_size=train_size)
        return self._finish_install(storage, x_route)

    def _install_routed(self, storage: jax.Array, labels: np.ndarray,
                        centroids: jax.Array, dim: int) -> "IVFIndex":
        """Adopt pre-encoded storage already routed to an *existing* router
        — no k-means refit, no float decode.  This is the chunked-compaction
        fold: a store-backed main cannot decode its whole corpus to refit,
        but its delta rows were routed to the same centroids, so keeping the
        router and rebuilding only the list table is exact."""
        if self.residual:
            raise ValueError("residual IVF cannot adopt pre-encoded storage")
        storage = jnp.asarray(storage)
        if storage.shape[0] == 0:
            raise ValueError("cannot install an empty corpus")
        self.centroids = jnp.asarray(centroids)
        self.nlist = int(self.centroids.shape[0])
        self._labels = np.asarray(labels)
        if self._labels.shape != (int(storage.shape[0]),):
            raise ValueError("labels must be one cluster id per storage row")
        self.lists = jnp.asarray(build_padded_lists(self._labels, self.nlist))
        return self._finish_install(storage, jnp.zeros((0, dim), jnp.float32))

    def add(self, docs: jax.Array) -> "IVFIndex":
        """Append docs, routing them to the *existing* centroids (no refit)."""
        if self.store is not None:
            raise ValueError(
                "store-backed (tiered) IVF index is read-only — wrap it in "
                "a SegmentedIndex for live updates, or reload with "
                "resident='all'")
        if self.centroids is None:
            return self.fit(docs)
        x = apply_float_stages(self.float_stages, docs, "docs")
        x_f = jnp.asarray(x, jnp.float32)
        labels = np.asarray(assign(x_f, self.centroids))
        if self.residual:
            enc = self.scorer.encode_docs(
                x_f - self.centroids[jnp.asarray(labels)])
        else:
            enc = self.scorer.encode_docs(x)
        self.storage = jnp.concatenate([self.storage, enc], axis=0)
        self._labels = np.concatenate([self._labels, labels])
        self.lists = jnp.asarray(build_padded_lists(self._labels, self.nlist))
        self._n_docs = int(self.storage.shape[0])
        self._version += 1
        self._source = None    # storage was copied on append: now our own
        self._search_fn = None
        self._list_layout = None
        self._store_fns = None
        return self

    def __len__(self) -> int:
        return self._n_docs

    @property
    def nbytes(self) -> int:
        """Bytes of the quantized document storage (the paper's metric).

        For a store-backed index this is the *encoded artifact* size — what
        a fully-resident load would cost — not the hot-tier residency
        (``store.stats()['bytes_resident']`` reports that)."""
        if self.storage is not None:
            return int(self.storage.size * self.storage.dtype.itemsize)
        assert self.store is not None
        return int(self.store.encoded_nbytes)

    @property
    def aux_nbytes(self) -> int:
        """Routing overhead: centroids + padded inverted lists (+ the
        list-major storage copy once the fused kernel path materialises it)."""
        aux = 0
        for a in (self.centroids, self.lists):
            if a is not None:
                aux += int(a.size * a.dtype.itemsize)
        if self._list_layout is not None:
            ls = self._list_layout[1]
            aux += int(ls.size * ls.dtype.itemsize)
        return aux

    # -- search ------------------------------------------------------------
    def encode_queries(self, queries: jax.Array) -> jax.Array:
        """Queries through the float stages (no query-side quantization)."""
        return apply_float_stages(self.float_stages, queries, "queries")

    def _resolve_nprobe(self, nprobe: Optional[int]) -> int:
        return resolve_nprobe(nprobe, self.nlist, default=self.nprobe)

    @property
    def _use_fused_kernel(self) -> bool:
        """Route search through the fused Pallas kernel?

        The kernel covers the IP hot path for all four storage formats; the
        1-bit backend additionally needs the paper's α = 0.5 offset (any
        other offset has rank-1 corrections the standalone op applies
        outside the kernel).  Everything else falls back to the streaming
        jnp path, which is the numerics oracle anyway.  A store-backed
        index always streams: the fused kernel DMAs a device-resident
        list-major copy of the whole storage, which is exactly what a
        tiered index does not have.
        """
        if self.store is not None:
            return False
        if not self.scorer.use_pallas or self.sim != "ip":
            return False
        if self.scorer.name == "onebit":
            return float(self.scorer.quantizer.offset) == 0.5
        return True

    def _list_major_layout(self) -> tuple[jax.Array, jax.Array]:
        """(nlist, max_len, w) list-major storage + (nlist, max_len) ids.

        The fused kernel DMAs whole inverted lists, so rows must be
        contiguous per list.  Built lazily on the first fused search and
        cached against ``_version`` (counted in :attr:`aux_nbytes`); the
        canonical row-major ``storage`` stays the single source of truth
        for persistence, sharding, and the jnp path.
        """
        if self._list_layout is not None and \
                self._list_layout[0] == self._version:
            return self._list_layout[1], self._list_layout[2]
        list_storage = self.storage[jnp.maximum(self.lists, 0)]
        pad = (self.lists < 0)[..., None]
        if list_storage.ndim == 3:
            list_storage = jnp.where(pad, jnp.zeros((), list_storage.dtype),
                                     list_storage)
        self._list_layout = (self._version, list_storage, self.lists)
        return list_storage, self.lists

    def _streaming_search_fn(self):
        """jit'd route→scan(gather→score→merge) streaming top-k (jnp path).

        ``PROBE_BLOCK`` probed lists are gathered and scored per scan step
        through the backend's ``scores_gathered`` oracle, then folded into
        a (Q, k) running top-k with the shared (score desc, id asc) merge
        — exact and bit-identical to the old monolithic masked top-k (the
        order is total, so blockwise merging is associative for any block
        size), but the peak intermediate drops from (Q, nprobe·max_len)
        to (Q, g·max_len).
        """
        stages = tuple(self.float_stages)
        scorer = self.scorer
        sim = self.sim
        residual = self.residual

        @functools.partial(jax.jit, static_argnames=("k", "nprobe"))
        def _search(queries, centroids, lists, storage, params, *, k, nprobe):
            q = queries
            for t in stages:
                q = t(q, "queries")
            cscores = similarity(q, centroids, sim)
            cvals, probe = jax.lax.top_k(cscores, nprobe)   # (Q, nprobe)
            qe = scorer.encode_queries(q)
            n_q, max_len = q.shape[0], lists.shape[1]
            g = min(PROBE_BLOCK, nprobe)
            probe, lists_ext, (cvals,) = _pad_probe(probe, lists, [cvals], g)
            p_steps = jnp.moveaxis(probe.reshape(n_q, -1, g), 1, 0)
            c_steps = jnp.moveaxis(cvals.reshape(n_q, -1, g), 1, 0)

            def step(carry, inp):
                pj, cj = inp                               # (Q, g) slots
                cand_j = lists_ext[pj].reshape(n_q, -1)    # (Q, g·L)
                gathered = storage[jnp.maximum(cand_j, 0)]
                s_j = scorer.scores_gathered(qe, gathered, params=params)
                if residual:                   # routed q·centroid term
                    s_j = s_j + jnp.repeat(cj, max_len, axis=1)
                s_j = jnp.where(cand_j >= 0, s_j, -jnp.inf)
                rv, ri = carry
                # the sort-free merge (k max/min-id rounds): XLA's CPU
                # lowering of the lexsort merge is a scalar comparator
                # loop that dominated the whole search (~70% of the
                # hot path at nlist=512); bit-identical by the strict
                # total order, see topk.merge_topk_block
                return merge_topk_block(
                    rv, ri, s_j,
                    jnp.where(cand_j >= 0, cand_j, -1), k), None

            init = (jnp.full((n_q, k), -jnp.inf, jnp.float32),
                    jnp.full((n_q, k), -1, jnp.int32))
            (vals, ids), _ = jax.lax.scan(step, init, (p_steps, c_steps))
            return vals, ids

        return _search

    # -- tiered (store-backed) search --------------------------------------
    def _store_fn_pair(self):
        """jit'd (route, step) pair for the store-backed streaming search.

        The two graphs together are an exact mirror of
        :meth:`_streaming_search_fn`, split at the host boundary where list
        bytes come from the :class:`~repro.storage.store.ListStore` instead
        of a device gather.  Bit-identity holds unconditionally: the route
        graph runs the same ops (stages → similarity → top_k →
        encode_queries); each step scores the same ``(Q, g·max_len)`` block
        through the same ``scores_gathered`` oracle and folds it with the
        same associative merge.  Pad slots differ in *content* (zero rows
        here vs row-0 gathers there) but every pad score is masked to
        ``-inf`` before the merge, and a matmul output column depends only
        on its own input column — pad bytes can never reach a kept bit.
        """
        if self._store_fns is not None:
            return self._store_fns
        stages = tuple(self.float_stages)
        scorer = self.scorer
        sim = self.sim
        residual = self.residual

        @functools.partial(jax.jit, static_argnames=("nprobe",))
        def _route(queries, centroids, *, nprobe):
            q = queries
            for t in stages:
                q = t(q, "queries")
            cscores = similarity(q, centroids, sim)
            cvals, probe = jax.lax.top_k(cscores, nprobe)   # (Q, nprobe)
            return scorer.encode_queries(q), probe, cvals

        @functools.partial(jax.jit, static_argnames=("k", "max_len"))
        def _step(qe, gathered, cand_j, cj, rv, ri, params, *, k, max_len):
            s_j = scorer.scores_gathered(qe, gathered, params=params)
            if residual:                   # routed q·centroid term
                s_j = s_j + jnp.repeat(cj, max_len, axis=1)
            s_j = jnp.where(cand_j >= 0, s_j, -jnp.inf)
            return merge_topk_block(
                rv, ri, s_j, jnp.where(cand_j >= 0, cand_j, -1), k)

        self._store_fns = (_route, _step)
        return self._store_fns

    def _gather_block(self, pj: np.ndarray, g: int, max_len: int
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Assemble one scoring block from the store: ``pj`` is the (Q, g)
        probe slice (phantom pad slots carry id ``nlist``); returns the
        zero-filled ``(Q, g·L, w)`` gathered rows and the −1-filled
        ``(Q, g·L)`` candidate ids.  Lists repeated across queries within
        the block are fetched once (one touch per block, so the store's
        frequency-aware admission counts probes, not fan-out)."""
        store = self.store
        n_q = pj.shape[0]
        gathered = np.zeros((n_q, g * max_len, store.storage_width),
                            store.storage_dtype)
        cand = np.full((n_q, g * max_len), -1, np.int32)
        block: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        for qi in range(n_q):
            for j in range(g):
                lid = int(pj[qi, j])
                if lid >= self.nlist:          # phantom pad slot
                    continue
                entry = block.get(lid)
                if entry is None:
                    entry = block[lid] = store.get(lid)
                rows, ids = entry
                n = ids.shape[0]
                if n:
                    gathered[qi, j * max_len: j * max_len + n] = rows
                    cand[qi, j * max_len: j * max_len + n] = ids
        return gathered, cand

    def _store_search(self, queries: jax.Array, k: int, nprobe: int,
                      query_chunk: int) -> tuple[jax.Array, jax.Array]:
        """Streaming search with list bytes served by :attr:`store`."""
        route, step = self._store_fn_pair()
        params = self.scorer.params()
        max_len = max(1, int(self.store.max_len))
        g = min(PROBE_BLOCK, nprobe)
        npad = -(-nprobe // g) * g
        vals_out, idx_out = [], []
        for s in range(0, queries.shape[0], query_chunk):
            qc = queries[s: s + query_chunk]
            qe, probe, cvals = route(qc, self.centroids, nprobe=nprobe)
            probe_np = np.asarray(probe)
            cvals_np = np.asarray(cvals)
            n_q = probe_np.shape[0]
            if npad != nprobe:                 # mirror _pad_probe
                fill = npad - nprobe
                probe_np = np.concatenate(
                    [probe_np,
                     np.full((n_q, fill), self.nlist, probe_np.dtype)],
                    axis=1)
                cvals_np = np.concatenate(
                    [cvals_np, np.zeros((n_q, fill), cvals_np.dtype)],
                    axis=1)
            rv = jnp.full((n_q, k), -jnp.inf, jnp.float32)
            ri = jnp.full((n_q, k), -1, jnp.int32)
            for j0 in range(0, npad, g):
                gathered, cand = self._gather_block(
                    probe_np[:, j0: j0 + g], g, max_len)
                rv, ri = step(qe, jnp.asarray(gathered), jnp.asarray(cand),
                              jnp.asarray(cvals_np[:, j0: j0 + g]),
                              rv, ri, params, k=k, max_len=max_len)
            vals_out.append(rv)
            idx_out.append(ri)
        return jnp.concatenate(vals_out), jnp.concatenate(idx_out)

    def prefetch(self, queries: jax.Array,
                 nprobe: Optional[int] = None) -> int:
        """Warm the store's hot tier with the probe table for ``queries``
        (route only — no scoring); returns lists touched.  No-op (0) on a
        fully-resident index."""
        if self.store is None:
            return 0
        nprobe = self._resolve_nprobe(nprobe)
        route, _ = self._store_fn_pair()
        _, probe, _ = route(jnp.asarray(queries), self.centroids,
                            nprobe=nprobe)
        lids = np.unique(np.asarray(probe).ravel())
        return self.store.prefetch(lids[lids < self.nlist].tolist())

    def _fused_search_fn(self):
        """jit'd route → fused Pallas kernel (gather+score+top-k in VMEM)."""
        from repro.kernels.ivf_fused import ops as fused_ops
        stages = tuple(self.float_stages)
        scorer = self.scorer
        sim = self.sim
        residual = self.residual
        backend = scorer.name
        use_pallas = not self._fused_reference_only

        @functools.partial(jax.jit, static_argnames=("k", "nprobe"))
        def _search(queries, centroids, list_storage, list_ids, params, *,
                    k, nprobe):
            q = queries
            for t in stages:
                q = t(q, "queries")
            q = q.astype(jnp.float32)
            cscores = similarity(q, centroids, sim)
            cvals, probe = jax.lax.top_k(cscores, nprobe)   # (Q, nprobe)
            extra = cvals if residual else None
            return fused_ops.fused_ivf_topk(probe, q, list_storage,
                                            list_ids, k, backend,
                                            params=params, extra_base=extra,
                                            use_pallas=use_pallas)

        return _search

    def search(self, queries: jax.Array, k: int,
               nprobe: Optional[int] = None, query_chunk: int = 64,
               ) -> tuple[jax.Array, jax.Array]:
        """Top-``min(k, n_docs)`` over the probed lists.

        Slots with no reachable candidate (probed pool < k) come back with
        score ``-inf`` and id ``-1``; with ``nprobe == nlist`` every stored
        doc is reachable and the ranking matches exact search.
        """
        if self.storage is None and self.store is None:
            raise ValueError("IVFIndex is not fitted")
        if self._source is not None and \
                self._source[0]._version != self._source[1]:
            raise ValueError(
                "source CompressedIndex changed since to_ivf (add was "
                "called); the promoted IVF view shares its old storage — "
                "re-promote with to_ivf()")
        nprobe = self._resolve_nprobe(nprobe)
        k = resolve_k(k, self._n_docs)
        if self.storage is None:       # tiered: lists come from the store
            return self._store_search(jnp.asarray(queries), k, nprobe,
                                      query_chunk)
        fused = self._use_fused_kernel
        if fused:
            list_storage, list_ids = self._list_major_layout()
        # k / nprobe are static_argnames: one jit wrapper specializes per
        # (k, nprobe) in its own trace cache
        if self._search_fn is None:
            self._search_fn = (self._fused_search_fn() if fused
                               else self._streaming_search_fn())
        fn = self._search_fn
        queries = jnp.asarray(queries)
        params = self.scorer.params()
        vals_out, idx_out = [], []
        for s in range(0, queries.shape[0], query_chunk):
            qc = queries[s: s + query_chunk]
            if fused:
                v, i = fn(qc, self.centroids, list_storage, list_ids,
                          params, k=k, nprobe=nprobe)
            else:
                v, i = fn(qc, self.centroids, self.lists, self.storage,
                          params, k=k, nprobe=nprobe)
            vals_out.append(v)
            idx_out.append(i)
        return jnp.concatenate(vals_out), jnp.concatenate(idx_out)

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        """Pipeline + storage + router + list layout: the full IVF artifact
        (cold-start search needs no access to the raw corpus)."""
        if self.storage is None and self.store is not None:
            raise ValueError(
                "store-backed (tiered) IVF index has no resident storage to "
                "snapshot — save_index(..., chunked=True) streams it from "
                "the store, or reload with resident='all' first")
        return {"pipeline": self.pipeline.state_dict(),
                "storage": self.storage,
                "centroids": self.centroids,
                "lists": self.lists,
                "labels": self._labels,
                "scorer_extra": self.scorer.extra_state(),
                "nlist": self.nlist,
                "nlist_requested": self._nlist_requested,
                "nprobe": self.nprobe,
                "residual": self.residual,
                "kmeans_init": self.kmeans_init,
                "balanced": self.balanced,
                "n_docs": self._n_docs, "dim": self._dim,
                "version": self._version}

    def load_state_dict(self, sd: dict) -> "IVFIndex":
        self.pipeline.load_state_dict(sd["pipeline"])
        # storage/lists may be None for a tiered load: the caller attaches
        # a ListStore afterwards (repro.retrieval.api._load_index_chunked)
        storage = sd["storage"]
        self.storage = jnp.asarray(storage) if storage is not None else None
        self.centroids = jnp.asarray(sd["centroids"])
        lists = sd["lists"]
        self.lists = jnp.asarray(lists) if lists is not None else None
        labels = sd.get("labels")
        self._labels = (np.asarray(labels) if labels is not None else None)
        self.scorer.load_extra_state(sd.get("scorer_extra", {}))
        self.nlist = int(sd["nlist"])
        self._nlist_requested = int(sd.get("nlist_requested", sd["nlist"]))
        self.nprobe = int(sd["nprobe"])
        self.residual = bool(sd.get("residual", False))
        self.kmeans_init = str(sd.get("kmeans_init", "random"))
        self.balanced = bool(sd.get("balanced", False))
        self._n_docs = int(sd["n_docs"])
        self._dim = int(sd["dim"])
        self._version = int(sd.get("version", 0))
        self._source = None            # an artifact owns its storage
        self._search_fn = None
        self._list_layout = None
        self.store = None
        self._store_fns = None
        return self

    def save(self, path: str) -> None:
        from repro.retrieval.api import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str) -> "IVFIndex":
        from repro.retrieval.api import load_index
        return load_index(path, expect=cls)


class IVFFlatIndex(IVFIndex):
    """Float-storage IVF (the seed's FAISS ``IndexIVFFlat`` analogue).

    Thin facade over :class:`IVFIndex` with no compression pipeline — kept
    for the Figure-1 benchmarks and as the uncompressed ANN baseline.
    """

    def __init__(self, nlist: int = 200, nprobe: int = 100, sim: str = "ip",
                 kmeans_iters: int = 15, kmeans_init: str = "random",
                 balanced: bool = False):
        super().__init__(None, nlist=nlist, nprobe=nprobe, sim=sim,
                         backend="jnp", kmeans_iters=kmeans_iters,
                         kmeans_init=kmeans_init, balanced=balanced)

    @property
    def docs(self) -> Optional[jax.Array]:
        return self.storage
