"""IVF approximate nearest-neighbour search over quantized storage.

Reproduces the paper's Figure-1 retrieval condition (FAISS ``IndexIVFFlat``,
nlist=200, nprobe=100) and extends it to the compressed-serving path: a
k-means coarse quantizer partitions the index into ``nlist`` inverted lists;
search scores only the ``nprobe`` lists nearest to each query.

Unlike the seed implementation (full float32 docs, bespoke einsum scoring),
:class:`IVFIndex` stores the inverted lists in *scorer-backend storage*
(float / fp16 / int8 codes / bit-packed 1-bit words, via the
:mod:`repro.retrieval.scorers` registry) and scores probed candidates through
the same kernel paths as exact search — so ANN search compounds with the
paper's compression instead of forfeiting it.  The whole query path is one
jit graph per (k, nprobe): float stages → coarse routing → list gather →
``scorer.scores_gathered`` → masked top-k.

Implementation notes (TPU/JAX adaptation): inverted lists are stored as one
padded (nlist, max_len) id matrix so probing is a dense gather; masked
scoring keeps everything jit-compatible.  For the production multi-pod path
the lists are partitioned over devices (:class:`repro.retrieval.sharded.
ShardedIVFIndex`) — IVF then reduces per-device compute by nprobe/nlist
while the collective schedule is unchanged.

Degenerate corpora are handled explicitly: ``fit`` clamps the effective
``nlist`` to the number of documents (a k-means run can still leave a
cluster empty — those lists are simply padded), and ``search`` always
returns ``min(k, n_docs)`` columns, padding truly-unreachable slots (fewer
than k candidates probed) with score ``-inf`` and id ``-1``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CompressionPipeline
from repro.retrieval.kmeans import assign, kmeans_fit
from repro.retrieval.scorers import (Scorer, apply_float_stages,
                                     scorer_for_pipeline)
from repro.retrieval.topk import (masked_topk_by_id, resolve_k, similarity,
                                  topk_score_then_id)

__all__ = ["IVFIndex", "IVFFlatIndex", "build_padded_lists",
           "probe_and_score", "masked_topk_by_id", "topk_score_then_id"]


def probe_and_score(q: jax.Array, centroids: jax.Array, lists: jax.Array,
                    storage: jax.Array, scorer: Scorer, params, sim: str,
                    nprobe: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Coarse-route ``q`` to ``nprobe`` lists, gather and score candidates.

    Returns ``(scores, cand, valid)``: scores ``(Q, C)`` with pad slots at
    ``-inf``, the gathered candidate row ids ``(Q, C)`` (−1 pads), and the
    validity mask.  The caller maps ``cand`` to output ids (global ids on
    the single host, shard-local → global via a gids table when sharded).
    """
    cscores = similarity(q, centroids, sim)
    _, probe = jax.lax.top_k(cscores, nprobe)          # (Q, nprobe)
    cand = lists[probe].reshape(q.shape[0], -1)        # (Q, C)
    valid = cand >= 0
    gathered = storage[jnp.maximum(cand, 0)]           # (Q, C, w)
    qe = scorer.encode_queries(q)
    s = scorer.scores_gathered(qe, gathered, params=params)
    return jnp.where(valid, s, -jnp.inf), cand, valid


def build_padded_lists(labels: np.ndarray, nlist: int) -> np.ndarray:
    """(n_docs,) cluster labels → (nlist, max_len) id matrix, −1 padded.

    Empty clusters become all-pad rows (the ``nlist > n_docs`` /
    empty-bucket case), never a crash.  One stable argsort buckets every
    doc — O(n log n + nlist), not a per-cluster scan — and keeps doc ids
    ascending within each list (the tie order the search paths rely on).
    """
    order = np.argsort(labels, kind="stable").astype(np.int32)
    counts = np.bincount(labels, minlength=nlist)
    max_len = max(1, int(counts.max(initial=0)))
    lists = np.full((nlist, max_len), -1, np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for c in range(nlist):
        b = order[starts[c]: starts[c + 1]]
        lists[c, : len(b)] = b
    return lists


class IVFIndex:
    """Quantized IVF index: coarse k-means router over scorer-backend storage.

    ``pipeline`` follows :class:`~repro.retrieval.index.CompressedIndex`
    semantics: float stages transform docs/queries, a trailing quantizer (if
    any) selects the scorer backend that owns the stored representation.
    ``pipeline=None`` stores plain float (the classic IVF-Flat).

    ``fit`` clamps the effective ``nlist`` to the corpus size; ``nprobe``
    is clamped to ``nlist`` at search time and can be overridden per call
    (and per request through :class:`repro.serve.ServeEngine`).
    """

    def __init__(self, pipeline: Optional[CompressionPipeline] = None,
                 nlist: int = 200, nprobe: int = 100, sim: str = "ip",
                 backend: str = "auto", kmeans_iters: int = 15):
        if nlist < 1:
            raise ValueError("nlist must be ≥ 1")
        self.pipeline = pipeline if pipeline is not None \
            else CompressionPipeline([])
        self.nlist = nlist
        self._nlist_requested = nlist  # clamp is per-fit, never sticky
        self.nprobe = nprobe
        self.sim = sim
        self.backend = backend
        self.kmeans_iters = kmeans_iters
        self.float_stages, self.scorer = scorer_for_pipeline(
            self.pipeline, sim=sim, backend=backend)
        self.centroids: Optional[jax.Array] = None   # (nlist, d) float routing
        self.lists: Optional[jax.Array] = None       # (nlist, max_len), −1 pad
        self.storage: Optional[jax.Array] = None     # scorer-encoded rows
        self.spec = None               # set by api.build_index / api.load_index
        self._labels: Optional[np.ndarray] = None    # (n_docs,) cluster ids
        self._n_docs = 0
        self._dim = 0
        self._version = 0      # bumped on every fit/add; snapshots check it
        self._source = None    # (CompressedIndex, version) when promoted
        self._search_fn = None

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array,
              queries_sample: Optional[jax.Array] = None,
              pipeline: Optional[CompressionPipeline] = None, *,
              nlist: int = 200, nprobe: int = 100, sim: str = "ip",
              backend: str = "auto", kmeans_iters: int = 15,
              rng=None) -> "IVFIndex":
        """Fit the pipeline on ``docs`` then fit the IVF structure."""
        pipeline = pipeline if pipeline is not None else CompressionPipeline([])
        pipeline.fit(docs, queries_sample, rng=rng)
        idx = cls(pipeline, nlist=nlist, nprobe=nprobe, sim=sim,
                  backend=backend, kmeans_iters=kmeans_iters)
        return idx.fit(docs, rng=rng)

    def fit(self, docs: jax.Array, rng=None,
            train_size: int = 100_000) -> "IVFIndex":
        """Encode ``docs`` through the (already fitted) pipeline and build
        the coarse router + inverted lists."""
        x = apply_float_stages(self.float_stages, docs, "docs")
        storage = self.scorer.encode_docs(x)
        return self._install(storage, x, rng=rng, train_size=train_size)

    def _install(self, storage: jax.Array, x_route: jax.Array, rng=None,
                 train_size: int = 100_000) -> "IVFIndex":
        """Install pre-encoded ``storage`` with routing vectors ``x_route``
        (float, same row order) — shared by ``fit`` and
        :meth:`CompressedIndex.to_ivf <repro.retrieval.index.CompressedIndex.to_ivf>`."""
        n_docs = int(storage.shape[0])
        if n_docs == 0:
            raise ValueError("cannot fit an IVF index on an empty corpus")
        if rng is None:
            rng = jax.random.PRNGKey(0)
        x_route = jnp.asarray(x_route, jnp.float32)
        # clamp to this corpus, from the *requested* nlist — a refit on a
        # larger corpus gets the configured list count back
        self.nlist = max(1, min(self._nlist_requested, n_docs))
        train = x_route
        if n_docs > train_size:
            sel = jax.random.choice(rng, n_docs, (train_size,), replace=False)
            train = x_route[sel]
        self.centroids = kmeans_fit(train, self.nlist, self.kmeans_iters, rng)
        self._labels = np.asarray(assign(x_route, self.centroids))
        self.lists = jnp.asarray(build_padded_lists(self._labels, self.nlist))
        self.storage = storage
        self._n_docs = n_docs
        self._dim = int(x_route.shape[-1])
        self._version += 1
        self._source = None    # fresh fit: no longer a shared-storage view
        self._search_fn = None
        return self

    def add(self, docs: jax.Array) -> "IVFIndex":
        """Append docs, routing them to the *existing* centroids (no refit)."""
        if self.centroids is None:
            return self.fit(docs)
        x = apply_float_stages(self.float_stages, docs, "docs")
        enc = self.scorer.encode_docs(x)
        labels = np.asarray(assign(jnp.asarray(x, jnp.float32),
                                   self.centroids))
        self.storage = jnp.concatenate([self.storage, enc], axis=0)
        self._labels = np.concatenate([self._labels, labels])
        self.lists = jnp.asarray(build_padded_lists(self._labels, self.nlist))
        self._n_docs = int(self.storage.shape[0])
        self._version += 1
        self._source = None    # storage was copied on append: now our own
        self._search_fn = None
        return self

    def __len__(self) -> int:
        return self._n_docs

    @property
    def nbytes(self) -> int:
        """Bytes of the quantized document storage (the paper's metric)."""
        assert self.storage is not None
        return int(self.storage.size * self.storage.dtype.itemsize)

    @property
    def aux_nbytes(self) -> int:
        """Routing overhead: centroids + padded inverted lists."""
        aux = 0
        for a in (self.centroids, self.lists):
            if a is not None:
                aux += int(a.size * a.dtype.itemsize)
        return aux

    # -- search ------------------------------------------------------------
    def encode_queries(self, queries: jax.Array) -> jax.Array:
        """Queries through the float stages (no query-side quantization)."""
        return apply_float_stages(self.float_stages, queries, "queries")

    def _resolve_nprobe(self, nprobe: Optional[int]) -> int:
        nprobe = self.nprobe if nprobe is None else nprobe
        if nprobe < 1:
            raise ValueError("nprobe must be ≥ 1")
        return min(nprobe, self.nlist)

    def _fused_search_fn(self):
        """jit'd probe→gather→score→masked-top-k over the whole query path."""
        stages = tuple(self.float_stages)
        scorer = self.scorer
        sim = self.sim

        @functools.partial(jax.jit, static_argnames=("k", "nprobe"))
        def _search(queries, centroids, lists, storage, params, *, k, nprobe):
            q = queries
            for t in stages:
                q = t(q, "queries")
            s, cand, valid = probe_and_score(q, centroids, lists, storage,
                                             scorer, params, sim, nprobe)
            return masked_topk_by_id(s, jnp.where(valid, cand, -1), k)

        return _search

    def search(self, queries: jax.Array, k: int,
               nprobe: Optional[int] = None, query_chunk: int = 64,
               ) -> tuple[jax.Array, jax.Array]:
        """Top-``min(k, n_docs)`` over the probed lists.

        Slots with no reachable candidate (probed pool < k) come back with
        score ``-inf`` and id ``-1``; with ``nprobe == nlist`` every stored
        doc is reachable and the ranking matches exact search.
        """
        if self.storage is None:
            raise ValueError("IVFIndex is not fitted")
        if self._source is not None and \
                self._source[0]._version != self._source[1]:
            raise ValueError(
                "source CompressedIndex changed since to_ivf (add was "
                "called); the promoted IVF view shares its old storage — "
                "re-promote with to_ivf()")
        nprobe = self._resolve_nprobe(nprobe)
        k = resolve_k(k, self._n_docs)
        # k / nprobe are static_argnames: one jit wrapper specializes per
        # (k, nprobe) in its own trace cache
        if self._search_fn is None:
            self._search_fn = self._fused_search_fn()
        fn = self._search_fn
        queries = jnp.asarray(queries)
        params = self.scorer.params()
        vals_out, idx_out = [], []
        for s in range(0, queries.shape[0], query_chunk):
            v, i = fn(queries[s: s + query_chunk], self.centroids,
                      self.lists, self.storage, params, k=k, nprobe=nprobe)
            vals_out.append(v)
            idx_out.append(i)
        return jnp.concatenate(vals_out), jnp.concatenate(idx_out)

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        """Pipeline + storage + router + list layout: the full IVF artifact
        (cold-start search needs no access to the raw corpus)."""
        return {"pipeline": self.pipeline.state_dict(),
                "storage": self.storage,
                "centroids": self.centroids,
                "lists": self.lists,
                "labels": self._labels,
                "scorer_extra": self.scorer.extra_state(),
                "nlist": self.nlist,
                "nlist_requested": self._nlist_requested,
                "nprobe": self.nprobe,
                "n_docs": self._n_docs, "dim": self._dim,
                "version": self._version}

    def load_state_dict(self, sd: dict) -> "IVFIndex":
        self.pipeline.load_state_dict(sd["pipeline"])
        self.storage = jnp.asarray(sd["storage"])
        self.centroids = jnp.asarray(sd["centroids"])
        self.lists = jnp.asarray(sd["lists"])
        labels = sd.get("labels")
        self._labels = (np.asarray(labels) if labels is not None else None)
        self.scorer.load_extra_state(sd.get("scorer_extra", {}))
        self.nlist = int(sd["nlist"])
        self._nlist_requested = int(sd.get("nlist_requested", sd["nlist"]))
        self.nprobe = int(sd["nprobe"])
        self._n_docs = int(sd["n_docs"])
        self._dim = int(sd["dim"])
        self._version = int(sd.get("version", 0))
        self._source = None            # an artifact owns its storage
        self._search_fn = None
        return self

    def save(self, path: str) -> None:
        from repro.retrieval.api import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str) -> "IVFIndex":
        from repro.retrieval.api import load_index
        return load_index(path, expect=cls)


class IVFFlatIndex(IVFIndex):
    """Float-storage IVF (the seed's FAISS ``IndexIVFFlat`` analogue).

    Thin facade over :class:`IVFIndex` with no compression pipeline — kept
    for the Figure-1 benchmarks and as the uncompressed ANN baseline.
    """

    def __init__(self, nlist: int = 200, nprobe: int = 100, sim: str = "ip",
                 kmeans_iters: int = 15):
        super().__init__(None, nlist=nlist, nprobe=nprobe, sim=sim,
                         backend="jnp", kmeans_iters=kmeans_iters)

    @property
    def docs(self) -> Optional[jax.Array]:
        return self.storage
