"""IVF-Flat approximate nearest-neighbour index (our FAISS analogue).

Reproduces the paper's Figure-1 retrieval condition (FAISS ``IndexIVFFlat``,
nlist=200, nprobe=100): a k-means coarse quantizer partitions the index into
``nlist`` inverted lists; search scores only the ``nprobe`` lists nearest to
each query.  The paper's finding — a small *systematic* loss vs exact search
across all embedding models — is reproduced in
``benchmarks/fig1_models_faiss.py``.

Implementation notes (TPU/JAX adaptation): inverted lists are stored as one
padded (nlist, max_len) id matrix so probing is a dense gather; masked scoring
keeps everything jit-compatible.  For the production multi-pod path the lists
are sharded over devices (see retrieval/sharded.py) — IVF then reduces
per-device compute by nprobe/nlist while the collective schedule is unchanged.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.kmeans import assign, kmeans_fit
from repro.retrieval.topk import similarity


class IVFFlatIndex:
    def __init__(self, nlist: int = 200, nprobe: int = 100, sim: str = "ip",
                 kmeans_iters: int = 15):
        self.nlist = nlist
        self.nprobe = min(nprobe, nlist)
        self.sim = sim
        self.kmeans_iters = kmeans_iters
        self.centroids: Optional[jax.Array] = None
        self.lists: Optional[jax.Array] = None       # (nlist, max_len) ids, −1 pad
        self.docs: Optional[jax.Array] = None

    def fit(self, docs: jax.Array, rng=None, train_size: int = 100_000,
            ) -> "IVFFlatIndex":
        docs = jnp.asarray(docs, jnp.float32)
        self.docs = docs
        if rng is None:
            rng = jax.random.PRNGKey(0)
        train = docs
        if docs.shape[0] > train_size:
            sel = jax.random.choice(rng, docs.shape[0], (train_size,),
                                    replace=False)
            train = docs[sel]
        self.centroids = kmeans_fit(train, self.nlist, self.kmeans_iters, rng)
        labels = np.asarray(assign(docs, self.centroids))
        buckets = [np.where(labels == c)[0] for c in range(self.nlist)]
        max_len = max(1, max(len(b) for b in buckets))
        lists = np.full((self.nlist, max_len), -1, np.int32)
        for c, b in enumerate(buckets):
            lists[c, : len(b)] = b
        self.lists = jnp.asarray(lists)
        return self

    def __len__(self) -> int:
        return int(self.docs.shape[0]) if self.docs is not None else 0

    def search(self, queries: jax.Array, k: int, query_chunk: int = 64,
               ) -> tuple[jax.Array, jax.Array]:
        queries = jnp.asarray(queries, jnp.float32)
        vals_out, idx_out = [], []
        for s in range(0, queries.shape[0], query_chunk):
            v, i = _ivf_search_chunk(queries[s: s + query_chunk],
                                     self.centroids, self.lists, self.docs,
                                     k, self.nprobe, self.sim)
            vals_out.append(v)
            idx_out.append(i)
        return jnp.concatenate(vals_out), jnp.concatenate(idx_out)


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "sim"))
def _ivf_search_chunk(queries, centroids, lists, docs, k, nprobe, sim):
    # 1) coarse: nearest nprobe centroids per query
    cscores = similarity(queries, centroids, sim)
    _, probe = jax.lax.top_k(cscores, nprobe)              # (Q, nprobe)
    # 2) candidates: gather inverted lists
    cand = lists[probe].reshape(queries.shape[0], -1)      # (Q, C)
    valid = cand >= 0
    docs_c = docs[jnp.maximum(cand, 0)]                    # (Q, C, d)
    # 3) fine scoring
    if sim == "ip":
        s = jnp.einsum("qd,qcd->qc", queries, docs_c)
    else:  # l2
        diff = queries[:, None, :] - docs_c
        s = -jnp.sum(diff * diff, axis=-1)
    s = jnp.where(valid, s, -jnp.inf)
    kk = min(k, s.shape[1])
    vals, pos = jax.lax.top_k(s, kk)
    return vals, jnp.take_along_axis(cand, pos, axis=1)
