"""R-Precision evaluation (paper §3.1, following Petroni et al. 2021).

For query q with r(q) relevant documents, R-Precision is
``|relevant ∩ top-r(q) retrieved| / r(q)``, averaged over queries.

Relevance is a padded ``(Q, max_r)`` int32 array of document ids (−1 padding);
HotpotQA-style data has r = 2 for every query (two supporting documents).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.topk import similarity, topk_search


def recall_at_k(got, want) -> float:
    """Mean per-query overlap of retrieved ids with a reference top-k.

    ``want`` (Q, k) defines the reference set; ``got`` may have any column
    count (extra columns are extra chances, −1 pads never match).
    """
    got, want = np.asarray(got), np.asarray(want)
    k = want.shape[1]
    return float(np.mean([len(set(got[i]) & set(want[i])) / k
                          for i in range(want.shape[0])]))


def _hits_from_topk(idx: jax.Array, relevant: jax.Array) -> jax.Array:
    """Count relevant docs among the first r(q) retrieved, per query.

    idx: (Q, K) retrieved ids with K >= max_r; relevant: (Q, max_r), −1 pad.
    """
    r = jnp.sum(relevant >= 0, axis=1)                      # (Q,)
    pos_valid = jnp.arange(idx.shape[1])[None, :] < r[:, None]
    is_rel = jnp.any(idx[:, :, None] == relevant[:, None, :], axis=-1)
    return jnp.sum(is_rel & pos_valid, axis=1)              # (Q,)


@functools.partial(jax.jit, static_argnames=())
def r_precision_from_scores(scores: jax.Array,
                            relevant: jax.Array) -> jax.Array:
    """R-Precision from a dense (Q, D) score matrix (small-scale path)."""
    max_r = relevant.shape[1]
    _, idx = jax.lax.top_k(scores, max_r)
    r = jnp.maximum(jnp.sum(relevant >= 0, axis=1), 1)
    hits = _hits_from_topk(idx, relevant)
    return jnp.mean(hits / r)


def retrieved_relevant_counts(queries: jax.Array, docs: jax.Array,
                              relevant: jax.Array, sim: str = "ip",
                              doc_chunk: int = 131072) -> jax.Array:
    """Per-query number of relevant docs in the top-r(q) (paper Fig. 7)."""
    max_r = relevant.shape[1]
    _, idx = topk_search(queries, docs, max_r, sim=sim, doc_chunk=doc_chunk)
    return _hits_from_topk(idx, relevant)


def r_precision(queries: jax.Array, docs: jax.Array, relevant: jax.Array,
                sim: str = "ip", doc_chunk: int = 131072) -> float:
    """Streaming R-Precision over an arbitrarily large document index."""
    hits = retrieved_relevant_counts(queries, docs, relevant, sim, doc_chunk)
    r = jnp.maximum(jnp.sum(relevant >= 0, axis=1), 1)
    return float(jnp.mean(hits / r))


# ---------------------------------------------------------------------------
# Greedy-dimension-dropping scorer (paper §4.1) — per-dimension quality
# ---------------------------------------------------------------------------


def make_dim_drop_scorer(relevant: np.ndarray, sim: str = "ip",
                         n_queries: int = 256, n_docs: int = 8192,
                         dim_chunk: int = 16, seed: int = 0,
                         ) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Build the scorer used by :class:`GreedyDimensionDrop`.

    Returns ``scorer(queries, docs) → (d,)`` where entry i is the R-Precision
    *with dimension i removed* (evaluated on a fixed subsample that always
    contains each sampled query's relevant documents plus random distractors).
    The rank-1 update ``S_i = S − q_i d_iᵀ`` makes the 768 evaluations cheap:
    one (Q, D) GEMM total, then d rank-1 updates.
    """
    relevant = np.asarray(relevant)

    def scorer(queries: jax.Array, docs: jax.Array) -> jax.Array:
        rng = np.random.default_rng(seed)
        n_q = min(n_queries, queries.shape[0])
        qi = rng.choice(queries.shape[0], size=n_q, replace=False)
        rel = relevant[qi]                                    # (q, max_r)
        needed = np.unique(rel[rel >= 0])
        n_total = docs.shape[0]
        budget = max(n_docs - needed.size, 0)
        extra = rng.choice(n_total, size=min(budget, n_total), replace=False)
        doc_ids = np.unique(np.concatenate([needed, extra]))
        lookup = np.full((n_total,), -1, np.int64)
        lookup[doc_ids] = np.arange(doc_ids.size)
        rel_local = np.where(rel >= 0, lookup[np.maximum(rel, 0)], -1)
        rel_local = jnp.asarray(rel_local.astype(np.int32))

        qs = jnp.asarray(queries)[qi].astype(jnp.float32)
        ds = jnp.asarray(docs)[doc_ids].astype(jnp.float32)
        base = similarity(qs, ds, sim)

        if sim == "ip":
            def drop_dim(i):
                return base - jnp.outer(qs[:, i], ds[:, i])
        elif sim == "l2":
            def drop_dim(i):
                diff2 = jnp.square(qs[:, i][:, None] - ds[:, i][None, :])
                return base + diff2  # base is negative sq-dist; add back dim i
        else:
            raise ValueError("greedy dim-drop scorer supports ip|l2")

        @jax.jit
        def eval_dims(dims):
            def one(i):
                return r_precision_from_scores(drop_dim(i), rel_local)
            return jax.vmap(one)(dims)

        d = queries.shape[-1]
        out = []
        for s in range(0, d, dim_chunk):
            dims = jnp.arange(s, min(s + dim_chunk, d))
            out.append(eval_dims(dims))
        return jnp.concatenate(out)

    return scorer
