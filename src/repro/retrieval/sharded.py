"""Distributed KB search over a device mesh (the production serving path).

Layout
------
* Document index: row-sharded over the ``doc_axis`` ("model" within a pod; the
  "pod" axis adds capacity — 2 pods hold 2× the KB).
* Queries: batch-sharded over ``query_axis`` ("data") when given, replicated
  otherwise.

Schedule (per query shard)::

    local scores (Q_local, D_local)          # GEMM/kernel, no comms
    local top-k                              # on-device
    all_gather over doc_axis → (shards·k)    # tiny: k·(score+id) per shard
    global top-k merge                       # on-device

Collective volume per query is ``O(n_doc_shards · k · 8 bytes)`` — independent
of index size, which is what makes the design scale to 1000+ nodes: adding
devices grows the KB linearly at constant per-query communication.

Quantized variants score via the *same* scorer backends as the single-host
:class:`~repro.retrieval.index.CompressedIndex`
(:mod:`repro.retrieval.scorers`): the shard-local GEMM is the Pallas hot
path, the merge is unchanged.  :class:`ShardedCompressedIndex` wraps the
whole thing behind the single-host ``build``/``add``/``search`` API.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pipeline import CompressionPipeline
from repro.parallel.compat import shard_map
from repro.retrieval.scorers import (Scorer, apply_float_stages,
                                     scorer_for_pipeline)
from repro.retrieval.topk import similarity

AxisName = Union[str, Sequence[str]]


def _as_tuple(axis: Optional[AxisName]) -> tuple[str, ...]:
    if axis is None:
        return ()
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _axis_spec(axes: tuple[str, ...]):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def make_sharded_scorer_search(mesh: Mesh, scorer: Scorer, *, k: int = 10,
                               n_docs: Optional[int] = None,
                               doc_axis: AxisName = "model",
                               query_axis: Optional[AxisName] = None):
    """shard_map'd quantized search: (queries, storage, params) → (vals, ids).

    ``storage`` is the scorer's encoded representation, row-sharded over
    ``doc_axis`` (rows may be padded to divide the shard count — pass the
    true ``n_docs`` and padded rows are masked out of the top-k).  ``params``
    is ``scorer.params()``; it is threaded through explicitly (replicated)
    so the mapped function closes over no device arrays.
    """
    doc_axes = _as_tuple(doc_axis)
    q_axes = _as_tuple(query_axis)
    if not doc_axes:
        raise ValueError("doc_axis must name at least one mesh axis")

    def local_search(q, storage_shard, params):
        shard_id = jnp.zeros((), jnp.int32)
        for a in doc_axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        d_local = storage_shard.shape[0]
        scores = scorer.scores(q, storage_shard, params=params)
        gidx_all = shard_id * d_local + jnp.arange(d_local, dtype=jnp.int32)
        if n_docs is not None:
            # rows padded to divide the shard count never win the top-k
            scores = jnp.where(gidx_all[None, :] < n_docs, scores, -jnp.inf)
        kk = min(k, d_local)
        vals, idx = jax.lax.top_k(scores, kk)
        gidx = jnp.take(gidx_all, idx)
        for a in doc_axes:
            vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
            gidx = jax.lax.all_gather(gidx, a, axis=1, tiled=True)
        k_out = min(k, vals.shape[1] if n_docs is None else n_docs)
        fvals, pos = jax.lax.top_k(vals, k_out)
        fidx = jnp.take_along_axis(gidx, pos, axis=1)
        return fvals, fidx

    q_spec = P(_axis_spec(q_axes), None)
    in_specs = (q_spec, P(_axis_spec(doc_axes), None), P())
    out_specs = (q_spec,) * 2
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return jax.jit(fn)


def make_distributed_search(mesh: Mesh, *, sim: str = "ip", k: int = 10,
                            query_axis: AxisName = "data",
                            doc_axis: AxisName = "model"):
    """Float-GEMM sharded search: (queries, docs) → (scores, global ids).

    Kept for the dense/uncompressed path; the quantized backends go through
    :func:`make_sharded_scorer_search` (identical schedule, scorer kernels).
    ``doc_axis`` may be a tuple (e.g. ("pod", "model")) — the KB is then
    sharded over the combined axes and the gather happens over both.
    """
    doc_axes = _as_tuple(doc_axis)
    q_axes = _as_tuple(query_axis)

    def local_search(q, d_shard):
        # shard ids along the doc axes → global row offset of this shard
        shard_id = jnp.zeros((), jnp.int32)
        for a in doc_axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        d_local = d_shard.shape[0]
        scores = similarity(q, d_shard, sim)
        kk = min(k, d_local)
        vals, idx = jax.lax.top_k(scores, kk)
        gidx = idx + shard_id * d_local
        # gather candidates from every doc shard: (n_shards·k) per query
        all_vals = vals
        all_idx = gidx
        for a in doc_axes:
            all_vals = jax.lax.all_gather(all_vals, a, axis=1, tiled=True)
            all_idx = jax.lax.all_gather(all_idx, a, axis=1, tiled=True)
        fvals, pos = jax.lax.top_k(all_vals, min(k, all_vals.shape[1]))
        fidx = jnp.take_along_axis(all_idx, pos, axis=1)
        return fvals, fidx

    in_specs = (P(_axis_spec(q_axes), None), P(_axis_spec(doc_axes), None))
    out_specs = (P(_axis_spec(q_axes), None),) * 2

    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return jax.jit(fn)


def shard_index(docs: jax.Array, mesh: Mesh, doc_axis: AxisName = "model"
                ) -> jax.Array:
    """Place a host array as a row-sharded device array on the mesh."""
    spec = P(_axis_spec(_as_tuple(doc_axis)), None)
    return jax.device_put(docs, NamedSharding(mesh, spec))


class ShardedCompressedIndex:
    """Compressed index row-sharded over a mesh, single-host API.

    Mirrors :class:`~repro.retrieval.index.CompressedIndex`
    (``build`` / ``add`` / ``search`` / ``nbytes``) but keeps the encoded
    storage as a device array sharded over ``doc_axis`` and scores each
    shard locally through the same scorer backend, merging per-shard top-k
    candidates with a constant-volume all-gather.  Rankings are identical
    to the single-host index (see tests/test_sharded_index.py).
    """

    def __init__(self, pipeline: CompressionPipeline, mesh: Mesh,
                 sim: str = "ip", backend: str = "auto",
                 doc_axis: AxisName = "model",
                 query_axis: Optional[AxisName] = None):
        self.pipeline = pipeline
        self.mesh = mesh
        self.sim = sim
        self.backend = backend
        self.doc_axes = _as_tuple(doc_axis)
        self.query_axis = query_axis
        self.float_stages, self.scorer = scorer_for_pipeline(
            pipeline, sim=sim, backend=backend)
        self._storage_host: Optional[jax.Array] = None  # unpadded, unsharded
        self._placed: Optional[jax.Array] = None        # padded, mesh-sharded
        self._search_fns: dict[int, object] = {}
        self._n_docs = 0
        self._dim = 0

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array, queries_sample: Optional[jax.Array],
              pipeline: CompressionPipeline, mesh: Mesh, sim: str = "ip",
              backend: str = "auto", doc_axis: AxisName = "model",
              query_axis: Optional[AxisName] = None,
              rng=None) -> "ShardedCompressedIndex":
        pipeline.fit(docs, queries_sample, rng=rng)
        idx = cls(pipeline, mesh, sim=sim, backend=backend,
                  doc_axis=doc_axis, query_axis=query_axis)
        idx.add(docs)
        return idx

    @property
    def n_doc_shards(self) -> int:
        n = 1
        for a in self.doc_axes:
            n *= self.mesh.shape[a]
        return n

    def add(self, docs: jax.Array) -> "ShardedCompressedIndex":
        x = apply_float_stages(self.float_stages, docs, "docs")
        self._dim = int(x.shape[-1])
        enc = self.scorer.encode_docs(x)
        if self._storage_host is None:
            self._storage_host = enc
        else:
            self._storage_host = jnp.concatenate([self._storage_host, enc],
                                                 axis=0)
        self._n_docs = int(self._storage_host.shape[0])
        self._placed = None            # re-place lazily on next search
        self._search_fns.clear()       # n_docs is baked into the mask
        return self

    def __len__(self) -> int:
        return self._n_docs

    @property
    def nbytes(self) -> int:
        assert self._storage_host is not None
        return int(self._storage_host.size * self._storage_host.dtype.itemsize)

    # -- search ------------------------------------------------------------
    def _placed_storage(self) -> jax.Array:
        if self._placed is None:
            enc = self._storage_host
            pad = (-enc.shape[0]) % self.n_doc_shards
            if pad:
                enc = jnp.concatenate(
                    [enc, jnp.zeros((pad,) + enc.shape[1:], enc.dtype)],
                    axis=0)
            self._placed = shard_index(enc, self.mesh, self.doc_axes)
        return self._placed

    def encode_queries(self, queries: jax.Array) -> jax.Array:
        return apply_float_stages(self.float_stages, queries, "queries")

    def search(self, queries: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        k = min(k, self._n_docs)
        if k not in self._search_fns:
            self._search_fns[k] = make_sharded_scorer_search(
                self.mesh, self.scorer, k=k, n_docs=self._n_docs,
                doc_axis=self.doc_axes, query_axis=self.query_axis)
        q = self.scorer.encode_queries(self.encode_queries(queries))
        return self._search_fns[k](q, self._placed_storage(),
                                   self.scorer.params())
