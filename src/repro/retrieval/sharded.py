"""Distributed KB search over a device mesh (the production serving path).

Layout
------
* Document index: row-sharded over the ``doc_axis`` ("model" within a pod; the
  "pod" axis adds capacity — 2 pods hold 2× the KB).
* Queries: batch-sharded over ``query_axis`` ("data") when given, replicated
  otherwise.

Schedule (per query shard)::

    local scores (Q_local, D_local)          # GEMM/kernel, no comms
    local top-k                              # on-device
    all_gather over doc_axis → (shards·k)    # tiny: k·(score+id) per shard
    global top-k merge                       # on-device

Collective volume per query is ``O(n_doc_shards · k · 8 bytes)`` — independent
of index size, which is what makes the design scale to 1000+ nodes: adding
devices grows the KB linearly at constant per-query communication.

Quantized variants score via the *same* scorer backends as the single-host
:class:`~repro.retrieval.index.CompressedIndex`
(:mod:`repro.retrieval.scorers`): the shard-local GEMM is the Pallas hot
path, the merge is unchanged.  :class:`ShardedCompressedIndex` wraps the
whole thing behind the single-host ``build``/``add``/``search`` API.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pipeline import CompressionPipeline
from repro.parallel.compat import shard_map
from repro.parallel.placement import place_shards
from repro.retrieval.ivf import IVFIndex, probe_and_score
from repro.retrieval.scorers import (Scorer, apply_float_stages,
                                     scorer_for_pipeline)
from repro.retrieval.topk import (masked_topk_by_id, resolve_k, similarity)

AxisName = Union[str, Sequence[str]]


def _as_tuple(axis: Optional[AxisName]) -> tuple[str, ...]:
    if axis is None:
        return ()
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _axis_spec(axes: tuple[str, ...]):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _pad_queries(q: jax.Array, n_query_shards: int
                 ) -> tuple[jax.Array, int]:
    """Pad query rows to divide the query (replica) axis; returns the
    padded block and the true row count so callers trim the outputs.
    Padded rows score but never surface — the trim drops them whole."""
    n = int(q.shape[0])
    pad = (-n) % max(1, n_query_shards)
    if pad:
        q = jnp.concatenate(
            [q, jnp.zeros((pad,) + q.shape[1:], q.dtype)], axis=0)
    return q, n


def make_sharded_scorer_search(mesh: Mesh, scorer: Scorer, *, k: int = 10,
                               n_docs: Optional[int] = None,
                               doc_axis: AxisName = "model",
                               query_axis: Optional[AxisName] = None):
    """shard_map'd quantized search: (queries, storage, params) → (vals, ids).

    ``storage`` is the scorer's encoded representation, row-sharded over
    ``doc_axis`` (rows may be padded to divide the shard count — pass the
    true ``n_docs`` and padded rows are masked out of the top-k).  ``params``
    is ``scorer.params()``; it is threaded through explicitly (replicated)
    so the mapped function closes over no device arrays.
    """
    doc_axes = _as_tuple(doc_axis)
    q_axes = _as_tuple(query_axis)
    if not doc_axes:
        raise ValueError("doc_axis must name at least one mesh axis")

    def local_search(q, storage_shard, params):
        shard_id = jnp.zeros((), jnp.int32)
        for a in doc_axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        d_local = storage_shard.shape[0]
        scores = scorer.scores(q, storage_shard, params=params)
        gidx_all = shard_id * d_local + jnp.arange(d_local, dtype=jnp.int32)
        if n_docs is not None:
            # rows padded to divide the shard count never win the top-k
            scores = jnp.where(gidx_all[None, :] < n_docs, scores, -jnp.inf)
        kk = min(k, d_local)
        vals, idx = jax.lax.top_k(scores, kk)
        gidx = jnp.take(gidx_all, idx)
        for a in doc_axes:
            vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
            gidx = jax.lax.all_gather(gidx, a, axis=1, tiled=True)
        k_out = min(k, vals.shape[1] if n_docs is None else n_docs)
        fvals, pos = jax.lax.top_k(vals, k_out)
        fidx = jnp.take_along_axis(gidx, pos, axis=1)
        return fvals, fidx

    q_spec = P(_axis_spec(q_axes), None)
    in_specs = (q_spec, P(_axis_spec(doc_axes), None), P())
    out_specs = (q_spec,) * 2
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return jax.jit(fn)


def make_distributed_search(mesh: Mesh, *, sim: str = "ip", k: int = 10,
                            query_axis: AxisName = "data",
                            doc_axis: AxisName = "model"):
    """Float-GEMM sharded search: (queries, docs) → (scores, global ids).

    Kept for the dense/uncompressed path; the quantized backends go through
    :func:`make_sharded_scorer_search` (identical schedule, scorer kernels).
    ``doc_axis`` may be a tuple (e.g. ("pod", "model")) — the KB is then
    sharded over the combined axes and the gather happens over both.
    """
    doc_axes = _as_tuple(doc_axis)
    q_axes = _as_tuple(query_axis)

    def local_search(q, d_shard):
        # shard ids along the doc axes → global row offset of this shard
        shard_id = jnp.zeros((), jnp.int32)
        for a in doc_axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        d_local = d_shard.shape[0]
        scores = similarity(q, d_shard, sim)
        kk = min(k, d_local)
        vals, idx = jax.lax.top_k(scores, kk)
        gidx = idx + shard_id * d_local
        # gather candidates from every doc shard: (n_shards·k) per query
        all_vals = vals
        all_idx = gidx
        for a in doc_axes:
            all_vals = jax.lax.all_gather(all_vals, a, axis=1, tiled=True)
            all_idx = jax.lax.all_gather(all_idx, a, axis=1, tiled=True)
        fvals, pos = jax.lax.top_k(all_vals, min(k, all_vals.shape[1]))
        fidx = jnp.take_along_axis(all_idx, pos, axis=1)
        return fvals, fidx

    in_specs = (P(_axis_spec(q_axes), None), P(_axis_spec(doc_axes), None))
    out_specs = (P(_axis_spec(q_axes), None),) * 2

    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return jax.jit(fn)


def shard_index(docs: jax.Array, mesh: Mesh, doc_axis: AxisName = "model"
                ) -> jax.Array:
    """Place a host array as a row-sharded device array on the mesh."""
    spec = P(_axis_spec(_as_tuple(doc_axis)), None)
    return jax.device_put(docs, NamedSharding(mesh, spec))


class ShardedCompressedIndex:
    """Compressed index row-sharded over a mesh, single-host API.

    Mirrors :class:`~repro.retrieval.index.CompressedIndex`
    (``build`` / ``add`` / ``search`` / ``nbytes``) but keeps the encoded
    storage as a device array sharded over ``doc_axis`` and scores each
    shard locally through the same scorer backend, merging per-shard top-k
    candidates with a constant-volume all-gather.  Rankings are identical
    to the single-host index (see tests/test_sharded_index.py).
    """

    #: sharded storage is always fully resident (Index-protocol surface:
    #: the serving tier rollup reads ``store`` uniformly)
    store = None

    def __init__(self, pipeline: CompressionPipeline, mesh: Mesh,
                 sim: str = "ip", backend: str = "auto",
                 doc_axis: AxisName = "model",
                 query_axis: Optional[AxisName] = None):
        self.pipeline = pipeline
        self.mesh = mesh
        self.sim = sim
        self.backend = backend
        self.doc_axes = _as_tuple(doc_axis)
        self.query_axis = query_axis
        self.float_stages, self.scorer = scorer_for_pipeline(
            pipeline, sim=sim, backend=backend)
        self._storage_host: Optional[jax.Array] = None  # unpadded, unsharded
        self._placed: Optional[jax.Array] = None        # padded, mesh-sharded
        self._search_fns: dict[int, object] = {}
        self.spec = None               # set by api.build_index / api.load_index
        self._n_docs = 0
        self._dim = 0

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array, queries_sample: Optional[jax.Array],
              pipeline: CompressionPipeline, mesh: Mesh, sim: str = "ip",
              backend: str = "auto", doc_axis: AxisName = "model",
              query_axis: Optional[AxisName] = None,
              rng=None) -> "ShardedCompressedIndex":
        pipeline.fit(docs, queries_sample, rng=rng)
        idx = cls(pipeline, mesh, sim=sim, backend=backend,
                  doc_axis=doc_axis, query_axis=query_axis)
        idx.add(docs)
        return idx

    @property
    def n_doc_shards(self) -> int:
        n = 1
        for a in self.doc_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def n_query_shards(self) -> int:
        n = 1
        for a in _as_tuple(self.query_axis):
            n *= self.mesh.shape[a]
        return n

    @property
    def storage(self):
        """Unsharded encoded rows (single-host view for persistence and
        the mutable wrapper's compaction path)."""
        return self._storage_host

    def shard_stats(self) -> list[dict]:
        """Per-shard rollup for ``RetrievalService.stats()``: rows are
        split evenly over the doc shards (padding rows excluded)."""
        n, s = self._n_docs, self.n_doc_shards
        rows_per = (n + (-n) % s) // s if n else 0
        return [{"shard": i,
                 "n_docs": int(max(0, min(rows_per, n - i * rows_per)))}
                for i in range(s)]

    def add(self, docs: jax.Array) -> "ShardedCompressedIndex":
        x = apply_float_stages(self.float_stages, docs, "docs")
        self._dim = int(x.shape[-1])
        enc = self.scorer.encode_docs(x)
        if self._storage_host is None:
            self._storage_host = enc
        else:
            self._storage_host = jnp.concatenate([self._storage_host, enc],
                                                 axis=0)
        self._n_docs = int(self._storage_host.shape[0])
        self._placed = None            # re-place lazily on next search
        self._search_fns.clear()       # n_docs is baked into the mask
        return self

    def __len__(self) -> int:
        return self._n_docs

    @property
    def nbytes(self) -> int:
        assert self._storage_host is not None
        return int(self._storage_host.size * self._storage_host.dtype.itemsize)

    def place(self) -> "ShardedCompressedIndex":
        """Force mesh placement *now* (it is otherwise lazy until the
        first search): every shard lands on its device or this raises.
        The serving layer calls this at engine construction so staging a
        sharded version is all-or-none rather than failing mid-query."""
        self._placed_storage()
        return self

    # -- search ------------------------------------------------------------
    def _placed_storage(self) -> jax.Array:
        if self._placed is None:
            enc = self._storage_host
            pad = (-enc.shape[0]) % self.n_doc_shards
            if pad:
                enc = jnp.concatenate(
                    [enc, jnp.zeros((pad,) + enc.shape[1:], enc.dtype)],
                    axis=0)
            spec = P(_axis_spec(self.doc_axes), None)
            self._placed, = place_shards([enc], self.mesh, [spec],
                                         n_shards=self.n_doc_shards)
        return self._placed

    def encode_queries(self, queries: jax.Array) -> jax.Array:
        return apply_float_stages(self.float_stages, queries, "queries")

    def search(self, queries: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        k = resolve_k(k, self._n_docs)
        if k not in self._search_fns:
            self._search_fns[k] = make_sharded_scorer_search(
                self.mesh, self.scorer, k=k, n_docs=self._n_docs,
                doc_axis=self.doc_axes, query_axis=self.query_axis)
        q = self.scorer.encode_queries(self.encode_queries(queries))
        q, n = _pad_queries(q, self.n_query_shards)
        vals, ids = self._search_fns[k](q, self._placed_storage(),
                                        self.scorer.params())
        return vals[:n], ids[:n]

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        """Single-host state: the *unsharded* encoded storage plus pipeline
        state.  Mesh placement is reconstructed at load time (pass the
        mesh to :func:`repro.retrieval.api.load_index`)."""
        return {"pipeline": self.pipeline.state_dict(),
                "storage": self._storage_host,
                "scorer_extra": self.scorer.extra_state(),
                "n_docs": self._n_docs, "dim": self._dim}

    def load_state_dict(self, sd: dict) -> "ShardedCompressedIndex":
        self.pipeline.load_state_dict(sd["pipeline"])
        self._storage_host = jnp.asarray(sd["storage"])
        self.scorer.load_extra_state(sd.get("scorer_extra", {}))
        self._n_docs = int(sd["n_docs"])
        self._dim = int(sd["dim"])
        self._placed = None
        self._search_fns.clear()
        return self

    def save(self, path: str) -> None:
        from repro.retrieval.api import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str, mesh: Optional[Mesh] = None, *,
             shard=None) -> "ShardedCompressedIndex":
        """Load from an artifact; the mesh derives from the embedded (or
        passed) ShardSpec — ``mesh=`` is deprecated but still honoured."""
        from repro.retrieval.api import load_index
        return load_index(path, mesh=mesh, expect=cls, shard=shard)


# ---------------------------------------------------------------------------
# sharded IVF: inverted lists partitioned over the doc shards
# ---------------------------------------------------------------------------


def make_sharded_ivf_search(mesh: Mesh, scorer: Scorer, *, sim: str,
                            k: int, nprobe: int,
                            doc_axis: AxisName = "model",
                            query_axis: Optional[AxisName] = None):
    """shard_map'd IVF search.

    ``(q_float, centroids, lists, storage, gids, params) → (vals, ids)``
    where ``lists`` holds *shard-local* row indices (−1 for pad / lists the
    shard does not own), ``storage`` the shard-local encoded rows, and
    ``gids`` the local-row → global-doc-id map.  Every shard routes the
    (replicated) queries identically on the replicated centroids, scores
    only the probed lists it owns, and the per-shard top-k candidates merge
    through the same constant-volume all-gather as the flat sharded search.
    """
    doc_axes = _as_tuple(doc_axis)
    q_axes = _as_tuple(query_axis)
    if not doc_axes:
        raise ValueError("doc_axis must name at least one mesh axis")

    def local_search(q, centroids, lists, storage, gids, params):
        # coarse routing is identical on every shard (replicated inputs);
        # the shard scores only the probed lists it owns
        s, cand, valid = probe_and_score(q, centroids, lists, storage,
                                         scorer, params, sim, nprobe)
        g = jnp.where(valid, gids[jnp.maximum(cand, 0)], -1)
        # (score desc, id asc) everywhere — same strict total order as the
        # single-host IVF, so the shard merge cannot reorder ties
        vals, ids = masked_topk_by_id(s, g, k)
        for a in doc_axes:
            vals = jax.lax.all_gather(vals, a, axis=1, tiled=True)
            ids = jax.lax.all_gather(ids, a, axis=1, tiled=True)
        return masked_topk_by_id(vals, ids, k)

    q_spec = P(_axis_spec(q_axes), None)
    doc_spec = P(_axis_spec(doc_axes), None)
    in_specs = (q_spec, P(), doc_spec, doc_spec, P(_axis_spec(doc_axes)), P())
    out_specs = (q_spec,) * 2
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return jax.jit(fn)


def partition_ivf_lists(lists: np.ndarray, storage: np.ndarray,
                        n_shards: int
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
    """Partition inverted lists over shards, greedily balancing doc counts.

    ``lists`` is the (nlist, max_len) global-doc-id matrix (−1 padded);
    ``storage`` the (n_docs, …) encoded rows.  Returns stacked per-shard
    arrays splittable along axis 0 by ``shard_map``, plus the ownership
    map:

    * ``lists_stacked``   (n_shards·nlist, max_len) — local row ids, −1 for
      pad *and* for lists the shard does not own;
    * ``storage_stacked`` (n_shards·rows_max, …)    — shard-local rows;
    * ``gids_stacked``    (n_shards·rows_max,)      — global doc ids, −1 pad;
    * ``owner``           (nlist,)                  — which shard owns each
      list (feeds the per-shard stats rollup and the delta-segment
      placement preview).
    """
    nlist, max_len = lists.shape
    sizes = (lists >= 0).sum(axis=1)
    owner = np.zeros(nlist, np.int32)
    loads = np.zeros(n_shards, np.int64)
    for c in np.argsort(-sizes, kind="stable"):   # biggest list first
        s = int(np.argmin(loads))
        owner[c] = s
        loads[s] += sizes[c]
    rows_max = max(1, int(loads.max()))

    lists_stacked = np.full((n_shards * nlist, max_len), -1, np.int32)
    storage_stacked = np.zeros((n_shards * rows_max,) + storage.shape[1:],
                               storage.dtype)
    gids_stacked = np.full((n_shards * rows_max,), -1, np.int32)
    for s in range(n_shards):
        r = 0
        for c in np.flatnonzero(owner == s):
            ids = lists[c][lists[c] >= 0]
            storage_stacked[s * rows_max + r: s * rows_max + r + len(ids)] = \
                storage[ids]
            gids_stacked[s * rows_max + r: s * rows_max + r + len(ids)] = ids
            lists_stacked[s * nlist + c, : len(ids)] = \
                np.arange(r, r + len(ids), dtype=np.int32)
            r += len(ids)
    return lists_stacked, storage_stacked, gids_stacked, owner


class ShardedIVFIndex:
    """IVF index with inverted lists partitioned over the mesh's doc shards.

    Each shard owns a balanced subset of the lists *and* the quantized
    storage rows of exactly those lists, so adding devices grows KB
    capacity linearly while per-query compute stays at the probed fraction.
    Wraps a fitted :class:`~repro.retrieval.ivf.IVFIndex` (centroids and
    list assignment are taken as-is, so rankings match the single-host
    index exactly; see tests/test_sharded_ivf.py).
    """

    #: sharded lists are always fully resident (Index-protocol surface:
    #: the serving tier rollup reads ``store`` uniformly)
    store = None

    def __init__(self, ivf: IVFIndex, mesh: Mesh,
                 doc_axis: AxisName = "model",
                 query_axis: Optional[AxisName] = None):
        if ivf.storage is None:
            raise ValueError("IVFIndex must be fitted before sharding")
        if getattr(ivf, "residual", False):
            raise ValueError(
                "ShardedIVFIndex cannot wrap a residual-encoded IVFIndex: "
                "the shard-local probe_and_score path has no routed "
                "q\u00b7centroid correction — build with residual=False")
        self.ivf = ivf
        self.mesh = mesh
        self.doc_axes = _as_tuple(doc_axis)
        self.query_axis = query_axis
        self.scorer = ivf.scorer
        self.float_stages = ivf.float_stages
        self.sim = ivf.sim
        self._snapshot_version = ivf._version   # partition frozen at this fit
        lists_s, storage_s, gids_s, owner = partition_ivf_lists(
            np.asarray(ivf.lists), np.asarray(ivf.storage),
            self.n_doc_shards)
        self.list_owner = owner        # (nlist,) → shard, for stats rollup
        doc_spec = P(_axis_spec(self.doc_axes), None)
        gid_spec = P(_axis_spec(self.doc_axes))
        self._lists, self._storage, self._gids = place_shards(
            [jnp.asarray(lists_s), jnp.asarray(storage_s),
             jnp.asarray(gids_s)],
            mesh, [doc_spec, doc_spec, gid_spec],
            n_shards=self.n_doc_shards)
        self._search_fns: dict[tuple[int, int], object] = {}
        self.spec = None               # set by api.build_index / api.load_index

    def place(self) -> "ShardedIVFIndex":
        """Already placed — the constructor put every shard's lists,
        storage, and gid map on its device (or raised).  Kept so the
        serving layer can call ``place()`` uniformly on any sharded
        index at engine construction."""
        return self

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, docs: jax.Array,
              queries_sample: Optional[jax.Array] = None,
              pipeline: Optional[CompressionPipeline] = None, *,
              mesh: Mesh, nlist: int = 200, nprobe: int = 100,
              sim: str = "ip", backend: str = "auto",
              kmeans_iters: int = 15, doc_axis: AxisName = "model",
              query_axis: Optional[AxisName] = None,
              rng=None) -> "ShardedIVFIndex":
        ivf = IVFIndex.build(docs, queries_sample, pipeline, nlist=nlist,
                             nprobe=nprobe, sim=sim, backend=backend,
                             kmeans_iters=kmeans_iters, rng=rng)
        return cls(ivf, mesh, doc_axis=doc_axis, query_axis=query_axis)

    @property
    def n_doc_shards(self) -> int:
        n = 1
        for a in self.doc_axes:
            n *= self.mesh.shape[a]
        return n

    def __len__(self) -> int:
        return len(self.ivf)

    def add(self, docs: jax.Array) -> "ShardedIVFIndex":
        """The list partition is frozen at construction — grow the wrapped
        :class:`IVFIndex` and rebuild the wrapper instead."""
        raise NotImplementedError(
            "ShardedIVFIndex cannot add in place; call ivf.add(docs) and "
            "re-wrap with ShardedIVFIndex(ivf, mesh)")

    @property
    def nbytes(self) -> int:
        return self.ivf.nbytes

    @property
    def nlist(self) -> int:
        return self.ivf.nlist

    @property
    def nprobe(self) -> int:
        return self.ivf.nprobe

    # -- Index-protocol surface delegated to the wrapped single-host IVF
    # (lets SegmentedIndex layer deltas over a sharded main and the serving
    # stats read one schema) ------------------------------------------------
    @property
    def centroids(self):
        return self.ivf.centroids

    @property
    def pipeline(self):
        return self.ivf.pipeline

    @property
    def storage(self):
        """Unsharded encoded rows (single-host view for persistence and
        the mutable wrapper's compaction path)."""
        return self.ivf.storage

    @property
    def lists(self):
        return self.ivf.lists

    @property
    def backend(self):
        return self.ivf.backend

    @property
    def residual(self) -> bool:
        return False                   # rejected at construction

    @property
    def _version(self):
        return self.ivf._version

    @property
    def _nlist_requested(self):
        return self.ivf._nlist_requested

    @property
    def kmeans_iters(self):
        return self.ivf.kmeans_iters

    @property
    def kmeans_init(self):
        return self.ivf.kmeans_init

    @property
    def balanced(self):
        return self.ivf.balanced

    def _resolve_nprobe(self, nprobe: Optional[int]) -> int:
        return self.ivf._resolve_nprobe(nprobe)

    def prefetch(self, queries: jax.Array,
                 nprobe: Optional[int] = None) -> int:
        return 0                       # always fully resident

    @property
    def n_query_shards(self) -> int:
        n = 1
        for a in _as_tuple(self.query_axis):
            n *= self.mesh.shape[a]
        return n

    def shard_stats(self) -> list[dict]:
        """Per-shard rollup for ``RetrievalService.stats()``: docs and
        inverted lists owned by each shard under the greedy partition."""
        owner = self.list_owner
        sizes = (np.asarray(self.ivf.lists) >= 0).sum(axis=1)
        return [{"shard": s,
                 "n_docs": int(sizes[owner == s].sum()),
                 "n_lists": int((owner == s).sum())}
                for s in range(self.n_doc_shards)]

    # -- search ------------------------------------------------------------
    def encode_queries(self, queries: jax.Array) -> jax.Array:
        return apply_float_stages(self.float_stages, queries, "queries")

    def search(self, queries: jax.Array, k: int,
               nprobe: Optional[int] = None
               ) -> tuple[jax.Array, jax.Array]:
        if self.ivf._version != self._snapshot_version:
            raise ValueError(
                "wrapped IVFIndex changed since sharding (fit/add was "
                "called); the list partition is frozen at construction — "
                "rebuild the ShardedIVFIndex")
        nprobe = self.ivf._resolve_nprobe(nprobe)
        k = resolve_k(k, len(self.ivf))
        key = (k, nprobe)
        if key not in self._search_fns:
            self._search_fns[key] = make_sharded_ivf_search(
                self.mesh, self.scorer, sim=self.sim, k=k, nprobe=nprobe,
                doc_axis=self.doc_axes, query_axis=self.query_axis)
        q = self.encode_queries(queries)
        q, n = _pad_queries(q, self.n_query_shards)
        vals, ids = self._search_fns[key](q, self.ivf.centroids, self._lists,
                                          self._storage, self._gids,
                                          self.scorer.params())
        return vals[:n], ids[:n]

    # -- persistence -------------------------------------------------------
    def state_dict(self) -> dict:
        """The wrapped single-host IVF state; the shard partition is a pure
        function of (lists, storage, n_shards) and is recomputed at load."""
        return {"ivf": self.ivf.state_dict()}

    def load_state_dict(self, sd: dict) -> "ShardedIVFIndex":
        # the partition is frozen at construction; loading state into an
        # existing wrapper would desynchronise it — reconstruct instead
        raise NotImplementedError(
            "ShardedIVFIndex partitions at construction; use "
            "ShardedIVFIndex.load(path, mesh) / api.load_index")

    def save(self, path: str) -> None:
        from repro.retrieval.api import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str, mesh: Optional[Mesh] = None, *,
             shard=None) -> "ShardedIVFIndex":
        """Load from an artifact; the mesh derives from the embedded (or
        passed) ShardSpec — ``mesh=`` is deprecated but still honoured."""
        from repro.retrieval.api import load_index
        return load_index(path, mesh=mesh, expect=cls, shard=shard)
