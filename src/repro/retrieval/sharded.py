"""Distributed KB search over a device mesh (the production serving path).

Layout
------
* Document index: row-sharded over the ``doc_axis`` ("model" within a pod; the
  "pod" axis adds capacity — 2 pods hold 2× the KB).
* Queries: batch-sharded over ``query_axis`` ("data"), replicated over
  ``doc_axis``.

Schedule (per query shard)::

    local scores (Q_local, D_local)          # GEMM, no comms
    local top-k                              # on-device
    all_gather over doc_axis → (shards·k)    # tiny: k·(score+id) per shard
    global top-k merge                       # on-device

Collective volume per query is ``O(n_doc_shards · k · 8 bytes)`` — independent
of index size, which is what makes the design scale to 1000+ nodes: adding
devices grows the KB linearly at constant per-query communication.

Quantized variants score via the same kernels as the single-host
:class:`~repro.retrieval.index.CompressedIndex` (the shard-local GEMM is the
Pallas hot path; the merge is unchanged).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.retrieval.topk import similarity


def make_distributed_search(mesh: Mesh, *, sim: str = "ip", k: int = 10,
                            query_axis="data", doc_axis="model"):
    """Build a shard_map'd search fn: (queries, docs) → (scores, global ids).

    ``doc_axis`` may be a tuple (e.g. ("pod", "model")) — the KB is then
    sharded over the combined axes and the gather happens over both.
    """
    doc_axes = (doc_axis,) if isinstance(doc_axis, str) else tuple(doc_axis)
    q_axes = (query_axis,) if isinstance(query_axis, str) else tuple(query_axis)

    def local_search(q, d_shard):
        # shard ids along the doc axes → global row offset of this shard
        shard_sizes = [jax.lax.axis_size(a) for a in doc_axes]
        shard_id = jnp.zeros((), jnp.int32)
        for a, size in zip(doc_axes, shard_sizes):
            shard_id = shard_id * size + jax.lax.axis_index(a)
        d_local = d_shard.shape[0]
        scores = similarity(q, d_shard, sim)
        kk = min(k, d_local)
        vals, idx = jax.lax.top_k(scores, kk)
        gidx = idx + shard_id * d_local
        # gather candidates from every doc shard: (n_shards·k) per query
        all_vals = vals
        all_idx = gidx
        for a in doc_axes:
            all_vals = jax.lax.all_gather(all_vals, a, axis=1, tiled=True)
            all_idx = jax.lax.all_gather(all_idx, a, axis=1, tiled=True)
        fvals, pos = jax.lax.top_k(all_vals, min(k, all_vals.shape[1]))
        fidx = jnp.take_along_axis(all_idx, pos, axis=1)
        return fvals, fidx

    in_specs = (P(q_axes if len(q_axes) > 1 else q_axes[0], None),
                P(doc_axes if len(doc_axes) > 1 else doc_axes[0], None))
    out_specs = (P(q_axes if len(q_axes) > 1 else q_axes[0], None),) * 2

    fn = jax.shard_map(local_search, mesh=mesh,
                       in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
    return jax.jit(fn)


def shard_index(docs: jax.Array, mesh: Mesh, doc_axis="model") -> jax.Array:
    """Place a host array as a row-sharded device array on the mesh."""
    spec = P(doc_axis, None)
    return jax.device_put(docs, NamedSharding(mesh, spec))
