"""Exact maximum-similarity search with streaming (chunked) top-k.

Scoring never materialises the full (Q, D) matrix: the document axis is
scanned in chunks, keeping a running top-k per query (two-stage top-k — the
same schedule the Pallas kernels use on TPU, here expressed in jnp for the
host/reference path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def resolve_k(k: int, n_docs: int) -> int:
    """The one ``k`` contract for every index class.

    ``k`` must be ≥ 1; a ``k`` beyond the corpus clamps to ``n_docs`` (the
    result then simply has fewer columns).  All five index classes
    (:class:`~repro.retrieval.index.DenseIndex`,
    :class:`~repro.retrieval.index.CompressedIndex`,
    :class:`~repro.retrieval.ivf.IVFIndex`, and both sharded wrappers) route
    through this guard so the clamping behaviour cannot drift.
    """
    if k < 1:
        raise ValueError(f"k must be ≥ 1, got {k}")
    return min(int(k), int(n_docs))


def topk_score_then_id(s: jax.Array, ids: jax.Array, k: int
                       ) -> tuple[jax.Array, jax.Array]:
    """Top-k by (score desc, doc id asc) — a strict total order.

    Exact search breaks score ties by document id implicitly (candidates
    are scanned in id order and ``lax.top_k`` keeps the first occurrence);
    IVF candidates arrive in probe order, sharded IVF candidates in shard
    order, and segmented candidates in layer order
    (:mod:`repro.retrieval.segments`), so ties must be broken *explicitly*
    on the id for all the paths to produce identical rankings.  Matters
    most for the 1-bit backend, whose integer sign-dot scores tie
    constantly.
    """
    order = jnp.lexsort((ids, -s), axis=-1)[..., :k]
    return (jnp.take_along_axis(s, order, axis=-1),
            jnp.take_along_axis(ids, order, axis=-1))


def masked_topk_by_id(s: jax.Array, ids: jax.Array, k: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Top-``k`` by (score desc, id asc), normalising unreachable slots.

    ``-inf`` scores come back with id ``-1``; when fewer than ``k``
    candidate columns exist the output is padded out to ``k`` with
    ``(-inf, -1)``.  Shared by the single-host IVF search, both halves
    (shard-local and post-gather merge) of the sharded search, and the
    cross-layer merge of :class:`~repro.retrieval.segments.SegmentedIndex`,
    so the paths cannot drift apart.
    """
    kk = min(k, s.shape[1])
    vals, out = topk_score_then_id(s, ids, kk)
    out = jnp.where(jnp.isfinite(vals), out, -1)
    if kk < k:
        pad = k - kk
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        out = jnp.pad(out, ((0, 0), (0, pad)), constant_values=-1)
    return vals, out


def similarity(queries: jax.Array, docs: jax.Array, sim: str) -> jax.Array:
    """(Q, d) × (D, d) → (Q, D) similarity. sim ∈ {"ip", "l2", "cos"}.

    "l2" returns the *negative squared* L2 distance so that maximum-similarity
    search is uniform across metrics (argmax).
    """
    if sim == "ip":
        return queries @ docs.T
    if sim == "cos":
        qn = queries / (jnp.linalg.norm(queries, axis=-1, keepdims=True) + 1e-12)
        dn = docs / (jnp.linalg.norm(docs, axis=-1, keepdims=True) + 1e-12)
        return qn @ dn.T
    if sim == "l2":
        q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
        d2 = jnp.sum(docs * docs, axis=-1)
        return -(q2 + d2[None, :] - 2.0 * (queries @ docs.T))
    raise ValueError(f"unknown similarity {sim!r}")


@functools.partial(jax.jit, static_argnames=("k", "sim"))
def _topk_chunk(queries, docs, base, k, sim):
    scores = similarity(queries, docs, sim)
    kk = min(k, docs.shape[0])
    vals, idx = jax.lax.top_k(scores, kk)
    return vals, idx + base


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(vals_a, idx_a, vals_b, idx_b, k):
    """Merge two top-k candidate sets into one global top-k."""
    vals = jnp.concatenate([vals_a, vals_b], axis=-1)
    idx = jnp.concatenate([idx_a, idx_b], axis=-1)
    top_vals, pos = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(idx, pos, axis=-1)


def topk_search(queries: jax.Array, docs: jax.Array, k: int,
                sim: str = "ip", doc_chunk: int = 131072,
                query_chunk: int = 4096) -> tuple[jax.Array, jax.Array]:
    """Exact top-k over the document axis, streamed in chunks.

    Returns (scores (Q, k), indices (Q, k)), sorted by descending score.
    """
    n_docs = docs.shape[0]
    k = resolve_k(k, n_docs)

    out_vals, out_idx = [], []
    for qs in range(0, queries.shape[0], query_chunk):
        q = queries[qs: qs + query_chunk]
        vals = jnp.full((q.shape[0], k), -jnp.inf, jnp.float32)
        idx = jnp.zeros((q.shape[0], k), jnp.int32)
        for ds in range(0, n_docs, doc_chunk):
            d = docs[ds: ds + doc_chunk]
            cv, ci = _topk_chunk(q, d, ds, k, sim)
            if cv.shape[-1] < k:  # chunk smaller than k: pad
                pad = k - cv.shape[-1]
                cv = jnp.pad(cv, ((0, 0), (0, pad)),
                             constant_values=-jnp.inf)
                ci = jnp.pad(ci, ((0, 0), (0, pad)))
            vals, idx = merge_topk(vals, idx, cv, ci, k)
        out_vals.append(vals)
        out_idx.append(idx)
    return (jnp.concatenate(out_vals, axis=0),
            jnp.concatenate(out_idx, axis=0))
