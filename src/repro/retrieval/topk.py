"""Exact maximum-similarity search with streaming (chunked) top-k.

Scoring never materialises the full (Q, D) matrix: the document axis is
scanned in chunks, keeping a running top-k per query (two-stage top-k — the
same schedule the Pallas kernels use on TPU, here expressed in jnp for the
host/reference path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def resolve_k(k: int, n_docs: int) -> int:
    """The one ``k`` contract for every index class.

    ``k`` must be ≥ 1; a ``k`` beyond the corpus clamps to ``n_docs`` (the
    result then simply has fewer columns).  All five index classes
    (:class:`~repro.retrieval.index.DenseIndex`,
    :class:`~repro.retrieval.index.CompressedIndex`,
    :class:`~repro.retrieval.ivf.IVFIndex`, and both sharded wrappers) route
    through this guard so the clamping behaviour cannot drift.
    """
    if k < 1:
        raise ValueError(f"k must be ≥ 1, got {k}")
    return min(int(k), int(n_docs))


def resolve_nprobe(nprobe, nlist: int, default=None) -> int:
    """The one ``nprobe`` contract, mirroring :func:`resolve_k`.

    ``None`` falls back to ``default``; the result must be ≥ 1 and clamps
    to ``nlist`` (probing every list is simply exact search over the
    clustered corpus).  :class:`~repro.retrieval.ivf.IVFIndex`, the sharded
    IVF wrapper, and :class:`~repro.retrieval.segments.SegmentedIndex` all
    route through this guard so the clamping behaviour cannot drift.
    """
    if nprobe is None:
        nprobe = default
    if nprobe is None or nprobe < 1:
        raise ValueError(f"nprobe must be ≥ 1, got {nprobe}")
    return min(int(nprobe), int(nlist))


def topk_score_then_id(s: jax.Array, ids: jax.Array, k: int
                       ) -> tuple[jax.Array, jax.Array]:
    """Top-k by (score desc, doc id asc) — a strict total order.

    Exact search breaks score ties by document id implicitly (candidates
    are scanned in id order and ``lax.top_k`` keeps the first occurrence);
    IVF candidates arrive in probe order, sharded IVF candidates in shard
    order, and segmented candidates in layer order
    (:mod:`repro.retrieval.segments`), so ties must be broken *explicitly*
    on the id for all the paths to produce identical rankings.  Matters
    most for the 1-bit backend, whose integer sign-dot scores tie
    constantly.
    """
    order = jnp.lexsort((ids, -s), axis=-1)[..., :k]
    return (jnp.take_along_axis(s, order, axis=-1),
            jnp.take_along_axis(ids, order, axis=-1))


def masked_topk_by_id(s: jax.Array, ids: jax.Array, k: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Top-``k`` by (score desc, id asc), normalising unreachable slots.

    ``-inf`` scores come back with id ``-1``; when fewer than ``k``
    candidate columns exist the output is padded out to ``k`` with
    ``(-inf, -1)``.  Shared by the single-host IVF search, both halves
    (shard-local and post-gather merge) of the sharded search, and the
    cross-layer merge of :class:`~repro.retrieval.segments.SegmentedIndex`,
    so the paths cannot drift apart.
    """
    kk = min(k, s.shape[1])
    vals, out = topk_score_then_id(s, ids, kk)
    out = jnp.where(jnp.isfinite(vals), out, -1)
    if kk < k:
        pad = k - kk
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        out = jnp.pad(out, ((0, 0), (0, pad)), constant_values=-1)
    return vals, out


def merge_topk_block(run_v: jax.Array, run_i: jax.Array, cand_v: jax.Array,
                     cand_i: jax.Array, k: int
                     ) -> tuple[jax.Array, jax.Array]:
    """Merge a scored block into a (Q, k) running top-k — no sort.

    Same (score desc, id asc) strict total order as
    :func:`masked_topk_by_id`, computed as ``k`` rounds of max score →
    min doc id among the hits → retire the winner, instead of a variadic
    lexsort (XLA lowers that sort to a scalar comparator loop on CPU —
    ~1000× the cost of these k vectorised passes, and it has no TPU
    lowering at all; this formulation is what the fused Pallas kernel
    runs in VMEM).  Pad entries are (−inf, −1) throughout, matching
    ``masked_topk_by_id``'s normalisation.

    Requires distinct (score, id) pairs among *reachable* candidates
    (every −inf entry is normalised to id −1, so pads are exempt): a
    round retires every entry matching the winning pair at once.  IVF
    candidate streams satisfy this — each doc id appears in exactly one
    probed list and the running buffer holds previously-merged distinct
    ids.
    """
    cv = jnp.concatenate([run_v, cand_v], axis=1)
    ci = jnp.concatenate([run_i, cand_i], axis=1)
    kw = run_v.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, kw), 1)
    new_v = jnp.full((1, kw), float("-inf"), jnp.float32)
    new_i = jnp.full((1, kw), -1, jnp.int32)
    int_max = 2**31 - 1
    for t in range(k):
        m = jnp.max(cv, axis=1)                              # (Q,)
        hit = cv == m[:, None]
        sel = jnp.min(jnp.where(hit, ci, int_max), axis=1)   # min id among max
        new_v = jnp.where(col == t, m[:, None], new_v)
        new_i = jnp.where(col == t, sel[:, None], new_i)
        cv = jnp.where(hit & (ci == sel[:, None]), float("-inf"), cv)
    # unreachable rounds picked a (−inf, ·) entry: normalise the id to −1
    new_i = jnp.where(new_v == float("-inf"), -1, new_i)
    return new_v, new_i


def streaming_masked_topk(s: jax.Array, ids: jax.Array, k: int,
                          block: int) -> tuple[jax.Array, jax.Array]:
    """Blockwise-streamed :func:`masked_topk_by_id`.

    Scans the candidate axis in ``block``-wide slices, keeping a running
    (k,) partial top-k per query and merging each new block into it.
    Because (score desc, id asc) is a *strict total order*, the blockwise
    merge is associative and exact: the result is bit-identical to the
    monolithic ``masked_topk_by_id(s, ids, k)`` for **any** block size
    (property-tested in tests/test_ivf_fused.py).  This is the schedule the
    fused Pallas IVF kernel uses on TPU, expressed in jnp for the
    host/reference path.
    """
    n = s.shape[1]
    if block < 1:
        raise ValueError(f"block must be ≥ 1, got {block}")
    run_v, run_i = masked_topk_by_id(s[:, :block], ids[:, :block], k)
    for ds in range(block, n, block):
        cv = jnp.concatenate([run_v, s[:, ds: ds + block]], axis=1)
        ci = jnp.concatenate([run_i, ids[:, ds: ds + block]], axis=1)
        run_v, run_i = masked_topk_by_id(cv, ci, k)
    return run_v, run_i


def similarity(queries: jax.Array, docs: jax.Array, sim: str) -> jax.Array:
    """(Q, d) × (D, d) → (Q, D) similarity. sim ∈ {"ip", "l2", "cos"}.

    "l2" returns the *negative squared* L2 distance so that maximum-similarity
    search is uniform across metrics (argmax).
    """
    if sim == "ip":
        return queries @ docs.T
    if sim == "cos":
        qn = queries / (jnp.linalg.norm(queries, axis=-1, keepdims=True) + 1e-12)
        dn = docs / (jnp.linalg.norm(docs, axis=-1, keepdims=True) + 1e-12)
        return qn @ dn.T
    if sim == "l2":
        q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
        d2 = jnp.sum(docs * docs, axis=-1)
        return -(q2 + d2[None, :] - 2.0 * (queries @ docs.T))
    raise ValueError(f"unknown similarity {sim!r}")


@functools.partial(jax.jit, static_argnames=("k", "sim"))
def _topk_chunk(queries, docs, base, k, sim):
    scores = similarity(queries, docs, sim)
    kk = min(k, docs.shape[0])
    vals, idx = jax.lax.top_k(scores, kk)
    return vals, idx + base


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(vals_a, idx_a, vals_b, idx_b, k):
    """Merge two top-k candidate sets into one global top-k."""
    vals = jnp.concatenate([vals_a, vals_b], axis=-1)
    idx = jnp.concatenate([idx_a, idx_b], axis=-1)
    top_vals, pos = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(idx, pos, axis=-1)


def topk_search(queries: jax.Array, docs: jax.Array, k: int,
                sim: str = "ip", doc_chunk: int = 131072,
                query_chunk: int = 4096) -> tuple[jax.Array, jax.Array]:
    """Exact top-k over the document axis, streamed in chunks.

    Returns (scores (Q, k), indices (Q, k)), sorted by descending score.
    """
    n_docs = docs.shape[0]
    k = resolve_k(k, n_docs)

    out_vals, out_idx = [], []
    for qs in range(0, queries.shape[0], query_chunk):
        q = queries[qs: qs + query_chunk]
        vals = jnp.full((q.shape[0], k), -jnp.inf, jnp.float32)
        idx = jnp.zeros((q.shape[0], k), jnp.int32)
        for ds in range(0, n_docs, doc_chunk):
            d = docs[ds: ds + doc_chunk]
            cv, ci = _topk_chunk(q, d, ds, k, sim)
            if cv.shape[-1] < k:  # chunk smaller than k: pad
                pad = k - cv.shape[-1]
                cv = jnp.pad(cv, ((0, 0), (0, pad)),
                             constant_values=-jnp.inf)
                ci = jnp.pad(ci, ((0, 0), (0, pad)))
            vals, idx = merge_topk(vals, idx, cv, ci, k)
        out_vals.append(vals)
        out_idx.append(idx)
    return (jnp.concatenate(out_vals, axis=0),
            jnp.concatenate(out_idx, axis=0))
