"""Mini-batch Lloyd k-means (coarse quantizer for IVF; also used by
k-means-pruning ablations).  Pure JAX, jit-compiled updates."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def assign(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest centroid (L2) per row of x."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=-1)
    d2 = x2 + c2[None, :] - 2.0 * (x @ centroids.T)
    return jnp.argmin(d2, axis=-1)


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _update(x, labels, n_clusters, old):
    sums = jax.ops.segment_sum(x, labels, num_segments=n_clusters)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), labels,
                                 num_segments=n_clusters)
    new = sums / jnp.maximum(counts[:, None], 1.0)
    # keep old centroid if a cluster went empty
    return jnp.where(counts[:, None] > 0, new, old)


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _kmeanspp_init(x: jax.Array, n_clusters: int, rng) -> jax.Array:
    """kmeans++ D²-sampling init (Arthur & Vassilvitskii 2007).

    One centroid per round, sampled ∝ squared distance to the nearest
    already-chosen centroid.  Sampling is Gumbel-top-1 over log(D²) so the
    whole loop stays inside a single ``fori_loop`` (no host round trips);
    total cost O(k·n·d), the same order as one Lloyd sweep.
    """
    n, d = x.shape
    keys = jax.random.split(rng, n_clusters)
    x2 = jnp.sum(x * x, axis=-1)

    def d2_to(c):
        return jnp.maximum(x2 - 2.0 * (x @ c) + jnp.sum(c * c), 0.0)

    first = jax.random.randint(keys[0], (), 0, n)
    centroids = jnp.zeros((n_clusters, d), x.dtype).at[0].set(x[first])
    min_d2 = d2_to(x[first])

    def body(i, carry):
        centroids, min_d2 = carry
        logits = jnp.where(min_d2 > 0.0, jnp.log(min_d2 + 1e-30), -jnp.inf)
        # all-duplicate corner: every D² is 0 → sample uniformly instead
        logits = jnp.where(jnp.any(min_d2 > 0.0), logits, 0.0)
        idx = jnp.argmax(logits + jax.random.gumbel(keys[i], (n,)))
        centroids = centroids.at[i].set(x[idx])
        return centroids, jnp.minimum(min_d2, d2_to(x[idx]))

    centroids, _ = jax.lax.fori_loop(1, n_clusters, body, (centroids, min_d2))
    return centroids


@jax.jit
def _penalized_assign(x, centroids, penalty):
    """argmin(D² + penalty[c]) per row, plus the unpenalised margin
    (second-nearest D² − nearest D²: the natural penalty unit — a penalty
    of ~margin is what it takes to flip a point to its runner-up list)."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=-1)
    d2 = x2 + c2[None, :] - 2.0 * (x @ centroids.T)
    labels = jnp.argmin(d2 + penalty[None, :], axis=-1)
    if centroids.shape[0] >= 2:
        neg2, _ = jax.lax.top_k(-d2, 2)        # (−min1, −min2)
        margin = neg2[:, 0] - neg2[:, 1]
    else:
        margin = jnp.zeros((x.shape[0],), jnp.float32)
    return labels, margin


def assign_balanced(x: jax.Array, centroids: jax.Array, *,
                    slack: float = 1.25, rounds: int = 4,
                    chunk: int = 65536) -> jax.Array:
    """Capacity-aware nearest-centroid assignment (penalty iterations).

    Plain argmin on clustered corpora leaves heavy-tailed list sizes: the
    padded-list matrix is sized by the *longest* list and probe latency by
    the fattest probed list.  Each round re-assigns with a per-centroid
    penalty that grows for lists over ``slack × n/k`` capacity and relaxes
    for lists under it, trading a little quantization error for flatter
    lists.  The penalty unit is the mean assignment *margin* (distance gap
    to the runner-up centroid), not the absolute distance — on corpora
    with tight sub-clusters the absolute scale is orders of magnitude too
    coarse and a single step would herd whole blobs onto one list.  The
    best (lowest-peak) assignment seen across rounds is returned; round 1
    runs with zero penalty, so the result is never more skewed than plain
    argmin.  Rows are processed in ``chunk``-sized slices so the (n, k)
    distance matrix is never materialised whole.
    """
    x = jnp.asarray(x, jnp.float32)
    n, k = x.shape[0], centroids.shape[0]
    cap = max(slack * n / k, 1.0)
    penalty = jnp.zeros((k,), jnp.float32)
    scale = None
    best_labels, best_peak = None, None
    for _ in range(max(1, rounds)):
        parts, margins = [], []
        for s in range(0, n, chunk):
            lab, mg = _penalized_assign(x[s: s + chunk], centroids, penalty)
            parts.append(lab)
            margins.append(mg)
        labels = jnp.concatenate(parts)
        if scale is None:   # typical flip cost sets the penalty unit
            scale = float(jnp.mean(jnp.concatenate(margins))) + 1e-6
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), labels,
                                     num_segments=k)
        peak = float(counts.max())
        if best_peak is None or peak < best_peak:
            best_labels, best_peak = labels, peak
        if peak <= cap:
            break
        over = jnp.maximum(counts - cap, 0.0) / cap
        under = jnp.maximum(cap - counts, 0.0) / cap
        penalty = jnp.maximum(penalty + scale * (over - 0.5 * under), 0.0)
    return best_labels


def kmeans_fit(x: jax.Array, n_clusters: int, n_iters: int = 20,
               rng=None, init: str = "random") -> jax.Array:
    """Fit k-means centroids.

    ``init="random"`` (default) seeds with random distinct rows —
    bit-identical to the historical behaviour the golden-ranking suite
    pins.  ``init="++"`` uses kmeans++ D² sampling (:func:`_kmeanspp_init`)
    for materially better coarse quantizers on clustered corpora.
    """
    if init not in ("random", "++"):
        raise ValueError(f"unknown kmeans init {init!r}")
    if rng is None:
        rng = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    if init == "++" and n > n_clusters:
        centroids = _kmeanspp_init(x, n_clusters, rng)
    else:
        init_idx = jax.random.choice(rng, n, (min(n_clusters, n),),
                                     replace=False)
        centroids = x[init_idx]
        if centroids.shape[0] < n_clusters:  # tiny corpora: repeat rows
            reps = -(-n_clusters // centroids.shape[0])
            centroids = jnp.tile(centroids, (reps, 1))[:n_clusters]
    for _ in range(n_iters):
        labels = assign(x, centroids)
        centroids = _update(x, labels, n_clusters, centroids)
    return centroids
