"""Mini-batch Lloyd k-means (coarse quantizer for IVF; also used by
k-means-pruning ablations).  Pure JAX, jit-compiled updates."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def assign(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest centroid (L2) per row of x."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=-1)
    d2 = x2 + c2[None, :] - 2.0 * (x @ centroids.T)
    return jnp.argmin(d2, axis=-1)


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _update(x, labels, n_clusters, old):
    sums = jax.ops.segment_sum(x, labels, num_segments=n_clusters)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), labels,
                                 num_segments=n_clusters)
    new = sums / jnp.maximum(counts[:, None], 1.0)
    # keep old centroid if a cluster went empty
    return jnp.where(counts[:, None] > 0, new, old)


def kmeans_fit(x: jax.Array, n_clusters: int, n_iters: int = 20,
               rng=None) -> jax.Array:
    """Fit k-means centroids; kmeans++-lite init (random distinct rows)."""
    if rng is None:
        rng = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    init_idx = jax.random.choice(rng, n, (min(n_clusters, n),), replace=False)
    centroids = x[init_idx]
    if centroids.shape[0] < n_clusters:  # tiny corpora: repeat rows
        reps = -(-n_clusters // centroids.shape[0])
        centroids = jnp.tile(centroids, (reps, 1))[:n_clusters]
    for _ in range(n_iters):
        labels = assign(x, centroids)
        centroids = _update(x, labels, n_clusters, centroids)
    return centroids
