"""One Index API: declarative specs, a unified protocol, full persistence.

Three pieces turn the five index classes into a single surface:

* :class:`Index` — the protocol every index implements
  (:class:`~repro.retrieval.index.DenseIndex`,
  :class:`~repro.retrieval.index.CompressedIndex`,
  :class:`~repro.retrieval.ivf.IVFIndex`, and both sharded wrappers), with
  one strict ``(score desc, id asc)`` ranking contract and uniform
  ``k > len(index)`` clamping (:func:`repro.retrieval.topk.resolve_k`).
* :class:`IndexSpec` — a frozen, JSON-serializable description of an index
  recipe (compression method or explicit stage list, similarity, scorer
  backend, optional IVF routing, optional sharding) and
  :func:`build_index`, the one factory that composes registry → pipeline →
  scorer → IVF promotion → sharding from it.
* :func:`save_index` / :func:`load_index` — a single ``.npz`` artifact
  holding the spec, pipeline/scorer state, encoded storage (bit-packed
  words included), IVF router + list layout, and version counters, so
  ``load_index(path)`` round-trips to bit-identical rankings on every
  backend and a serve process cold-starts without touching the raw corpus.

Typical life cycle::

    spec = IndexSpec(method="pca_int8", dim=128, ivf=(200, 100))
    index = build_index(spec, docs, queries_sample)
    index.save("kb.npz")            # ship the small artifact
    ...
    index = load_index("kb.npz")    # cold start: no corpus, no re-fit
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Optional, Protocol, Sequence, Tuple, Union, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CompressionPipeline
from repro.core.registry import (build_method, build_pipeline_from_spec,
                                 pipeline_spec)
from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFFlatIndex, IVFIndex
from repro.retrieval.segments import SegmentedIndex, _Segment
from repro.retrieval.sharded import (ShardedCompressedIndex, ShardedIVFIndex)

ARTIFACT_FORMAT = "repro-index"
# version 2 adds the mutable-index layer: delta segments, tombstones, and
# the monotonic doc-id allocator (version-1 artifacts still load)
ARTIFACT_VERSION = 2

#: stage-descriptor type: ``(transform class name, constructor kwargs)``
StageSpec = Tuple[str, dict]


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Index(Protocol):
    """What every index class exposes — the one API serving grows on.

    ``search`` returns ``(scores, ids)`` of shape ``(Q, min(k, len(self)))``
    ranked by ``(score desc, id asc)``; ``k < 1`` raises.  ``save`` writes
    the full artifact (see :func:`save_index`); the matching ``load``
    classmethod (sharded classes additionally take ``mesh``) restores it to
    bit-identical rankings without the raw corpus.
    """

    spec: Optional["IndexSpec"]

    def search(self, queries: jax.Array, k: int
               ) -> tuple[jax.Array, jax.Array]: ...

    def add(self, docs: jax.Array) -> "Index": ...

    def __len__(self) -> int: ...

    @property
    def nbytes(self) -> int: ...

    def state_dict(self) -> dict: ...

    def save(self, path: str) -> None: ...


# ---------------------------------------------------------------------------
# declarative specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Mesh placement for the sharded wrappers.

    ``doc_axis`` names the mesh axis (or axes) the document storage is
    row-sharded over; ``query_axis`` optionally batch-shards queries.  The
    mesh itself is a runtime resource — pass it to :func:`build_index` /
    :func:`load_index`, not the spec.
    """

    doc_axis: Union[str, Tuple[str, ...]] = "model"
    query_axis: Optional[str] = None

    def to_dict(self) -> dict:
        axis = (list(self.doc_axis) if isinstance(self.doc_axis, tuple)
                else self.doc_axis)
        return {"doc_axis": axis, "query_axis": self.query_axis}

    @classmethod
    def from_dict(cls, d: dict) -> "ShardSpec":
        axis = d.get("doc_axis", "model")
        if isinstance(axis, list):
            axis = tuple(axis)
        return cls(doc_axis=axis, query_axis=d.get("query_axis"))


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Declarative index recipe — everything :func:`build_index` needs.

    Exactly one of ``method`` / ``stages`` selects the compression recipe:

    * ``method`` — a registry name (:data:`repro.core.registry.METHODS`,
      e.g. ``"pca_int8"``), expanded through
      :func:`repro.core.registry.build_method` with ``dim``/``pre``/``post``;
      the special name ``"dense"`` means no pipeline at all (float index).
    * ``stages`` — an explicit ordered tuple of
      ``(transform class name, constructor kwargs)`` descriptors, resolved
      through the transform registry (``dim``/``pre``/``post`` are ignored).

    ``ivf=(nlist, nprobe)`` promotes to approximate search;
    ``shard=ShardSpec(...)`` wraps the result over a device mesh;
    ``mutable=True`` wraps the result in a
    :class:`~repro.retrieval.segments.SegmentedIndex` (live adds through
    the frozen pipeline, tombstone deletes, drift-monitored compaction —
    not combinable with ``shard``: compact on one host, then shard the
    artifact).  Specs are frozen, hashable, and JSON round-trippable
    (:meth:`to_json` / :meth:`from_json`) — the artifact format embeds them.
    """

    method: Optional[str] = None
    stages: Optional[Tuple[StageSpec, ...]] = None
    dim: int = 128
    sim: str = "ip"
    backend: str = "auto"
    pre: bool = True
    post: bool = True
    ivf: Optional[Tuple[int, int]] = None
    shard: Optional[ShardSpec] = None
    kmeans_iters: int = 15
    mutable: bool = False
    ivf_residual: bool = False
    kmeans_init: str = "random"
    balanced_lists: bool = False

    def __post_init__(self):
        if (self.method is None) == (self.stages is None):
            raise ValueError("IndexSpec needs exactly one of method= "
                             "(registry name) or stages= (descriptor list)")
        if self.stages is not None:
            # normalise to hashable nested tuples (accepts dict configs from
            # users/JSON and already-frozen configs from dataclasses.replace)
            object.__setattr__(
                self, "stages",
                tuple((str(n), _freeze(c if isinstance(c, dict)
                                       else _thaw(c)))
                      for n, c in self.stages))
        if self.ivf is not None:
            nlist, nprobe = self.ivf
            if nlist < 1 or nprobe < 1:
                raise ValueError(f"ivf=(nlist, nprobe) must be ≥ 1, "
                                 f"got {self.ivf}")
            object.__setattr__(self, "ivf", (int(nlist), int(nprobe)))
        if self.mutable and self.shard is not None:
            raise ValueError("mutable=True cannot be combined with shard= "
                             "(compact on one host, then shard the "
                             "compacted artifact)")
        if self.sim not in ("ip", "l2", "cos"):
            raise ValueError(f"unknown sim {self.sim!r}")
        if self.backend not in ("auto", "jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.kmeans_init not in ("random", "++"):
            raise ValueError(f"unknown kmeans_init {self.kmeans_init!r}")
        if self.ivf_residual:
            if self.ivf is None:
                raise ValueError("ivf_residual=True needs ivf=(nlist, "
                                 "nprobe)")
            if self.shard is not None or self.mutable:
                raise ValueError("ivf_residual=True is single-host / "
                                 "immutable only (the residual re-encode "
                                 "is incompatible with shared-storage "
                                 "promotion and delta layers)")

    # -- pipeline ----------------------------------------------------------
    def build_pipeline(self) -> Optional[CompressionPipeline]:
        """Unfitted pipeline for this recipe; ``None`` for a dense index."""
        if self.stages is not None:
            return build_pipeline_from_spec(
                [(n, _thaw(c)) for n, c in self.stages])
        if self.method == "dense":
            return None
        return build_method(self.method, self.dim, pre=self.pre,
                            post=self.post)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.shard is not None:
            d["shard"] = self.shard.to_dict()
        if self.stages is not None:
            d["stages"] = [[n, _thaw(c)] for n, c in self.stages]
        if self.ivf is not None:
            d["ivf"] = list(self.ivf)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        d = dict(d)
        if d.get("shard") is not None:
            d["shard"] = ShardSpec.from_dict(d["shard"])
        if d.get("stages") is not None:
            d["stages"] = tuple((n, c) for n, c in d["stages"])
        if d.get("ivf") is not None:
            d["ivf"] = tuple(d["ivf"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "IndexSpec":
        return cls.from_dict(json.loads(s))


# dicts freeze to a tagged tuple so that thawing is unambiguous (an empty
# dict and an empty list must round-trip to themselves, not each other)
_DICT_TAG = "__frozen_dict__"


def _freeze(obj: Any):
    """dict/list → nested hashable tuples (so specs stay hashable)."""
    if isinstance(obj, dict):
        return (_DICT_TAG,
                tuple(sorted((k, _freeze(v)) for k, v in obj.items())))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


def _thaw(obj: Any):
    """Inverse of :func:`_freeze`."""
    if (isinstance(obj, tuple) and len(obj) == 2 and obj[0] == _DICT_TAG):
        return {k: _thaw(v) for k, v in obj[1]}
    if isinstance(obj, tuple):
        return [_thaw(v) for v in obj]
    return obj


# ---------------------------------------------------------------------------
# the factory
# ---------------------------------------------------------------------------


def build_index(spec: IndexSpec, docs: jax.Array,
                queries_sample: Optional[jax.Array] = None, *,
                mesh=None, rng=None) -> Index:
    """Compose registry → pipeline → scorer → IVF promotion → sharding.

    One entry point for every index kind the repo can build:

    ========================  =======================================
    spec                      result
    ========================  =======================================
    plain                     :class:`CompressedIndex` (or
                              :class:`DenseIndex` for ``method="dense"``)
    ``ivf=(nlist, nprobe)``   :class:`IVFIndex`
    ``shard=ShardSpec(...)``  :class:`ShardedCompressedIndex`
    both                      :class:`ShardedIVFIndex`
    ========================  =======================================

    ``queries_sample`` feeds the two-population statistics (center/norm,
    PCA fit-on choices); ``mesh`` is required iff ``spec.shard`` is set.
    """
    if spec.shard is not None and mesh is None:
        raise ValueError("spec.shard is set — build_index needs mesh=")
    pipeline = spec.build_pipeline()

    if spec.shard is not None:
        shard = spec.shard
        pipe = pipeline if pipeline is not None else CompressionPipeline([])
        if spec.ivf is not None:
            nlist, nprobe = spec.ivf
            idx = ShardedIVFIndex.build(
                docs, queries_sample, pipe, mesh=mesh, nlist=nlist,
                nprobe=nprobe, sim=spec.sim, backend=spec.backend,
                kmeans_iters=spec.kmeans_iters, doc_axis=shard.doc_axis,
                query_axis=shard.query_axis, rng=rng)
        else:
            idx = ShardedCompressedIndex.build(
                docs, queries_sample, pipe, mesh, sim=spec.sim,
                backend=spec.backend, doc_axis=shard.doc_axis,
                query_axis=shard.query_axis, rng=rng)
    elif spec.ivf is not None:
        nlist, nprobe = spec.ivf
        idx = IVFIndex.build(docs, queries_sample, pipeline, nlist=nlist,
                             nprobe=nprobe, sim=spec.sim,
                             backend=spec.backend,
                             kmeans_iters=spec.kmeans_iters,
                             residual=spec.ivf_residual,
                             kmeans_init=spec.kmeans_init,
                             balanced=spec.balanced_lists, rng=rng)
    elif pipeline is None:
        idx = DenseIndex(docs, sim=spec.sim)
    else:
        idx = CompressedIndex.build(docs, queries_sample, pipeline,
                                    sim=spec.sim, backend=spec.backend,
                                    rng=rng)
    idx.spec = spec
    if spec.mutable:
        idx = SegmentedIndex(idx, spec=spec)
    return idx


# ---------------------------------------------------------------------------
# persistence: one .npz artifact per index
# ---------------------------------------------------------------------------


def _pipeline_of(index) -> Optional[CompressionPipeline]:
    if isinstance(index, ShardedIVFIndex):
        return index.ivf.pipeline
    return getattr(index, "pipeline", None)


def _flatten_pipeline_sd(pipe_sd: dict, arrays: dict) -> list[bool]:
    """Stage states → ``pipeline:{i}:{key}`` arrays; returns fitted flags."""
    fitted = []
    for i, stage in enumerate(pipe_sd["stages"]):
        fitted.append(bool(stage["fitted"]))
        for k, v in stage["state"].items():
            arrays[f"pipeline:{i}:{k}"] = np.asarray(v)
    return fitted


def _gather_pipeline_sd(data, types: Sequence[str],
                        fitted: Sequence[bool]) -> dict:
    per_stage: list[dict] = [{} for _ in types]
    for key in data.files:
        if not key.startswith("pipeline:"):
            continue
        _, i_str, k = key.split(":", 2)
        per_stage[int(i_str)][k] = data[key]
    return {"types": list(types),
            "stages": [{"name": t, "state": st, "fitted": bool(f)}
                       for t, st, f in zip(types, per_stage, fitted)]}


def save_index(index, path: str) -> None:
    """Write the full index artifact (spec + state) to one ``.npz``.

    The artifact is self-contained: :func:`load_index` reconstructs a
    bit-identically-ranking index from it with no access to the raw corpus
    and no re-fit — encoded storage, scorer codebooks, IVF centroids and
    list layout, and the version counter are all inside.  A
    :class:`~repro.retrieval.segments.SegmentedIndex` additionally
    persists its delta segments, tombstone set, and monotonic doc-id
    allocator (format version 2); immutable indexes keep writing
    version-1 artifacts that older builds can still read.
    """
    arrays: dict[str, np.ndarray] = {}
    meta: dict[str, Any] = {
        "format": ARTIFACT_FORMAT, "format_version": 1,
        "spec": index.spec.to_dict() if index.spec is not None else None,
    }
    if isinstance(index, SegmentedIndex):
        _collect_index(index.main, arrays, meta)
        meta["main_kind"] = meta["kind"]
        meta["kind"] = "SegmentedIndex"
        meta["format_version"] = ARTIFACT_VERSION
        sd = index.state_dict()
        arrays["main_gids"] = np.asarray(sd["main_gids"], np.int32)
        arrays["tombstones"] = np.asarray(sd["tombstones"], np.int64)
        for i, seg in enumerate(sd["segments"]):
            arrays[f"seg:{i}:storage"] = np.asarray(seg["storage"])
            arrays[f"seg:{i}:gids"] = np.asarray(seg["gids"], np.int32)
            if seg["labels"] is not None:
                arrays[f"seg:{i}:labels"] = np.asarray(seg["labels"],
                                                       np.int32)
        drift = sd["drift"]
        if drift["sum"] is not None:
            arrays["drift:sum"] = np.asarray(drift["sum"])
        meta["segmented"] = {
            "next_gid": int(sd["next_gid"]),
            "n_segments": len(sd["segments"]),
            "n_live": len(index),
            "drift": {"n_added": int(drift["n_added"]),
                      "norm_sum": float(drift["norm_sum"])},
            "drift_threshold": index.drift_threshold,
            "max_delta_fraction": index.max_delta_fraction,
        }
    else:
        _collect_index(index, arrays, meta)
    arrays["__meta__"] = np.asarray(json.dumps(meta, sort_keys=True))
    np.savez(path, **arrays)


def _collect_index(index, arrays: dict, meta: dict) -> None:
    """Fill ``arrays``/``meta`` with one core index's state (shared by
    :func:`save_index` for plain and segmented artifacts)."""
    kind = type(index).__name__
    meta["kind"] = kind

    pipeline = _pipeline_of(index)
    meta["stages"] = pipeline_spec(pipeline) if pipeline is not None else []

    sd = index.state_dict()
    if isinstance(index, DenseIndex):
        if len(index) == 0:
            raise ValueError("cannot save an empty index")
        arrays["storage"] = np.asarray(sd["docs"])
        meta["index"] = {"sim": index.sim, "n_docs": len(index)}
        meta["stage_fitted"] = []
    elif isinstance(index, (IVFIndex, ShardedIVFIndex)):
        ivf = index.ivf if isinstance(index, ShardedIVFIndex) else index
        ivf_sd = sd["ivf"] if isinstance(index, ShardedIVFIndex) else sd
        if ivf_sd["storage"] is None:
            raise ValueError("cannot save an empty index")
        meta["stage_fitted"] = _flatten_pipeline_sd(ivf_sd["pipeline"],
                                                    arrays)
        arrays["storage"] = np.asarray(ivf_sd["storage"])
        arrays["centroids"] = np.asarray(ivf_sd["centroids"])
        arrays["lists"] = np.asarray(ivf_sd["lists"])
        if ivf_sd["labels"] is not None:
            arrays["labels"] = np.asarray(ivf_sd["labels"])
        meta["index"] = {
            "sim": ivf.sim, "backend": ivf.backend,
            "n_docs": int(ivf_sd["n_docs"]), "dim": int(ivf_sd["dim"]),
            "version": int(ivf_sd["version"]),
            "scorer_extra": ivf_sd["scorer_extra"],
            "nlist": int(ivf_sd["nlist"]),
            "nlist_requested": int(ivf_sd["nlist_requested"]),
            "nprobe": int(ivf_sd["nprobe"]),
            "residual": bool(ivf_sd.get("residual", False)),
            "kmeans_init": str(ivf_sd.get("kmeans_init", "random")),
            "balanced": bool(ivf_sd.get("balanced", False)),
            "kmeans_iters": int(ivf.kmeans_iters),
        }
        if isinstance(index, ShardedIVFIndex):
            meta["index"]["doc_axis"] = list(index.doc_axes)
            meta["index"]["query_axis"] = index.query_axis
    elif isinstance(index, (CompressedIndex, ShardedCompressedIndex)):
        if sd["storage"] is None:
            raise ValueError("cannot save an empty index")
        meta["stage_fitted"] = _flatten_pipeline_sd(sd["pipeline"], arrays)
        arrays["storage"] = np.asarray(sd["storage"])
        meta["index"] = {
            "sim": index.sim, "backend": index.backend,
            "n_docs": int(sd["n_docs"]), "dim": int(sd["dim"]),
            "version": int(sd.get("version", 0)),
            "scorer_extra": sd["scorer_extra"],
        }
        if isinstance(index, ShardedCompressedIndex):
            meta["index"]["doc_axis"] = list(index.doc_axes)
            meta["index"]["query_axis"] = index.query_axis
    else:
        raise TypeError(f"don't know how to save {kind}")


def _rebuild_ivf(meta: dict, data, pipeline: CompressionPipeline,
                 backend: Optional[str], kind: str) -> IVFIndex:
    m = meta["index"]
    if kind == "IVFFlatIndex":
        ivf = IVFFlatIndex(nlist=m["nlist_requested"], nprobe=m["nprobe"],
                           sim=m["sim"], kmeans_iters=m["kmeans_iters"])
    else:
        ivf = IVFIndex(pipeline, nlist=m["nlist_requested"],
                       nprobe=m["nprobe"], sim=m["sim"],
                       backend=backend or m["backend"],
                       kmeans_iters=m["kmeans_iters"],
                       residual=bool(m.get("residual", False)),
                       kmeans_init=str(m.get("kmeans_init", "random")),
                       balanced=bool(m.get("balanced", False)))
    ivf.load_state_dict({
        "pipeline": _gather_pipeline_sd(data, [n for n, _ in meta["stages"]],
                                        meta["stage_fitted"]),
        "storage": data["storage"],
        "centroids": data["centroids"],
        "lists": data["lists"],
        "labels": data["labels"] if "labels" in data.files else None,
        "scorer_extra": m.get("scorer_extra", {}),
        "nlist": m["nlist"], "nlist_requested": m["nlist_requested"],
        "nprobe": m["nprobe"], "n_docs": m["n_docs"], "dim": m["dim"],
        "residual": bool(m.get("residual", False)),
        "kmeans_init": str(m.get("kmeans_init", "random")),
        "balanced": bool(m.get("balanced", False)),
        "version": m.get("version", 0)})
    return ivf


def load_index(path: str, *, mesh=None, backend: Optional[str] = None,
               expect: Optional[type] = None):
    """Reconstruct an index from a :func:`save_index` artifact.

    Cold-start path: no raw corpus, no re-fit, no re-encode — rankings are
    bit-identical to the index that was saved.  ``mesh`` is required for
    sharded artifacts (placement is a runtime concern, not an artifact
    one); ``backend`` optionally overrides the stored scorer backend
    (e.g. load a TPU-built artifact with ``backend="jnp"`` on a host).
    ``expect`` asserts the artifact kind (used by the per-class ``load``
    classmethods).
    """
    with np.load(path, allow_pickle=False) as data:
        return _load_index_from(data, path, mesh=mesh, backend=backend,
                                expect=expect)


def _parse_meta(data, path: str) -> dict:
    """Validate and decode the artifact's JSON header."""
    if "__meta__" not in data.files:
        raise ValueError(f"{path} is not a {ARTIFACT_FORMAT} artifact "
                         "(no __meta__ entry)")
    meta = json.loads(data["__meta__"].item())
    if meta.get("format") != ARTIFACT_FORMAT:
        raise ValueError(f"{path}: unknown artifact format "
                         f"{meta.get('format')!r}")
    if meta.get("format_version", 0) > ARTIFACT_VERSION:
        raise ValueError(
            f"{path}: artifact version {meta['format_version']} is newer "
            f"than this build ({ARTIFACT_VERSION})")
    return meta


def load_index_meta(path: str) -> dict:
    """Read an artifact's identity header without materialising any arrays.

    ``.npz`` members decompress lazily, so this touches only the JSON
    header — the serving registry (:mod:`repro.serve.router`) uses it to
    label a staged/registered version (kind, corpus size, spec) before, or
    instead of, paying the full :func:`load_index` cost.  ``fingerprint``
    hashes the canonical header: two artifacts agree iff their recipe,
    shape, and scalar state agree (storage bytes are *not* hashed).
    """
    with np.load(path, allow_pickle=False) as data:
        meta = _parse_meta(data, path)
    m = meta.get("index") or {}
    seg = meta.get("segmented")
    return {
        "format_version": meta.get("format_version"),
        "kind": meta["kind"],
        "spec": meta.get("spec"),
        "n_docs": seg["n_live"] if seg is not None else m.get("n_docs"),
        "dim": m.get("dim"),
        "index_version": m.get("version", 0),
        "mutable": seg is not None,
        "fingerprint": hashlib.sha256(
            json.dumps(meta, sort_keys=True).encode()).hexdigest()[:16],
    }


def _load_index_from(data, path: str, *, mesh, backend, expect):
    meta = _parse_meta(data, path)
    kind = meta["kind"]

    pipeline = (build_pipeline_from_spec(meta["stages"])
                if meta["stages"] else CompressionPipeline([]))

    if kind == "SegmentedIndex":
        main = _load_core(meta["main_kind"], meta, data, path, pipeline,
                          mesh=mesh, backend=backend)
        if meta.get("spec") is not None:
            main.spec = IndexSpec.from_dict(meta["spec"])
        seg_info = meta["segmented"]
        idx = SegmentedIndex(
            main,
            drift_threshold=seg_info.get("drift_threshold", 0.35),
            max_delta_fraction=seg_info.get("max_delta_fraction", 0.25))
        segments = []
        for i in range(seg_info["n_segments"]):
            lkey = f"seg:{i}:labels"
            labels = (np.asarray(data[lkey], np.int32)
                      if lkey in data.files else None)
            segments.append(_Segment(
                jnp.asarray(data[f"seg:{i}:storage"]),
                np.asarray(data[f"seg:{i}:gids"], np.int32), labels))
        next_gid = int(seg_info["next_gid"])
        tomb = np.zeros(next_gid, bool)
        tomb[np.asarray(data["tombstones"], np.int64)] = True
        drift_m = seg_info["drift"]
        idx._restore(
            main_gids=np.asarray(data["main_gids"], np.int32), tomb=tomb,
            next_gid=next_gid, segments=segments,
            drift_sd={"n_added": drift_m["n_added"],
                      "norm_sum": drift_m["norm_sum"],
                      "sum": (data["drift:sum"]
                              if "drift:sum" in data.files else None)})
    else:
        idx = _load_core(kind, meta, data, path, pipeline, mesh=mesh,
                         backend=backend)

    if meta.get("spec") is not None:
        idx.spec = IndexSpec.from_dict(meta["spec"])
    if expect is not None and not isinstance(idx, expect):
        raise TypeError(f"{path} holds a {kind}, expected "
                        f"{expect.__name__} — use api.load_index for "
                        "kind-dispatching loads")
    return idx


def _load_core(kind: str, meta: dict, data, path: str,
               pipeline: CompressionPipeline, *, mesh, backend):
    """Reconstruct one core (non-segmented) index from artifact arrays."""
    m = meta["index"]

    if kind == "DenseIndex":
        idx = DenseIndex(jnp.asarray(data["storage"]), sim=m["sim"])
    elif kind == "CompressedIndex":
        idx = CompressedIndex(pipeline, sim=m["sim"],
                              backend=backend or m["backend"])
        idx.load_state_dict({
            "pipeline": _gather_pipeline_sd(
                data, [n for n, _ in meta["stages"]], meta["stage_fitted"]),
            "storage": data["storage"],
            "scorer_extra": m.get("scorer_extra", {}),
            "n_docs": m["n_docs"], "dim": m["dim"],
            "version": m.get("version", 0)})
    elif kind in ("IVFIndex", "IVFFlatIndex"):
        idx = _rebuild_ivf(meta, data, pipeline, backend, kind)
    elif kind == "ShardedCompressedIndex":
        if mesh is None:
            raise ValueError(f"{kind} artifact needs mesh= to load")
        idx = ShardedCompressedIndex(
            pipeline, mesh, sim=m["sim"], backend=backend or m["backend"],
            doc_axis=tuple(m["doc_axis"]), query_axis=m.get("query_axis"))
        idx.load_state_dict({
            "pipeline": _gather_pipeline_sd(
                data, [n for n, _ in meta["stages"]], meta["stage_fitted"]),
            "storage": data["storage"],
            "scorer_extra": m.get("scorer_extra", {}),
            "n_docs": m["n_docs"], "dim": m["dim"]})
    elif kind == "ShardedIVFIndex":
        if mesh is None:
            raise ValueError(f"{kind} artifact needs mesh= to load")
        ivf = _rebuild_ivf(meta, data, pipeline, backend, "IVFIndex")
        idx = ShardedIVFIndex(ivf, mesh, doc_axis=tuple(m["doc_axis"]),
                              query_axis=m.get("query_axis"))
    else:
        raise ValueError(f"{path}: unknown index kind {kind!r}")
    return idx
