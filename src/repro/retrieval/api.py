"""One Index API: declarative specs, a unified protocol, full persistence.

Three pieces turn the five index classes into a single surface:

* :class:`Index` — the protocol every index implements
  (:class:`~repro.retrieval.index.DenseIndex`,
  :class:`~repro.retrieval.index.CompressedIndex`,
  :class:`~repro.retrieval.ivf.IVFIndex`, and both sharded wrappers), with
  one strict ``(score desc, id asc)`` ranking contract and uniform
  ``k > len(index)`` clamping (:func:`repro.retrieval.topk.resolve_k`).
* :class:`IndexSpec` — a frozen, JSON-serializable description of an index
  recipe (compression method or explicit stage list, similarity, scorer
  backend, optional IVF routing, optional sharding) and
  :func:`build_index`, the one factory that composes registry → pipeline →
  scorer → IVF promotion → sharding from it.
* :func:`save_index` / :func:`load_index` — a single ``.npz`` artifact
  holding the spec, pipeline/scorer state, encoded storage (bit-packed
  words included), IVF router + list layout, and version counters, so
  ``load_index(path)`` round-trips to bit-identical rankings on every
  backend and a serve process cold-starts without touching the raw corpus.

Typical life cycle::

    spec = IndexSpec(method="pca_int8", dim=128, ivf=(200, 100))
    index = build_index(spec, docs, queries_sample)
    index.save("kb.npz")            # ship the small artifact
    ...
    index = load_index("kb.npz")    # cold start: no corpus, no re-fit
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings
from typing import Any, Optional, Protocol, Sequence, Tuple, Union, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CompressionPipeline
from repro.core.registry import (build_method, build_pipeline_from_spec,
                                 pipeline_spec)
from repro.retrieval.index import CompressedIndex, DenseIndex
from repro.retrieval.ivf import IVFFlatIndex, IVFIndex, build_padded_lists
from repro.retrieval.segments import SegmentedIndex, _Segment
from repro.retrieval.sharded import (ShardedCompressedIndex, ShardedIVFIndex)
from repro.storage.format import (ArtifactError, ChunkReader, ChunkWriter,
                                  is_chunked_artifact, npz_member_nbytes)
from repro.storage.store import MmapStore

ARTIFACT_FORMAT = "repro-index"
# version 1: immutable .npz · version 2 adds the mutable-index layer
# (delta segments, tombstones, doc-id allocator) · version 3 is the
# chunked tiered layout (directory: manifest.json + per-list chunks.bin +
# aux.npz, see repro.storage.format) — older artifacts all still load
ARTIFACT_VERSION = 3
#: what a v2 (mutable .npz) artifact stamps itself as
SEGMENTED_NPZ_VERSION = 2

#: ``resident="auto"`` loads fully resident up to this encoded size, and
#: tiers (MmapStore at this budget) beyond it
AUTO_RESIDENT_BYTES = 1 << 30

#: stage-descriptor type: ``(transform class name, constructor kwargs)``
StageSpec = Tuple[str, dict]


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Index(Protocol):
    """What every index class exposes — the one API serving grows on.

    ``search`` returns ``(scores, ids)`` of shape ``(Q, min(k, len(self)))``
    ranked by ``(score desc, id asc)``; ``k < 1`` raises.  ``save`` writes
    the full artifact (see :func:`save_index`); the matching ``load``
    classmethod (sharded classes additionally take ``mesh``) restores it to
    bit-identical rankings without the raw corpus.
    """

    spec: Optional["IndexSpec"]

    def search(self, queries: jax.Array, k: int
               ) -> tuple[jax.Array, jax.Array]: ...

    def add(self, docs: jax.Array) -> "Index": ...

    def __len__(self) -> int: ...

    @property
    def nbytes(self) -> int: ...

    def state_dict(self) -> dict: ...

    def save(self, path: str) -> None: ...


# ---------------------------------------------------------------------------
# declarative specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Placement for the sharded wrappers — the *one* placement surface.

    ``doc_axis`` names the mesh axis (or axes) the document storage is
    row-sharded over; ``shards`` is how many devices that axis gets
    (``None`` = every device the replica count leaves available).
    ``replicas`` adds read-scaling replica groups: storage is replicated
    over the query axis while queries batch-shard over it, so ``replicas=2``
    halves per-device query load at unchanged capacity.  ``query_axis``
    names that axis (defaults to ``"data"`` whenever ``replicas > 1``).

    The mesh is *derived* from the spec (:meth:`build_mesh`, via
    :func:`repro.parallel.placement.mesh_from_spec`) — the old pattern of
    threading a hand-built ``mesh=`` through :func:`build_index` /
    :func:`load_index` still works but is deprecated.  Old JSON specs
    (without ``shards``/``replicas``) round-trip unchanged.
    """

    doc_axis: Union[str, Tuple[str, ...]] = "model"
    query_axis: Optional[str] = None
    shards: Optional[int] = None
    replicas: int = 1

    def __post_init__(self):
        if self.shards is not None and int(self.shards) < 1:
            raise ValueError(f"shards must be ≥ 1, got {self.shards}")
        if int(self.replicas) < 1:
            raise ValueError(f"replicas must be ≥ 1, got {self.replicas}")

    @property
    def effective_query_axis(self) -> Optional[str]:
        """The query/replica mesh axis, or ``None`` for replicated queries."""
        if self.query_axis is not None:
            return self.query_axis
        return "data" if self.replicas > 1 else None

    def build_mesh(self, devices=None):
        """The mesh this spec describes over the available devices."""
        from repro.parallel.placement import mesh_from_spec
        return mesh_from_spec(self, devices=devices)

    def to_dict(self) -> dict:
        axis = (list(self.doc_axis) if isinstance(self.doc_axis, tuple)
                else self.doc_axis)
        return {"doc_axis": axis, "query_axis": self.query_axis,
                "shards": self.shards, "replicas": self.replicas}

    @classmethod
    def from_dict(cls, d: dict) -> "ShardSpec":
        axis = d.get("doc_axis", "model")
        if isinstance(axis, list):
            axis = tuple(axis)
        return cls(doc_axis=axis, query_axis=d.get("query_axis"),
                   shards=d.get("shards"),
                   replicas=int(d.get("replicas", 1)))


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Declarative index recipe — everything :func:`build_index` needs.

    Exactly one of ``method`` / ``stages`` selects the compression recipe:

    * ``method`` — a registry name (:data:`repro.core.registry.METHODS`,
      e.g. ``"pca_int8"``), expanded through
      :func:`repro.core.registry.build_method` with ``dim``/``pre``/``post``;
      the special name ``"dense"`` means no pipeline at all (float index).
    * ``stages`` — an explicit ordered tuple of
      ``(transform class name, constructor kwargs)`` descriptors, resolved
      through the transform registry (``dim``/``pre``/``post`` are ignored).

    ``ivf=(nlist, nprobe)`` promotes to approximate search;
    ``shard=ShardSpec(...)`` wraps the result over the mesh the spec
    describes (see :meth:`ShardSpec.build_mesh`); ``mutable=True`` wraps
    the result in a :class:`~repro.retrieval.segments.SegmentedIndex`
    (live adds through the frozen pipeline, tombstone deletes,
    drift-monitored compaction).  ``mutable`` and ``shard`` compose: the
    delta layer rides on the host, the sharded main fans out per shard,
    and compaction folds + re-shards in one step.  Specs are frozen,
    hashable, and JSON round-trippable (:meth:`to_json` /
    :meth:`from_json`) — the artifact format embeds them.
    """

    method: Optional[str] = None
    stages: Optional[Tuple[StageSpec, ...]] = None
    dim: int = 128
    sim: str = "ip"
    backend: str = "auto"
    pre: bool = True
    post: bool = True
    ivf: Optional[Tuple[int, int]] = None
    shard: Optional[ShardSpec] = None
    kmeans_iters: int = 15
    mutable: bool = False
    ivf_residual: bool = False
    kmeans_init: str = "random"
    balanced_lists: bool = False

    def __post_init__(self):
        if (self.method is None) == (self.stages is None):
            raise ValueError("IndexSpec needs exactly one of method= "
                             "(registry name) or stages= (descriptor list)")
        if self.stages is not None:
            # normalise to hashable nested tuples (accepts dict configs from
            # users/JSON and already-frozen configs from dataclasses.replace)
            object.__setattr__(
                self, "stages",
                tuple((str(n), _freeze(c if isinstance(c, dict)
                                       else _thaw(c)))
                      for n, c in self.stages))
        if self.ivf is not None:
            nlist, nprobe = self.ivf
            if nlist < 1 or nprobe < 1:
                raise ValueError(f"ivf=(nlist, nprobe) must be ≥ 1, "
                                 f"got {self.ivf}")
            object.__setattr__(self, "ivf", (int(nlist), int(nprobe)))
        if self.sim not in ("ip", "l2", "cos"):
            raise ValueError(f"unknown sim {self.sim!r}")
        if self.backend not in ("auto", "jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.kmeans_init not in ("random", "++"):
            raise ValueError(f"unknown kmeans_init {self.kmeans_init!r}")
        if self.ivf_residual:
            if self.ivf is None:
                raise ValueError("ivf_residual=True needs ivf=(nlist, "
                                 "nprobe)")
            if self.shard is not None or self.mutable:
                raise ValueError("ivf_residual=True is single-host / "
                                 "immutable only (the residual re-encode "
                                 "is incompatible with shared-storage "
                                 "promotion and delta layers)")

    # -- pipeline ----------------------------------------------------------
    def build_pipeline(self) -> Optional[CompressionPipeline]:
        """Unfitted pipeline for this recipe; ``None`` for a dense index."""
        if self.stages is not None:
            return build_pipeline_from_spec(
                [(n, _thaw(c)) for n, c in self.stages])
        if self.method == "dense":
            return None
        return build_method(self.method, self.dim, pre=self.pre,
                            post=self.post)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.shard is not None:
            d["shard"] = self.shard.to_dict()
        if self.stages is not None:
            d["stages"] = [[n, _thaw(c)] for n, c in self.stages]
        if self.ivf is not None:
            d["ivf"] = list(self.ivf)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        d = dict(d)
        if d.get("shard") is not None:
            d["shard"] = ShardSpec.from_dict(d["shard"])
        if d.get("stages") is not None:
            d["stages"] = tuple((n, c) for n, c in d["stages"])
        if d.get("ivf") is not None:
            d["ivf"] = tuple(d["ivf"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "IndexSpec":
        return cls.from_dict(json.loads(s))


# dicts freeze to a tagged tuple so that thawing is unambiguous (an empty
# dict and an empty list must round-trip to themselves, not each other)
_DICT_TAG = "__frozen_dict__"


def _freeze(obj: Any):
    """dict/list → nested hashable tuples (so specs stay hashable)."""
    if isinstance(obj, dict):
        return (_DICT_TAG,
                tuple(sorted((k, _freeze(v)) for k, v in obj.items())))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


def _thaw(obj: Any):
    """Inverse of :func:`_freeze`."""
    if (isinstance(obj, tuple) and len(obj) == 2 and obj[0] == _DICT_TAG):
        return {k: _thaw(v) for k, v in obj[1]}
    if isinstance(obj, tuple):
        return [_thaw(v) for v in obj]
    return obj


# ---------------------------------------------------------------------------
# the factory
# ---------------------------------------------------------------------------


def _resolve_mesh(shard: ShardSpec, mesh, where: str):
    """Spec-derived mesh, honouring (but deprecating) an explicit one."""
    if mesh is not None:
        warnings.warn(
            f"{where}(mesh=...) is deprecated: placement now comes from "
            "the ShardSpec (shards=/replicas=) and the mesh is derived "
            "from it — the explicit mesh is still honoured for now",
            DeprecationWarning, stacklevel=3)
        return mesh
    return shard.build_mesh()


def build_index(spec: IndexSpec, docs: jax.Array,
                queries_sample: Optional[jax.Array] = None, *,
                mesh=None, rng=None) -> Index:
    """Compose registry → pipeline → scorer → IVF promotion → sharding.

    One entry point for every index kind the repo can build:

    ========================  =======================================
    spec                      result
    ========================  =======================================
    plain                     :class:`CompressedIndex` (or
                              :class:`DenseIndex` for ``method="dense"``)
    ``ivf=(nlist, nprobe)``   :class:`IVFIndex`
    ``shard=ShardSpec(...)``  :class:`ShardedCompressedIndex`
    both                      :class:`ShardedIVFIndex`
    ========================  =======================================

    ``queries_sample`` feeds the two-population statistics (center/norm,
    PCA fit-on choices).  With ``spec.shard`` set the mesh is derived from
    the spec; passing ``mesh=`` explicitly still works but is deprecated —
    the spec is the one placement surface.
    """
    pipeline = spec.build_pipeline()

    if spec.shard is not None:
        shard = spec.shard
        mesh = _resolve_mesh(shard, mesh, "build_index")
        pipe = pipeline if pipeline is not None else CompressionPipeline([])
        if spec.ivf is not None:
            nlist, nprobe = spec.ivf
            idx = ShardedIVFIndex.build(
                docs, queries_sample, pipe, mesh=mesh, nlist=nlist,
                nprobe=nprobe, sim=spec.sim, backend=spec.backend,
                kmeans_iters=spec.kmeans_iters, doc_axis=shard.doc_axis,
                query_axis=shard.effective_query_axis, rng=rng)
        else:
            idx = ShardedCompressedIndex.build(
                docs, queries_sample, pipe, mesh, sim=spec.sim,
                backend=spec.backend, doc_axis=shard.doc_axis,
                query_axis=shard.effective_query_axis, rng=rng)
    elif spec.ivf is not None:
        nlist, nprobe = spec.ivf
        idx = IVFIndex.build(docs, queries_sample, pipeline, nlist=nlist,
                             nprobe=nprobe, sim=spec.sim,
                             backend=spec.backend,
                             kmeans_iters=spec.kmeans_iters,
                             residual=spec.ivf_residual,
                             kmeans_init=spec.kmeans_init,
                             balanced=spec.balanced_lists, rng=rng)
    elif pipeline is None:
        idx = DenseIndex(docs, sim=spec.sim)
    else:
        idx = CompressedIndex.build(docs, queries_sample, pipeline,
                                    sim=spec.sim, backend=spec.backend,
                                    rng=rng)
    idx.spec = spec
    if spec.mutable:
        idx = SegmentedIndex(idx, spec=spec)
    return idx


# ---------------------------------------------------------------------------
# persistence: one .npz artifact per index
# ---------------------------------------------------------------------------


def _pipeline_of(index) -> Optional[CompressionPipeline]:
    if isinstance(index, ShardedIVFIndex):
        return index.ivf.pipeline
    return getattr(index, "pipeline", None)


def _flatten_pipeline_sd(pipe_sd: dict, arrays: dict) -> list[bool]:
    """Stage states → ``pipeline:{i}:{key}`` arrays; returns fitted flags."""
    fitted = []
    for i, stage in enumerate(pipe_sd["stages"]):
        fitted.append(bool(stage["fitted"]))
        for k, v in stage["state"].items():
            arrays[f"pipeline:{i}:{k}"] = np.asarray(v)
    return fitted


def _gather_pipeline_sd(data, types: Sequence[str],
                        fitted: Sequence[bool]) -> dict:
    per_stage: list[dict] = [{} for _ in types]
    for key in data.files:
        if not key.startswith("pipeline:"):
            continue
        _, i_str, k = key.split(":", 2)
        per_stage[int(i_str)][k] = data[key]
    return {"types": list(types),
            "stages": [{"name": t, "state": st, "fitted": bool(f)}
                       for t, st, f in zip(types, per_stage, fitted)]}


def save_index(index, path: str, *, chunked: bool = False) -> None:
    """Write the full index artifact (spec + state).

    The artifact is self-contained: :func:`load_index` reconstructs a
    bit-identically-ranking index from it with no access to the raw corpus
    and no re-fit — encoded storage, scorer codebooks, IVF centroids and
    list layout, and the version counter are all inside.  A
    :class:`~repro.retrieval.segments.SegmentedIndex` additionally
    persists its delta segments, tombstone set, and monotonic doc-id
    allocator (format version 2); immutable indexes keep writing
    version-1 artifacts that older builds can still read.

    ``chunked=True`` writes the v3 *tiered* layout instead of one
    ``.npz``: a directory with per-inverted-list chunks streamed to disk
    list-by-list (peak save RSS stays O(largest list)) that
    :func:`load_index` can serve with a byte-budgeted hot tier
    (``resident=``).  IVF indexes only (plain or under a
    ``SegmentedIndex``); a store-backed (tiered) index *must* be saved
    chunked — it has no resident storage to pack into an ``.npz``.
    """
    main = index.main if isinstance(index, SegmentedIndex) else index
    if chunked:
        return _save_index_chunked(index, path)
    if getattr(main, "store", None) is not None:
        raise ValueError(
            "store-backed (tiered) index cannot be packed into a .npz — "
            "save_index(..., chunked=True) streams it to a v3 artifact, "
            "or reload with resident='all' first")
    arrays: dict[str, np.ndarray] = {}
    meta: dict[str, Any] = {
        "format": ARTIFACT_FORMAT, "format_version": 1,
        "spec": index.spec.to_dict() if index.spec is not None else None,
    }
    if isinstance(index, SegmentedIndex):
        _collect_index(index.main, arrays, meta)
        meta["main_kind"] = meta["kind"]
        meta["kind"] = "SegmentedIndex"
        meta["format_version"] = SEGMENTED_NPZ_VERSION
        sd = index.state_dict()
        arrays["main_gids"] = np.asarray(sd["main_gids"], np.int32)
        arrays["tombstones"] = np.asarray(sd["tombstones"], np.int64)
        for i, seg in enumerate(sd["segments"]):
            arrays[f"seg:{i}:storage"] = np.asarray(seg["storage"])
            arrays[f"seg:{i}:gids"] = np.asarray(seg["gids"], np.int32)
            if seg["labels"] is not None:
                arrays[f"seg:{i}:labels"] = np.asarray(seg["labels"],
                                                       np.int32)
        drift = sd["drift"]
        if drift["sum"] is not None:
            arrays["drift:sum"] = np.asarray(drift["sum"])
        meta["segmented"] = {
            "next_gid": int(sd["next_gid"]),
            "n_segments": len(sd["segments"]),
            "n_live": len(index),
            "drift": {"n_added": int(drift["n_added"]),
                      "norm_sum": float(drift["norm_sum"])},
            "drift_threshold": index.drift_threshold,
            "max_delta_fraction": index.max_delta_fraction,
        }
    else:
        _collect_index(index, arrays, meta)
    arrays["__meta__"] = np.asarray(json.dumps(meta, sort_keys=True))
    np.savez(path, **arrays)


def _chunked_header(ivf: IVFIndex, seg: Optional[SegmentedIndex],
                    spec) -> tuple[dict, dict]:
    """(meta, aux arrays) for a v3 artifact — same header fields as the v2
    ``.npz`` writes, so load-side reconstruction is shared."""
    aux: dict[str, np.ndarray] = {}
    meta: dict[str, Any] = {
        "format": ARTIFACT_FORMAT, "format_version": ARTIFACT_VERSION,
        "spec": spec.to_dict() if spec is not None else None,
        "kind": type(ivf).__name__,
    }
    pipeline = _pipeline_of(ivf)
    meta["stages"] = pipeline_spec(pipeline) if pipeline is not None else []
    meta["stage_fitted"] = _flatten_pipeline_sd(ivf.pipeline.state_dict(),
                                                aux)
    aux["centroids"] = np.asarray(ivf.centroids)
    meta["index"] = {
        "sim": ivf.sim, "backend": ivf.backend,
        "n_docs": int(ivf._n_docs), "dim": int(ivf._dim),
        "version": int(ivf._version),
        "scorer_extra": ivf.scorer.extra_state(),
        "nlist": int(ivf.nlist),
        "nlist_requested": int(ivf._nlist_requested),
        "nprobe": int(ivf.nprobe),
        "residual": bool(ivf.residual),
        "kmeans_init": str(ivf.kmeans_init),
        "balanced": bool(ivf.balanced),
        "kmeans_iters": int(ivf.kmeans_iters),
    }
    if seg is not None:
        meta["main_kind"] = meta["kind"]
        meta["kind"] = "SegmentedIndex"
        st = seg._state
        aux["main_gids"] = np.asarray(seg._main_gids, np.int32)
        aux["tombstones"] = np.flatnonzero(st.tomb).astype(np.int64)
        for i, s in enumerate(st.segments):
            aux[f"seg:{i}:storage"] = np.asarray(s.storage)
            aux[f"seg:{i}:gids"] = np.asarray(s.gids, np.int32)
            if s.labels is not None:
                aux[f"seg:{i}:labels"] = np.asarray(s.labels, np.int32)
        drift_sd = seg.drift.state_dict()
        if drift_sd["sum"] is not None:
            aux["drift:sum"] = np.asarray(drift_sd["sum"])
        meta["segmented"] = {
            "next_gid": int(st.next_gid),
            "n_segments": len(st.segments),
            "n_live": len(seg),
            "drift": {"n_added": int(drift_sd["n_added"]),
                      "norm_sum": float(drift_sd["norm_sum"])},
            "drift_threshold": seg.drift_threshold,
            "max_delta_fraction": seg.max_delta_fraction,
        }
    return meta, aux


def _write_chunked(path: str, meta: dict, aux: dict, rows_iter, *,
                   storage_dtype, storage_width: int, n_lists: int) -> dict:
    """Stream ``(rows, ids)`` per list into a v3 artifact directory."""
    writer = ChunkWriter(path, storage_dtype=storage_dtype,
                         storage_width=storage_width)
    n = 0
    for rows, ids in rows_iter:
        writer.write_list(rows, ids)
        n += 1
    if n != n_lists:
        raise ValueError(f"chunk stream yielded {n} lists, expected "
                         f"{n_lists}")
    return writer.finish(meta, aux)


def _save_index_chunked(index, path: str) -> None:
    seg = index if isinstance(index, SegmentedIndex) else None
    ivf = seg.main if seg is not None else index
    if not isinstance(ivf, IVFIndex):
        raise TypeError(
            "chunked (v3) artifacts lay out per-IVF-list storage — "
            f"{type(index).__name__} has no inverted lists; save it "
            "without chunked=True")
    if ivf.centroids is None or (ivf.storage is None and ivf.store is None):
        raise ValueError("cannot save an empty index")
    meta, aux = _chunked_header(ivf, seg, index.spec)
    if ivf.store is not None:
        rows_iter = ((rows, ids) for _, rows, ids in ivf.store.iter_lists())
        dtype, width = ivf.store.storage_dtype, ivf.store.storage_width
    else:
        lists_np = np.asarray(ivf.lists)
        storage_np = np.asarray(ivf.storage)
        dtype, width = storage_np.dtype, int(storage_np.shape[1])

        def _iter_resident():
            for lid in range(ivf.nlist):
                members = lists_np[lid]
                members = members[members >= 0]
                yield storage_np[members], members

        rows_iter = _iter_resident()
    _write_chunked(path, meta, aux, rows_iter, storage_dtype=dtype,
                   storage_width=width, n_lists=ivf.nlist)


def _collect_index(index, arrays: dict, meta: dict) -> None:
    """Fill ``arrays``/``meta`` with one core index's state (shared by
    :func:`save_index` for plain and segmented artifacts)."""
    kind = type(index).__name__
    meta["kind"] = kind

    pipeline = _pipeline_of(index)
    meta["stages"] = pipeline_spec(pipeline) if pipeline is not None else []

    sd = index.state_dict()
    if isinstance(index, DenseIndex):
        if len(index) == 0:
            raise ValueError("cannot save an empty index")
        arrays["storage"] = np.asarray(sd["docs"])
        meta["index"] = {"sim": index.sim, "n_docs": len(index)}
        meta["stage_fitted"] = []
    elif isinstance(index, (IVFIndex, ShardedIVFIndex)):
        ivf = index.ivf if isinstance(index, ShardedIVFIndex) else index
        ivf_sd = sd["ivf"] if isinstance(index, ShardedIVFIndex) else sd
        if ivf_sd["storage"] is None:
            raise ValueError("cannot save an empty index")
        meta["stage_fitted"] = _flatten_pipeline_sd(ivf_sd["pipeline"],
                                                    arrays)
        arrays["storage"] = np.asarray(ivf_sd["storage"])
        arrays["centroids"] = np.asarray(ivf_sd["centroids"])
        arrays["lists"] = np.asarray(ivf_sd["lists"])
        if ivf_sd["labels"] is not None:
            arrays["labels"] = np.asarray(ivf_sd["labels"])
        meta["index"] = {
            "sim": ivf.sim, "backend": ivf.backend,
            "n_docs": int(ivf_sd["n_docs"]), "dim": int(ivf_sd["dim"]),
            "version": int(ivf_sd["version"]),
            "scorer_extra": ivf_sd["scorer_extra"],
            "nlist": int(ivf_sd["nlist"]),
            "nlist_requested": int(ivf_sd["nlist_requested"]),
            "nprobe": int(ivf_sd["nprobe"]),
            "residual": bool(ivf_sd.get("residual", False)),
            "kmeans_init": str(ivf_sd.get("kmeans_init", "random")),
            "balanced": bool(ivf_sd.get("balanced", False)),
            "kmeans_iters": int(ivf.kmeans_iters),
        }
        if isinstance(index, ShardedIVFIndex):
            meta["index"]["doc_axis"] = list(index.doc_axes)
            meta["index"]["query_axis"] = index.query_axis
    elif isinstance(index, (CompressedIndex, ShardedCompressedIndex)):
        if sd["storage"] is None:
            raise ValueError("cannot save an empty index")
        meta["stage_fitted"] = _flatten_pipeline_sd(sd["pipeline"], arrays)
        arrays["storage"] = np.asarray(sd["storage"])
        meta["index"] = {
            "sim": index.sim, "backend": index.backend,
            "n_docs": int(sd["n_docs"]), "dim": int(sd["dim"]),
            "version": int(sd.get("version", 0)),
            "scorer_extra": sd["scorer_extra"],
        }
        if isinstance(index, ShardedCompressedIndex):
            meta["index"]["doc_axis"] = list(index.doc_axes)
            meta["index"]["query_axis"] = index.query_axis
    else:
        raise TypeError(f"don't know how to save {kind}")


def _make_ivf(meta: dict, pipeline: CompressionPipeline,
              backend: Optional[str], kind: str) -> IVFIndex:
    """Construct the (unloaded) IVF shell an artifact header describes."""
    m = meta["index"]
    if kind == "IVFFlatIndex":
        return IVFFlatIndex(nlist=m["nlist_requested"], nprobe=m["nprobe"],
                            sim=m["sim"], kmeans_iters=m["kmeans_iters"])
    return IVFIndex(pipeline, nlist=m["nlist_requested"],
                    nprobe=m["nprobe"], sim=m["sim"],
                    backend=backend or m["backend"],
                    kmeans_iters=m["kmeans_iters"],
                    residual=bool(m.get("residual", False)),
                    kmeans_init=str(m.get("kmeans_init", "random")),
                    balanced=bool(m.get("balanced", False)))


def _ivf_sd_common(meta: dict, data) -> dict:
    """The storage-independent slice of an IVF ``load_state_dict`` dict
    (shared between the ``.npz`` and chunked load paths — ``data`` only
    needs ``.files`` and ``__getitem__``, so an ``aux.npz`` handle works)."""
    m = meta["index"]
    return {
        "pipeline": _gather_pipeline_sd(data, [n for n, _ in meta["stages"]],
                                        meta["stage_fitted"]),
        "centroids": data["centroids"],
        "scorer_extra": m.get("scorer_extra", {}),
        "nlist": m["nlist"], "nlist_requested": m["nlist_requested"],
        "nprobe": m["nprobe"], "n_docs": m["n_docs"], "dim": m["dim"],
        "residual": bool(m.get("residual", False)),
        "kmeans_init": str(m.get("kmeans_init", "random")),
        "balanced": bool(m.get("balanced", False)),
        "version": m.get("version", 0)}


def _rebuild_ivf(meta: dict, data, pipeline: CompressionPipeline,
                 backend: Optional[str], kind: str) -> IVFIndex:
    ivf = _make_ivf(meta, pipeline, backend, kind)
    ivf.load_state_dict({
        **_ivf_sd_common(meta, data),
        "storage": data["storage"],
        "lists": data["lists"],
        "labels": data["labels"] if "labels" in data.files else None})
    return ivf


def load_index(path: str, *, mesh=None, backend: Optional[str] = None,
               expect: Optional[type] = None,
               resident: Union[str, int] = "auto",
               shard: Optional[ShardSpec] = None):
    """Reconstruct an index from a :func:`save_index` artifact.

    Cold-start path: no raw corpus, no re-fit, no re-encode — rankings are
    bit-identical to the index that was saved.  Sharded artifacts derive
    their mesh from the embedded spec (``mesh=`` is still honoured but
    deprecated — placement is a :class:`ShardSpec` concern now);
    ``shard=ShardSpec(...)`` loads a *single-host* artifact (``.npz`` or
    chunked v3) and wraps it over the mesh the spec describes, so one
    artifact serves both single-host and sharded deployments.
    ``backend`` optionally overrides the stored scorer backend (e.g. load
    a TPU-built artifact with ``backend="jnp"`` on a host).  ``expect``
    asserts the artifact kind (used by the per-class ``load``
    classmethods).

    ``resident`` governs residency for chunked (v3) artifacts:

    * ``"all"`` — materialise every inverted list (today's behaviour:
      the result is bit-identical to loading the equivalent ``.npz``,
      fused-kernel capable, and owns no store).
    * an ``int`` — byte budget for an :class:`~repro.storage.store.
      MmapStore` hot tier; the encoded lists stay on disk behind an
      ``np.memmap`` and searches stream through the store
      (bit-identical results at any budget).
    * ``"auto"`` (default) — ``"all"`` when the encoded storage fits
      ``AUTO_RESIDENT_BYTES``, else a tier at that budget.

    ``.npz`` (v1/v2) artifacts load exactly as before; ``resident`` is
    ignored for them, and forced to ``"all"`` under ``shard=`` (per-shard
    storage must be materialised to be placed).
    """
    if mesh is not None:
        warnings.warn(
            "load_index(mesh=...) is deprecated: sharded artifacts derive "
            "their mesh from the embedded ShardSpec, and single-host "
            "artifacts shard with shard=ShardSpec(...) — the explicit "
            "mesh is still honoured for now", DeprecationWarning,
            stacklevel=2)
    if is_chunked_artifact(path):
        if shard is None and mesh is None:
            return _load_index_chunked(path, backend=backend,
                                       expect=expect, resident=resident)
        # sharding needs resident per-shard rows — materialise, then wrap
        idx = _load_index_chunked(path, backend=backend, expect=None,
                                  resident="all")
        if shard is None:
            shard = ShardSpec(doc_axis=mesh.axis_names[-1])
        idx = _shard_loaded(idx, shard, mesh)
        if expect is not None and not isinstance(idx, expect):
            raise TypeError(f"{path} loaded as {type(idx).__name__}, "
                            f"expected {expect.__name__}")
        return idx
    with np.load(path, allow_pickle=False) as data:
        return _load_index_from(data, path, mesh=mesh, backend=backend,
                                expect=expect, shard=shard)


def _resolve_resident(resident: Union[str, int],
                      encoded_nbytes: int) -> Optional[int]:
    """``None`` = load fully resident; an int = MmapStore byte budget."""
    if isinstance(resident, str):
        if resident == "all":
            return None
        if resident == "auto":
            return (None if encoded_nbytes <= AUTO_RESIDENT_BYTES
                    else AUTO_RESIDENT_BYTES)
        raise ValueError(f"resident must be 'auto', 'all', or a byte "
                         f"budget, got {resident!r}")
    if isinstance(resident, bool) or int(resident) < 0:
        raise ValueError(f"resident byte budget must be ≥ 0, "
                         f"got {resident!r}")
    return int(resident)


def _validate_header(meta: dict, path: str) -> None:
    if meta.get("format") != ARTIFACT_FORMAT:
        raise ValueError(f"{path}: unknown artifact format "
                         f"{meta.get('format')!r}")
    if meta.get("format_version", 0) > ARTIFACT_VERSION:
        raise ValueError(
            f"{path}: artifact version {meta['format_version']} is newer "
            f"than this build ({ARTIFACT_VERSION})")


def _parse_meta(data, path: str) -> dict:
    """Validate and decode the artifact's JSON header."""
    if "__meta__" not in data.files:
        raise ValueError(f"{path} is not a {ARTIFACT_FORMAT} artifact "
                         "(no __meta__ entry)")
    meta = json.loads(data["__meta__"].item())
    _validate_header(meta, path)
    return meta


def _is_seg_storage(name: str) -> bool:
    return name.startswith("seg:") and name.endswith(":storage")


def load_index_meta(path: str) -> dict:
    """Read an artifact's identity header without materialising any arrays.

    ``.npz`` members decompress lazily, so this touches only the JSON
    header plus per-member ``.npy`` headers — the serving registry
    (:mod:`repro.serve.router`) uses it to label a staged/registered
    version (kind, corpus size, spec) before, or instead of, paying the
    full :func:`load_index` cost.  ``fingerprint`` hashes the canonical
    header: two artifacts agree iff their recipe, shape, and scalar state
    agree (storage bytes are *not* hashed).

    Size accounting (matches the on-disk members exactly, see
    ``tests/test_storage.py``): ``encoded_nbytes`` is the encoded document
    storage — the main layer plus any delta segments; ``aux_nbytes`` is
    everything else an index must hold resident (router centroids, list
    ids, pipeline state, allocator arrays).  ``artifact_version`` is the
    on-disk format version (1/2 = ``.npz``, 3 = chunked directory).
    """
    if is_chunked_artifact(path):
        reader = ChunkReader(path)       # manifest only — map stays closed
        meta = reader.meta
        _validate_header(meta, path)
        aux_sizes = npz_member_nbytes(os.path.join(path, "aux.npz"))
        seg_storage = sum(v for k, v in aux_sizes.items()
                          if _is_seg_storage(k))
        encoded = reader.encoded_nbytes + seg_storage
        aux = (sum(aux_sizes.values()) - seg_storage
               + int(reader.manifest["ids_nbytes"]))
    else:
        with np.load(path, allow_pickle=False) as data:
            meta = _parse_meta(data, path)
        sizes = npz_member_nbytes(path)
        encoded = sizes.get("storage", 0) + sum(
            v for k, v in sizes.items() if _is_seg_storage(k))
        aux = sum(v for k, v in sizes.items()
                  if k != "__meta__") - encoded
    m = meta.get("index") or {}
    seg = meta.get("segmented")
    return {
        "format_version": meta.get("format_version"),
        "artifact_version": meta.get("format_version"),
        "kind": meta["kind"],
        "spec": meta.get("spec"),
        "n_docs": seg["n_live"] if seg is not None else m.get("n_docs"),
        "dim": m.get("dim"),
        "index_version": m.get("version", 0),
        "mutable": seg is not None,
        "encoded_nbytes": int(encoded),
        "aux_nbytes": int(aux),
        "fingerprint": hashlib.sha256(
            json.dumps(meta, sort_keys=True).encode()).hexdigest()[:16],
    }


def _load_index_from(data, path: str, *, mesh, backend, expect,
                     shard: Optional[ShardSpec] = None):
    meta = _parse_meta(data, path)
    kind = meta["kind"]

    pipeline = (build_pipeline_from_spec(meta["stages"])
                if meta["stages"] else CompressionPipeline([]))

    if kind == "SegmentedIndex":
        main = _load_core(meta["main_kind"], meta, data, path, pipeline,
                          mesh=mesh, backend=backend, shard=shard)
        if meta.get("spec") is not None:
            main.spec = IndexSpec.from_dict(meta["spec"])
        idx = _wrap_segmented(main, meta, data)
    else:
        idx = _load_core(kind, meta, data, path, pipeline, mesh=mesh,
                         backend=backend, shard=shard)

    if meta.get("spec") is not None:
        idx.spec = IndexSpec.from_dict(meta["spec"])
    if shard is not None and not _is_sharded(idx):
        idx = _shard_loaded(idx, shard, mesh)
    if expect is not None and not isinstance(idx, expect):
        raise TypeError(f"{path} holds a {kind}, expected "
                        f"{expect.__name__} — use api.load_index for "
                        "kind-dispatching loads")
    return idx


def _wrap_segmented(main, meta: dict, data) -> SegmentedIndex:
    """Restore the mutable layer (segments/tombstones/allocator/drift)
    around a loaded main — ``data`` is the v2 ``.npz`` handle or a v3
    ``aux.npz`` handle (same member names)."""
    seg_info = meta["segmented"]
    idx = SegmentedIndex(
        main,
        drift_threshold=seg_info.get("drift_threshold", 0.35),
        max_delta_fraction=seg_info.get("max_delta_fraction", 0.25))
    segments = []
    for i in range(seg_info["n_segments"]):
        lkey = f"seg:{i}:labels"
        labels = (np.asarray(data[lkey], np.int32)
                  if lkey in data.files else None)
        segments.append(_Segment(
            jnp.asarray(data[f"seg:{i}:storage"]),
            np.asarray(data[f"seg:{i}:gids"], np.int32), labels))
    next_gid = int(seg_info["next_gid"])
    tomb = np.zeros(next_gid, bool)
    tomb[np.asarray(data["tombstones"], np.int64)] = True
    drift_m = seg_info["drift"]
    idx._restore(
        main_gids=np.asarray(data["main_gids"], np.int32), tomb=tomb,
        next_gid=next_gid, segments=segments,
        drift_sd={"n_added": drift_m["n_added"],
                  "norm_sum": drift_m["norm_sum"],
                  "sum": (data["drift:sum"]
                          if "drift:sum" in data.files else None)})
    return idx


def _load_index_chunked(path: str, *, backend, expect,
                        resident: Union[str, int]):
    """Load a v3 chunked artifact at the requested residency."""
    reader = ChunkReader(path)
    meta = reader.meta
    _validate_header(meta, path)
    kind = meta["kind"]
    main_kind = meta.get("main_kind", kind)
    if main_kind not in ("IVFIndex", "IVFFlatIndex"):
        raise ValueError(f"{path}: chunked artifact holds unsupported "
                         f"kind {main_kind!r}")
    pipeline = (build_pipeline_from_spec(meta["stages"])
                if meta["stages"] else CompressionPipeline([]))
    m = meta["index"]
    budget = _resolve_resident(resident, reader.encoded_nbytes)
    ivf = _make_ivf(meta, pipeline, backend, main_kind)
    with reader.load_aux() as aux:
        sd = _ivf_sd_common(meta, aux)
        if budget is None:
            # fully resident: scatter chunks back into row-major storage —
            # bit-identical to the v2 load (lists rebuilt from the same
            # labels), fused-kernel capable, no store attached
            storage = np.empty((m["n_docs"], reader.storage_width),
                               reader.storage_dtype)
            labels = np.empty(m["n_docs"], np.int32)
            filled = 0
            for lid, rows, ids in reader.iter_lists():
                storage[ids] = rows
                labels[ids] = lid
                filled += int(ids.shape[0])
            if filled != m["n_docs"]:
                raise ArtifactError(
                    f"{path}: chunks hold {filled} rows, header says "
                    f"{m['n_docs']}")
            reader.close()
            ivf.load_state_dict({
                **sd, "storage": storage,
                "lists": build_padded_lists(labels, int(m["nlist"])),
                "labels": labels})
        else:
            ivf.load_state_dict({**sd, "storage": None, "lists": None,
                                 "labels": None})
            ivf.store = MmapStore(reader, budget)
            ivf._store_fns = None
        if meta.get("spec") is not None:
            ivf.spec = IndexSpec.from_dict(meta["spec"])
        if kind == "SegmentedIndex":
            idx = _wrap_segmented(ivf, meta, aux)
            if ivf.store is not None:
                # delta rows route to these lists on every probe that can
                # reach them — keep the write-hot head unevictable
                for s in idx._state.segments:
                    if s.labels is not None:
                        ivf.store.pin(np.unique(s.labels).tolist())
        else:
            idx = ivf
    if meta.get("spec") is not None:
        idx.spec = IndexSpec.from_dict(meta["spec"])
    if expect is not None and not isinstance(idx, expect):
        raise TypeError(f"{path} holds a {kind}, expected "
                        f"{expect.__name__} — use api.load_index for "
                        "kind-dispatching loads")
    return idx


def _load_core(kind: str, meta: dict, data, path: str,
               pipeline: CompressionPipeline, *, mesh, backend,
               shard: Optional[ShardSpec] = None):
    """Reconstruct one core (non-segmented) index from artifact arrays."""
    m = meta["index"]

    if kind == "DenseIndex":
        idx = DenseIndex(jnp.asarray(data["storage"]), sim=m["sim"])
    elif kind == "CompressedIndex":
        idx = CompressedIndex(pipeline, sim=m["sim"],
                              backend=backend or m["backend"])
        idx.load_state_dict({
            "pipeline": _gather_pipeline_sd(
                data, [n for n, _ in meta["stages"]], meta["stage_fitted"]),
            "storage": data["storage"],
            "scorer_extra": m.get("scorer_extra", {}),
            "n_docs": m["n_docs"], "dim": m["dim"],
            "version": m.get("version", 0)})
    elif kind in ("IVFIndex", "IVFFlatIndex"):
        idx = _rebuild_ivf(meta, data, pipeline, backend, kind)
    elif kind == "ShardedCompressedIndex":
        sh = _artifact_shard(meta, shard)
        if mesh is None:
            mesh = sh.build_mesh()
        idx = ShardedCompressedIndex(
            pipeline, mesh, sim=m["sim"], backend=backend or m["backend"],
            doc_axis=sh.doc_axis, query_axis=sh.effective_query_axis)
        idx.load_state_dict({
            "pipeline": _gather_pipeline_sd(
                data, [n for n, _ in meta["stages"]], meta["stage_fitted"]),
            "storage": data["storage"],
            "scorer_extra": m.get("scorer_extra", {}),
            "n_docs": m["n_docs"], "dim": m["dim"]})
    elif kind == "ShardedIVFIndex":
        sh = _artifact_shard(meta, shard)
        if mesh is None:
            mesh = sh.build_mesh()
        ivf = _rebuild_ivf(meta, data, pipeline, backend, "IVFIndex")
        idx = ShardedIVFIndex(ivf, mesh, doc_axis=sh.doc_axis,
                              query_axis=sh.effective_query_axis)
    else:
        raise ValueError(f"{path}: unknown index kind {kind!r}")
    return idx


# ---------------------------------------------------------------------------
# sharding a loaded single-host index
# ---------------------------------------------------------------------------


def _is_sharded(idx) -> bool:
    if isinstance(idx, (ShardedCompressedIndex, ShardedIVFIndex)):
        return True
    return isinstance(idx, SegmentedIndex) and isinstance(
        idx.main, (ShardedCompressedIndex, ShardedIVFIndex))


def _spec_with_shard(spec: Optional[IndexSpec],
                     shard: ShardSpec) -> Optional[IndexSpec]:
    if spec is None:
        return None
    return dataclasses.replace(spec, shard=shard)


def _derived_shard(m: dict) -> ShardSpec:
    """ShardSpec equivalent to what a pre-spec sharded artifact stored."""
    axis = m.get("doc_axis", "model")
    if isinstance(axis, list):
        axis = tuple(axis)
    if isinstance(axis, tuple) and len(axis) == 1:
        axis = axis[0]
    return ShardSpec(doc_axis=axis, query_axis=m.get("query_axis"))


def _artifact_shard(meta: dict, shard: Optional[ShardSpec]) -> ShardSpec:
    """The placement a sharded artifact should load with: an explicit
    ``shard=`` wins, then the spec embedded in the artifact, then a spec
    derived from the stored axis names (old artifacts)."""
    if shard is not None:
        return shard
    sp = meta.get("spec") or {}
    if sp.get("shard"):
        return ShardSpec.from_dict(sp["shard"])
    return _derived_shard(meta["index"])


def _shard_loaded(idx, shard: ShardSpec, mesh=None):
    """Wrap a loaded single-host index over the mesh ``shard`` describes.

    This is the one seam that lets a single-host artifact (``.npz`` or
    chunked v3, mutable or not) serve sharded: the main fans out over the
    doc axis, a SegmentedIndex's delta layer stays host-side (deltas are
    small by the compaction contract), and rankings stay bit-identical to
    the single-host index.
    """
    if mesh is None:
        mesh = shard.build_mesh()
    if isinstance(idx, SegmentedIndex):
        st = idx._state
        main = _shard_loaded(idx.main, shard, mesh)
        out = SegmentedIndex(main, spec=_spec_with_shard(idx.spec, shard),
                             drift_threshold=idx.drift_threshold,
                             max_delta_fraction=idx.max_delta_fraction)
        out._restore(main_gids=idx._main_gids, tomb=st.tomb,
                     next_gid=st.next_gid, segments=st.segments,
                     drift_sd=idx.drift.state_dict())
        return out
    if isinstance(idx, IVFIndex):
        if idx.store is not None:
            raise ValueError(
                "shard= needs a fully resident index — store-backed "
                "storage cannot be placed; load with resident='all'")
        out = ShardedIVFIndex(idx, mesh, doc_axis=shard.doc_axis,
                              query_axis=shard.effective_query_axis)
    elif isinstance(idx, CompressedIndex):
        out = ShardedCompressedIndex(
            idx.pipeline, mesh, sim=idx.sim, backend=idx.backend,
            doc_axis=shard.doc_axis, query_axis=shard.effective_query_axis)
        out.scorer.load_extra_state(idx.scorer.extra_state())
        out._storage_host = idx.storage
        out._n_docs = len(idx)
        out._dim = idx._dim
    else:
        raise TypeError(
            f"shard= cannot wrap a {type(idx).__name__} — sharding covers "
            "CompressedIndex, IVFIndex, and their mutable wrappers")
    out.spec = _spec_with_shard(idx.spec, shard)
    return out
