"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations


import jax

from repro.parallel.sharding import (AxisRules, MULTI_POD_RULES,
                                     SINGLE_POD_RULES)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod mesh: (data=16, model=16); two pods add a leading
    "pod" axis: (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for_mesh(mesh) -> AxisRules:
    return MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES


def make_test_mesh(n_devices: int = 8, model: int = 2):
    """Small mesh for unit tests (requires forced host devices)."""
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"))
