"""Roofline-term extraction from compiled XLA artifacts (TPU v5e model).

Per (arch × shape × mesh) cell:

    compute term    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
    memory term     = HLO_bytes   / (chips × 819e9 B/s HBM)
    collective term = coll_bytes  / (chips × 3 links × 50e9 B/s ICI)

``cost_analysis()`` supplies FLOPs and bytes accessed.  Collective bytes are
*not* in cost_analysis — we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (cross-pod DCI collectives are counted separately by
matching the replica-group span when possible).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e hardware model
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 3                # usable links per chip (2D torus + wrap)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u4": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' → byte count; tuple shapes handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # instruction lines look like:  "%name = f32[..] all-reduce(...)"
        m = re.search(r"=\s+((?:\(|\w).*?)\s+(" + "|".join(_COLLECTIVES)
                      + r")[\.\( ]", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        out[op] += nbytes
        out["total"] += nbytes
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    mesh: str
    chips: int
    hlo_gflops: float            # total across chips
    hlo_gbytes: float
    coll_gbytes: float
    per_collective: dict
    model_gflops: Optional[float]
    peak_memory_bytes: Optional[int]

    @property
    def t_compute(self) -> float:
        return self.hlo_gflops * 1e9 / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_gbytes * 1e9 / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return (self.coll_gbytes * 1e9
                / (self.chips * ICI_LINKS * ICI_BW))

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline-model step time: max of the three terms (perfect
        overlap assumption — the optimistic bound)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs throughput vs peak, at roofline step time."""
        if not self.model_gflops or self.step_time <= 0:
            return 0.0
        achieved = self.model_gflops * 1e9 / self.step_time
        return achieved / (self.chips * PEAK_FLOPS)

    @property
    def flops_efficiency(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — compiled-compute usefulness."""
        if not self.model_gflops or not self.hlo_gflops:
            return 0.0
        return self.model_gflops / self.hlo_gflops

    def to_dict(self) -> dict:
        return {
            "name": self.name, "mesh": self.mesh, "chips": self.chips,
            "hlo_gflops": self.hlo_gflops, "hlo_gbytes": self.hlo_gbytes,
            "coll_gbytes": self.coll_gbytes,
            "per_collective": self.per_collective,
            "model_gflops": self.model_gflops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "roofline_fraction": self.roofline_fraction,
            "flops_efficiency": self.flops_efficiency,
        }


def analyze(name: str, mesh_desc: str, chips: int, compiled,
            model_flops: Optional[float] = None) -> RooflineReport:
    """Build a report from a jax compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        peak_mem = int(getattr(ma, "temp_size_in_bytes", 0)
                       + getattr(ma, "argument_size_in_bytes", 0)
                       + getattr(ma, "output_size_in_bytes", 0)
                       - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass

    return RooflineReport(
        name=name, mesh=mesh_desc, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=nbytes / 1e9,
        coll_gbytes=coll["total"] / 1e9,
        per_collective={k: v for k, v in coll.items() if k != "total"},
        model_gflops=(model_flops / 1e9 if model_flops else None),
        peak_memory_bytes=peak_mem)
